"""§Perf hillclimb driver: named experiments = (cell, ArchConfig overrides).

Each experiment re-lowers one dry-run cell with a config change and records
the roofline deltas — the measure step of the hypothesis->change->measure->
validate loop logged in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.perf --exp olmoe_naive
    PYTHONPATH=src python -m repro.launch.perf --all
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402
import subprocess  # noqa: E402
import sys       # noqa: E402

# name -> (arch, shape, overrides)
EXPERIMENTS = {
    # ---- cell A: olmoe-1b-7b train_4k (the paper's technique at LM scale)
    "olmoe_baseline": ("olmoe-1b-7b", "train_4k", {}),
    "olmoe_naive": ("olmoe-1b-7b", "train_4k",
                    {"moe_impl": "naive"}),           # paper's -O2 baseline
    "olmoe_cf125": ("olmoe-1b-7b", "train_4k",
                    {"capacity_factor": 1.25}),
    "olmoe_cf100": ("olmoe-1b-7b", "train_4k",
                    {"capacity_factor": 1.0}),
    "olmoe_mb1": ("olmoe-1b-7b", "train_4k", {"microbatches": 1}),
    "olmoe_best": ("olmoe-1b-7b", "train_4k",
                   {"microbatches": 1, "capacity_factor": 1.25,
                    "moe_combine_bf16": True}),
    # ---- cell B: mistral-large-123b train_4k (most collective-bound)
    "mistral_baseline": ("mistral-large-123b", "train_4k", {}),
    "mistral_no_sp": ("mistral-large-123b", "train_4k",
                      {"seq_parallel": False}),       # Megatron-TP baseline
    "mistral_mb4": ("mistral-large-123b", "train_4k", {"microbatches": 4}),
    "mistral_mb16": ("mistral-large-123b", "train_4k", {"microbatches": 16}),
    "mistral_no_remat": ("mistral-large-123b", "train_4k", {"remat": False}),
    # ---- cell C: granite-34b decode_32k (memory-bound decode, MQA)
    "g34_decode_baseline": ("granite-34b", "decode_32k", {}),
    "g34_decode_seqshard": ("granite-34b", "decode_32k",
                            {"decode_cache_seq_shard": True}),
    "g34_decode_f8cache": ("granite-34b", "decode_32k",
                           {"cache_dtype": "float8_e4m3fn"}),
    "g34_decode_f8_seqshard": ("granite-34b", "decode_32k",
                               {"cache_dtype": "float8_e4m3fn",
                                "decode_cache_seq_shard": True}),
}


def run_experiment(name: str, out_dir: str = "experiments/perf") -> dict:
    from repro.launch.dryrun import analyze_cell
    arch, shape, overrides = EXPERIMENTS[name]
    res = analyze_cell(arch, shape, multi_pod=False,
                       arch_overrides=overrides)
    res["experiment"] = name
    res["overrides"] = {k: str(v) for k, v in overrides.items()}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None, choices=list(EXPERIMENTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default="experiments/perf")
    args = ap.parse_args()
    if args.all:
        # subprocess isolation per experiment
        fails = 0
        for name in EXPERIMENTS:
            path = os.path.join(args.out_dir, name + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {name}")
                continue
            print(f"[run] {name}", flush=True)
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.perf", "--exp", name,
                 "--out-dir", args.out_dir],
                env={**os.environ, "PYTHONPATH": "src"},
                capture_output=True, text=True, timeout=2400)
            if proc.returncode != 0:
                fails += 1
                print(f"[FAIL] {name}\n{(proc.stderr or '')[-1200:]}")
                with open(path, "w") as f:
                    json.dump({"experiment": name, "status": "fail",
                               "error": (proc.stderr or "")[-1500:]}, f)
            else:
                print(f"[ok] {name}")
        sys.exit(1 if fails else 0)
    assert args.exp
    res = run_experiment(args.exp, args.out_dir)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("collectives", "memory")}))


if __name__ == "__main__":
    main()
