"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
    * .lower().compile() must succeed on the 16x16 single-pod mesh AND the
      (2,16,16) multi-pod mesh for every runnable cell;
    * memory_analysis() proves the working set fits;
    * cost_analysis() + HLO collective parsing feed §Roofline.

Usage:
    python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--jobs-dir experiments/dryrun]

Each cell can run in a subprocess (--all) so a failure or OOM in one cell
never kills the sweep; results are cached incrementally as JSON.
"""
# The VERY FIRST lines, before ANY other import (jax locks device count on
# first init):
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import subprocess   # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPES, all_archs, get_arch, shape_skips  # noqa: E402
from repro import compat                                            # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_rules      # noqa: E402
from repro.models import build_model                                # noqa: E402
from repro.models import spec as S                                  # noqa: E402
from repro.train import optim as O                                  # noqa: E402
from repro.train import train_step as TS                            # noqa: E402

# ---------------------------------------------------------------------------
# Collective-byte extraction from (per-partition) compiled HLO
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.-]+)\s*\(.*\{\s*$")
_CALL_REFS = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w.-]+)"
    r"|branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OPLINE = re.compile(r"^(?:ROOT\s+)?%?([\w.-]+)\s*=\s*"
                     r"((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                     r"([a-z0-9-]+)\(")
# operands may carry inline type annotations on older XLA text
# ("dot(f32[64,32]{1,0} %Arg_0.1, ...)"), bare names on newer
_DOT_OPERANDS = re.compile(
    r"\((?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?%?([\w.-]+),"
    r"\s*(?:[a-z0-9]+\[[0-9,]*\]\S*\s+)?%?([\w.-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


# ops that alias rather than move data
_ALIAS_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
              "constant", "iota", "while", "conditional", "after-all",
              "opt-barrier"}
_OPERANDS_RE = re.compile(r"%([\w.-]+)")


def _effective_bytes(op: str, typ: str, line: str, types: dict) -> int:
    """Bytes actually moved by this op (output bytes, with corrections):
    alias ops move nothing; dynamic-update-slice and scatter write only
    their update operand, not the whole buffer."""
    if op in _ALIAS_OPS:
        return 0
    if op in ("dynamic-update-slice", "scatter", "scatter-add"):
        args = line.split(op + "(", 1)
        if len(args) == 2:
            names = _OPERANDS_RE.findall(args[1].split(")", 1)[0])
            upd_idx = 1 if op == "dynamic-update-slice" else 2
            if len(names) > upd_idx and names[upd_idx] in types:
                return _shape_bytes(types[names[upd_idx]])
    return _shape_bytes(typ)


def analyze_hlo(hlo_text: str) -> dict:
    """Loop-aware analysis of partitioned HLO text.

    XLA's cost_analysis counts while bodies ONCE; here every computation's
    cost is multiplied by its execution count (from known_trip_count
    backend configs), giving per-device totals for:
      * flops         — 2*M*N*K summed over dot ops (matmul-dominated)
      * bytes_proxy   — sum of op output bytes outside fusion bodies,
                        x2 for read+write (HBM traffic proxy)
      * collectives   — output bytes by kind (link-traffic proxy)
    """
    comps: dict = {}
    entry = None
    cur = None
    # tensors below this size are treated as VMEM-resident within their
    # computation (fused / register-allocated on the TPU target); larger
    # outputs are assumed to round-trip HBM.
    HBM_THRESHOLD = 1 << 20
    for raw in hlo_text.splitlines():
        stripped = raw.strip()
        m = _COMP_HDR.match(stripped) if raw and not raw.startswith("  ") else None
        if m and "=" not in stripped.split("(")[0]:
            cur = m.group(2)
            comps[cur] = {"coll_bytes": {k: 0 for k in _COLLECTIVES},
                          "coll_counts": {k: 0 for k in _COLLECTIVES},
                          "flops": 0.0, "out_bytes": 0.0, "hbm_bytes": 0.0,
                          "edges": [], "fused": False, "types": {},
                          "fusion_ops": [], "root_dus_bytes": None}
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        c = comps[cur]
        om = _OPLINE.match(stripped)
        if om:
            name, typ, op = om.group(1), om.group(2), om.group(3)
            c["types"][name] = typ
            if op == "fusion":
                # resolved at totals time: an in-place DUS-rooted fusion
                # writes only its update slice, not the whole buffer
                fm = re.search(r"calls=%?([\w.-]+)", stripped)
                c["fusion_ops"].append(
                    (fm.group(1) if fm else "", _shape_bytes(typ)))
                nbytes = 0
            else:
                nbytes = _effective_bytes(op, typ, stripped, c["types"])
            if stripped.startswith("ROOT") and op in (
                    "dynamic-update-slice", "scatter", "scatter-add"):
                c["root_dus_bytes"] = _effective_bytes(
                    op, typ, stripped, c["types"])
            c["out_bytes"] += nbytes
            if nbytes >= HBM_THRESHOLD:
                c["hbm_bytes"] += nbytes
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and not op.endswith("-done"):
                c["coll_bytes"][base] += nbytes
                c["coll_counts"][base] += 1
            if op == "dot":
                dm = _DOT_OPERANDS.search(stripped)
                cm = _LHS_CDIMS.search(stripped)
                if dm and cm is not None:
                    lhs_type = c["types"].get(dm.group(1), "")
                    ldims = _dims_of(lhs_type)
                    cidx = [int(i) for i in cm.group(1).split(",") if i]
                    ksize = 1
                    for i in cidx:
                        if i < len(ldims):
                            ksize *= ldims[i]
                    out_elems = 1
                    for d in _dims_of(typ):
                        out_elems *= d
                    c["flops"] += 2.0 * out_elems * ksize
        trip = 1
        tm = _TRIP.search(stripped)
        if tm:
            trip = int(tm.group(1))
        is_fusion_line = " fusion(" in stripped or stripped.startswith("fusion(")
        for cmatch in _CALL_REFS.finditer(stripped):
            if cmatch.group(1):
                is_body = stripped[cmatch.start():cmatch.start() + 5] == "body="
                callee = cmatch.group(1)
                c["edges"].append((callee, trip if is_body else 1))
                if is_fusion_line and callee in comps:
                    comps[callee]["fused"] = True
                elif is_fusion_line:
                    c.setdefault("fused_callees", []).append(callee)
            elif cmatch.group(2):
                for br in re.findall(r"%?([\w.-]+)", cmatch.group(2)):
                    c["edges"].append((br, 1))
    # late fusion marks (callee defined after caller)
    for c in comps.values():
        for callee in c.get("fused_callees", []):
            if callee in comps:
                comps[callee]["fused"] = True
    # execution-count fixpoint over the call DAG
    mult = {name: 0.0 for name in comps}
    if entry is None and comps:
        entry = next(iter(comps))
    if entry:
        mult[entry] = 1.0
    for _ in range(64):
        new = {name: 0.0 for name in comps}
        if entry:
            new[entry] = 1.0
        for name, c in comps.items():
            for callee, factor in c["edges"]:
                if callee in new:
                    new[callee] += mult[name] * factor
        if new == mult:
            break
        mult = new
    flops = 0.0
    out_bytes = 0.0
    hbm_bytes = 0.0
    coll = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for name, c in comps.items():
        mlt = mult.get(name, 0.0)
        flops += c["flops"] * mlt
        if not c["fused"]:
            fusion_bytes = 0.0
            fusion_hbm = 0.0
            for callee, out_b in c["fusion_ops"]:
                dus = comps.get(callee, {}).get("root_dus_bytes")
                eff = dus if dus is not None else out_b
                fusion_bytes += eff
                if eff >= (1 << 20):
                    fusion_hbm += eff
            out_bytes += (c["out_bytes"] + fusion_bytes) * mlt
            hbm_bytes += (c["hbm_bytes"] + fusion_hbm) * mlt
        for k in _COLLECTIVES:
            coll[k] += int(c["coll_bytes"][k] * mlt)
            counts[k] += int(c["coll_counts"][k] * mlt)
    return {
        "flops": flops,
        "bytes_proxy": 2.0 * out_bytes,
        "bytes_hbm_est": 2.0 * hbm_bytes,
        "collectives": {"bytes": coll, "counts": counts,
                        "total_bytes": int(sum(coll.values()))},
    }


def collective_bytes(hlo_text: str) -> dict:
    return analyze_hlo(hlo_text)["collectives"]


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

# Gradient-accumulation factors for the train shape: chosen so per-device
# activation live-sets fit 16 GB v5e HBM (validated via memory_analysis in
# EXPERIMENTS.md §Dry-run).  Must divide global_batch/batch_shards.
TRAIN_MICROBATCHES = {
    "rwkv6-1.6b": 2,
    "internvl2-2b": 2,
    "granite-moe-3b-a800m": 2,
    "olmoe-1b-7b": 2,
    "granite-8b": 2,
    "mistral-large-123b": 8,
    "granite-34b": 4,
    "olmo-1b": 1,
    "jamba-v0.1-52b": 8,
    "hubert-xlarge": 2,
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_overrides: dict | None = None,
               arch_overrides: dict | None = None):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        cfg = cfg.replace(microbatches=TRAIN_MICROBATCHES.get(cfg.name, 1))
    if arch_overrides:
        import jax.numpy as jnp
        conv = {}
        for k, v in arch_overrides.items():
            if k.endswith("dtype") and isinstance(v, str):
                v = jnp.dtype(v).type if hasattr(jnp, v) is False else getattr(jnp, v)
            conv[k] = v
        cfg = cfg.replace(**conv)
    skip = shape_skips(cfg, shape)
    if skip:
        return {"status": "skip", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = mesh_rules(multi_pod)
    cfg = cfg.replace(spmd_constraints=True,
                      mesh_axis_sizes=tuple(mesh.shape.items()))
    model = build_model(cfg)
    pshard = TS.param_shardings(model, mesh, rules)
    abs_params = model.abstract_params()

    if shape.kind == "train":
        opt_cfg = O.AdamWConfig(**(opt_overrides or {}))
        step = TS.make_train_step(model, opt_cfg)
        oshard = TS.opt_state_shardings(model, opt_cfg, mesh, rules)
        bshard = TS.batch_shardings(model, shape, mesh, rules)
        abs_opt = jax.eval_shape(lambda p: O.adamw_init(opt_cfg, p), abs_params)
        abs_batch = model.input_specs(shape)
        with compat.use_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(pshard, oshard, bshard),
                out_shardings=(pshard, oshard, None),
                donate_argnums=(0, 1),
            ).lower(abs_params, abs_opt, abs_batch)
    elif shape.kind == "prefill":
        step = TS.make_serve_step(model, "prefill")
        bshard = TS.batch_shardings(model, shape, mesh, rules)
        cshard = TS.prefill_cache_shardings(model, shape, mesh, rules)
        abs_batch = model.input_specs(shape)
        with compat.use_mesh(mesh):
            lowered = jax.jit(
                step, in_shardings=(pshard, bshard),
                out_shardings=(None, cshard),
            ).lower(abs_params, abs_batch)
    else:  # decode
        step = TS.make_serve_step(model, "decode")
        bsh = TS.batch_shardings(model, shape, mesh, rules)
        specs = model.input_specs(shape)
        with compat.use_mesh(mesh):
            lowered = jax.jit(
                step,
                in_shardings=(pshard, bsh["cache"], bsh["tokens"], bsh["pos"]),
                out_shardings=(None, bsh["cache"]),
                donate_argnums=(1,),
            ).lower(abs_params, specs["cache"], specs["tokens"], specs["pos"])
    return {"status": "lowered", "lowered": lowered, "model": model,
            "mesh": mesh, "cfg": cfg, "shape": shape}


def analyze_cell(arch: str, shape_name: str, multi_pod: bool,
                 opt_overrides: dict | None = None,
                 arch_overrides: dict | None = None) -> dict:
    t0 = time.time()
    res = lower_cell(arch, shape_name, multi_pod, opt_overrides,
                     arch_overrides)
    if res["status"] == "skip":
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": res["reason"]}
    lowered, model = res["lowered"], res["model"]
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    hstats = analyze_hlo(hlo)
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "compile_seconds": round(compile_s, 1),
        "n_devices": int(np.prod(list(res["mesh"].shape.values()))),
        "params_total": model.param_count(),
        "params_active": model.active_param_count(),
        # loop-corrected per-device numbers from the HLO walk
        "flops": hstats["flops"],
        "bytes_proxy": hstats["bytes_proxy"],
        "bytes_hbm_est": hstats["bytes_hbm_est"],
        # XLA's own (loop-body-once) numbers, for reference
        "xla_flops": float(cost.get("flops", 0.0)) if cost else None,
        "xla_bytes_accessed": (float(cost.get("bytes accessed", 0.0))
                               if cost else None),
        "collectives": hstats["collectives"],
        "memory": _memory_dict(mem),
        "hlo_bytes": len(hlo),
    }
    return out


def _memory_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "host_generated_code_size_in_bytes",
            "host_argument_size_in_bytes", "host_output_size_in_bytes",
            "host_temp_size_in_bytes", "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_one(args) -> int:
    result = analyze_cell(args.arch, args.shape, args.mesh == "multi",
                          arch_overrides=json.loads(args.overrides or "{}"))
    print(json.dumps(result))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return 0 if result["status"] in ("ok", "skip") else 1


def run_all(args) -> int:
    os.makedirs(args.jobs_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    for arch in all_archs():
        for shape in SHAPES:
            for mesh in meshes:
                cells.append((arch, shape, mesh))
    failures = 0
    for arch, shape, mesh in cells:
        name = f"{arch}__{shape}__{mesh}".replace("/", "_")
        path = os.path.join(args.jobs_dir, name + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[cached] {name}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", path]
        if args.overrides:
            cmd += ["--overrides", args.overrides]
        print(f"[run] {name}", flush=True)
        t0 = time.time()
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=args.timeout,
                              env={**os.environ, "PYTHONPATH": "src"})
        dt = time.time() - t0
        if proc.returncode != 0:
            failures += 1
            err = (proc.stderr or "")[-2000:]
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "fail", "error": err}, f, indent=1)
            print(f"[FAIL {dt:.0f}s] {name}\n{err}", flush=True)
        else:
            print(f"[ok {dt:.0f}s] {name}", flush=True)
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--overrides", default=None,
                    help='JSON ArchConfig overrides, e.g. {"moe_impl":"naive"}')
    ap.add_argument("--jobs-dir", default="experiments/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    assert args.arch and args.shape and args.mesh in ("single", "multi")
    sys.exit(run_one(args))


if __name__ == "__main__":
    main()
