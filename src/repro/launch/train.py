"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        [--steps N] [--smoke] [--data data.bin] [--ckpt-dir ckpts] \
        [--mesh-data D --mesh-model M] [--compress-grads] [--moe-impl lilac]

On this CPU container use --smoke (reduced config).  On a real cluster the
same entrypoint runs under `jax.distributed.initialize()` per host; the
mesh spans all devices and the checkpoint/restart + elastic logic in
train/ takes over on failures.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.launch.mesh import make_host_mesh, mesh_rules
from repro.models import build_model
from repro.train.data import MemmapCorpus, SyntheticEmbeds, SyntheticLM
from repro.train.loop import LoopConfig, train_loop
from repro.train.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--data", default=None, help="token .bin (int32)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "naive", "lilac", "grouped"])
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.moe_impl:
        cfg = cfg.replace(moe_impl=args.moe_impl)

    mesh = rules = None
    if args.mesh_data * args.mesh_model > 1:
        mesh = make_host_mesh(args.mesh_data, args.mesh_model)
        rules = mesh_rules(False)
        cfg = cfg.replace(spmd_constraints=True,
                          mesh_axis_sizes=tuple(mesh.shape.items()))

    model = build_model(cfg)
    print(f"{cfg.name}: {model.param_count()/1e6:.1f}M params "
          f"({model.active_param_count()/1e6:.1f}M active), "
          f"mesh={dict(mesh.shape) if mesh else 'single-device'}")

    if args.data:
        data = MemmapCorpus(args.data, args.seq, args.batch)
    elif cfg.frontend == "stub":
        data = SyntheticEmbeds(d_model=cfg.d_model, vocab=cfg.vocab,
                               seq_len=args.seq, global_batch=args.batch)
    else:
        data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch)

    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 10, 1),
                      compress_grads=args.compress_grads)
    loop = LoopConfig(steps=args.steps,
                      ckpt_every=max(args.steps // 4, 1),
                      log_every=10, ckpt_dir=args.ckpt_dir)
    res = train_loop(model, opt, loop, data.batch_at, mesh=mesh, rules=rules)
    h = res["history"]
    print(f"final: loss {h[0]:.4f} -> {h[-1]:.4f}; "
          f"stragglers={res['straggler'].slow_steps}")


if __name__ == "__main__":
    main()
