"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    return compat.make_mesh((data, model), ("data", "model"))


def mesh_rules(multi_pod: bool = False):
    from repro.models.spec import MULTI_POD_RULES, SINGLE_POD_RULES
    return MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES
