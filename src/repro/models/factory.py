"""Model factory: ArchConfig -> Model (spec + step functions + input specs)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T
from repro.models import spec as S

F32 = jnp.float32


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    spec: Dict[str, Any]

    # -- parameters -----------------------------------------------------------

    def init(self, key: jax.Array):
        return S.init_params(self.spec, key)

    def abstract_params(self):
        return S.abstract_params(self.spec)

    def param_count(self) -> int:
        return S.count_params(self.spec)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of the experts)."""
        cfg = self.cfg
        total = self.param_count()
        if not cfg.moe_experts:
            return total
        # jax.tree.leaves_with_path is newer-jax only; the tree_util
        # spelling exists on both sides of the pin
        leaves = jax.tree_util.tree_leaves_with_path(
            self.spec, is_leaf=lambda x: isinstance(x, S.ParamSpec))
        expert_params = 0
        for path, p in leaves:
            keys = "/".join(str(k) for k in path)
            if "moe" in keys and "router" not in keys:
                expert_params += int(np.prod(p.shape))
        active = total - expert_params \
            + expert_params * cfg.moe_topk // cfg.moe_experts
        return int(active)

    # -- forward paths ---------------------------------------------------------

    def loss_fn(self, params, batch: Dict[str, Any]):
        """batch: tokens/embeds + labels -> scalar loss."""
        x, aux, _ = T.forward(self.cfg, params, batch)
        loss = T.lm_loss(self.cfg, params, x, batch["labels"])
        return loss + 0.01 * aux

    def prefill(self, params, batch: Dict[str, Any]):
        x, _, caches = T.forward(self.cfg, params, batch, collect_cache=True)
        logits = T.lm_logits_last(self.cfg, params, x)
        return logits, caches

    def decode(self, params, cache, tokens, pos):
        return T.decode_step(self.cfg, params, cache, tokens, pos)

    def init_cache(self, B: int, max_seq: int):
        return T.init_cache(self.cfg, B, max_seq)

    def cache_from_prefill(self, caches, prefill_len: int, max_seq: int):
        """Convert prefill-collected (stacked, length-S) caches into the
        per-layer decode cache layout padded to ``max_seq``."""
        cfg = self.cfg
        out = {}
        n_periods = T.n_periods(cfg)
        for j in range(n_periods):
            period = {}
            for bkey, entries in caches.items():
                ce = {}
                for name, leaf in entries.items():
                    sliced = leaf[j]
                    if name in ("k", "v"):
                        pad = max_seq - sliced.shape[1]
                        if pad > 0:
                            sliced = jnp.pad(
                                sliced, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        sliced = sliced.astype(cfg.cache_dtype)
                    ce[name] = sliced
                period[bkey] = ce
            out[f"p{j}"] = period
        return out

    # -- batched-cache slot management (serving tier) --------------------------

    def cache_set_slot(self, cache, slot: int, row_cache):
        """Write a single-request cache (every leaf batch-1, same seq
        capacity) into row ``slot`` of a batched cache."""
        return jax.tree.map(
            lambda full, one: full.at[slot].set(one[0].astype(full.dtype)),
            cache, row_cache)

    def cache_move_slot(self, cache, src: int, dst: int):
        """Copy cache row ``src`` over row ``dst`` (slot compaction after
        an eviction; the stale ``src`` row is left behind and simply never
        read once the scheduler shrinks the active prefix)."""
        return jax.tree.map(lambda a: a.at[dst].set(a[src]), cache)

    def cache_resize(self, cache, B: Optional[int] = None,
                     max_seq: Optional[int] = None):
        """Re-bucket a cache: grow/shrink the batch axis (axis 0 of every
        leaf) and the sequence-capacity axis of the k/v leaves (axis 1 —
        keyed by leaf NAME, not rank: mamba's conv leaf also has 3 dims but
        its axis 1 is the kernel width).  Growth pads with zeros; shrink
        slices (the engine only shrinks when every active request fits)."""
        def fix(path, a):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if B is not None and a.shape[0] != B:
                if B > a.shape[0]:
                    a = jnp.pad(a, ((0, B - a.shape[0]),)
                                + ((0, 0),) * (a.ndim - 1))
                else:
                    a = a[:B]
            if (max_seq is not None and name in ("k", "v")
                    and a.shape[1] != max_seq):
                if max_seq > a.shape[1]:
                    a = jnp.pad(a, ((0, 0), (0, max_seq - a.shape[1]))
                                + ((0, 0),) * (a.ndim - 2))
                else:
                    a = a[:, :max_seq]
            return a
        return jax.tree_util.tree_map_with_path(fix, cache)

    # -- dry-run inputs ---------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation).

        train:   {"tokens"/"embeds", "labels"}
        prefill: {"tokens"/"embeds"}
        decode:  {"tokens", "pos", "cache"}  (cache of seq_len)
        """
        cfg = self.cfg
        B, Sq = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, Sq), jnp.int32)
        emb = jax.ShapeDtypeStruct((B, Sq, cfg.d_model), cfg.param_dtype)
        if shape.kind == "train":
            inp = {"embeds": emb} if cfg.frontend == "stub" else {"tokens": tok}
            inp["labels"] = jax.ShapeDtypeStruct((B, Sq), jnp.int32)
            return inp
        if shape.kind == "prefill":
            return {"embeds": emb} if cfg.frontend == "stub" else {"tokens": tok}
        if shape.kind == "decode":
            cache = jax.eval_shape(lambda: self.init_cache(B, Sq))
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                    "pos": jax.ShapeDtypeStruct((), jnp.int32),
                    "cache": cache}
        raise ValueError(shape.kind)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg, spec=T.model_spec(cfg))
