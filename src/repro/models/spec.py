"""Parameter specification trees: shape + dtype + logical sharding axes.

Every module describes its parameters as a tree of ``ParamSpec``; from one
spec tree we derive
  * initialized parameters (for real runs),
  * ShapeDtypeStruct stand-ins (for the dry-run — no allocation),
  * NamedShardings via logical->mesh axis rules (the distribution config).

Logical axes used across the zoo:
  "embed"    d_model dims of weight matrices        -> FSDP axis ("data")
  "mlp"      d_ff / expert hidden dims              -> TP axis ("model")
  "heads"    attention-head dims (q)                -> TP axis ("model")
  "kv_heads" kv-head dims                           -> TP if divisible
  "vocab"    embedding/unembedding vocab dim        -> TP axis ("model")
  "expert"   MoE expert dim                         -> EP axis ("model")
  "layers"   scan-stacked layer dim                 -> replicated
  None       replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"     # normal | zeros | ones | arange_log | dt_bias
    scale: float = 1.0       # stddev multiplier for normal init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# default logical->mesh rules (single pod). Multi-pod rules map "batch" to
# ("pod", "data") and keep weight axes identical (pod replicates weights —
# pure DP across pods; FSDP within a pod).
SINGLE_POD_RULES: Dict[str, Any] = {
    "batch": "data",
    "embed": "data",      # FSDP / ZeRO-3 axis for weights
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "expert": "model",
    "seq": None,
    "layers": None,
}

MULTI_POD_RULES: Dict[str, Any] = {
    **SINGLE_POD_RULES,
    "batch": ("pod", "data"),
}

# Compute-time rules: inside the per-layer scan body, weights are
# constrained to TP-only sharding (replicated over the FSDP axis).  The
# storage rules above shard weights 2D (data x model) for memory; the
# constraint makes XLA all-gather each layer's weight slice just-in-time
# (ZeRO-3 semantics: small per-layer weight gathers instead of activation
# all-reduces on every contraction with a data-sharded dimension).
COMPUTE_RULES: Dict[str, Any] = {
    **SINGLE_POD_RULES,
    "embed": None,
}


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_to_pspec(spec: ParamSpec, mesh: Mesh, rules: Dict[str, Any]) -> P:
    """Logical axes -> PartitionSpec, dropping non-divisible shardings
    (e.g. kv_heads=8 on a 16-way model axis -> replicate)."""
    entries = []
    used: set = set()
    for dim, ax in zip(spec.shape, spec.axes):
        mapped = rules.get(ax) if ax is not None else None
        if mapped is None:
            entries.append(None)
            continue
        axes = mapped if isinstance(mapped, tuple) else (mapped,)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            entries.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size != 0:
            # jit in_shardings require divisibility; replicate instead
            # (e.g. kv=8 or vocab=504 on a 16-way axis, experts=40).  The
            # compute path re-shards paddable dims itself (shard_map MoE).
            entries.append(None)
            continue
        entries.append(axes[0] if len(axes) == 1 else axes)
        used.update(axes)
    return P(*entries)


def spec_to_pspec_sizes(spec: ParamSpec, axis_sizes: Dict[str, int],
                        rules: Dict[str, Any]) -> P:
    """Like spec_to_pspec but with explicit axis sizes (usable at trace
    time inside with_sharding_constraint, no Mesh object needed)."""
    entries = []
    used: set = set()
    for dim, ax in zip(spec.shape, spec.axes):
        mapped = rules.get(ax) if ax is not None else None
        if mapped is None:
            entries.append(None)
            continue
        axes = mapped if isinstance(mapped, tuple) else (mapped,)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            entries.append(None)
            continue
        size = int(np.prod([axis_sizes.get(a, 1) for a in axes]))
        if dim % size != 0:
            entries.append(None)
            continue
        entries.append(axes[0] if len(axes) == 1 else axes)
        used.update(axes)
    return P(*entries)


def compute_pspecs(spec_tree, axis_sizes: Dict[str, int],
                   rules: Optional[Dict[str, Any]] = None):
    """PartitionSpec tree for compute-time constraints (TP-only weights)."""
    rules = rules or COMPUTE_RULES
    return jax.tree.map(
        lambda s: spec_to_pspec_sizes(s, axis_sizes, rules),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_pspecs(spec_tree, mesh: Mesh, rules: Dict[str, Any]):
    return jax.tree.map(
        lambda s: spec_to_pspec(s, mesh, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def tree_shardings(spec_tree, mesh: Mesh, rules: Dict[str, Any]):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def abstract_params(spec_tree):
    """ShapeDtypeStruct stand-ins — the dry-run path, no allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def init_params(spec_tree, key: jax.Array):
    """Materialize parameters (smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = []
    for s, k in zip(leaves, keys):
        # np-backed constants: distinct buffers per leaf (jnp.zeros would
        # alias identical constants, breaking donation)
        if s.init == "zeros":
            vals.append(jnp.asarray(np.zeros(s.shape), dtype=s.dtype))
        elif s.init == "ones":
            vals.append(jnp.asarray(np.ones(s.shape), dtype=s.dtype))
        elif s.init == "arange_log":
            # Mamba S4D-real init: A_log[..., n] = log(n+1), so the decay
            # spectrum A = -[1..N] is spread per state dim.  Keeps |h|
            # bounded; init="zeros" (A = -1 uniformly) lets the selective
            # scan state reach ~1e7 where fp32 ulp noise flips predictions.
            row = np.log(np.arange(1, s.shape[-1] + 1))
            vals.append(jnp.asarray(
                np.broadcast_to(row, s.shape).copy(), dtype=s.dtype))
        elif s.init == "dt_bias":
            # softplus^-1(dt_init): softplus(dt_bias) == dt_init == scale,
            # the reference Mamba timestep floor (dt in [1e-3, 1e-1]).
            val = np.log(np.expm1(s.scale))
            vals.append(jnp.asarray(np.full(s.shape, val), dtype=s.dtype))
        else:
            fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            std = s.scale / np.sqrt(max(fan_in, 1))
            vals.append((jax.random.normal(k, s.shape, jnp.float32) * std
                         ).astype(s.dtype))
    return jax.tree.unflatten(treedef, vals)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(np.prod(s.shape) for s in leaves))
