"""Shared layers: norms, rotary, GQA attention, SwiGLU MLP, MoE variants.

All functions are pure; parameters arrive as dict subtrees built from the
matching *_spec functions (see spec.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.spec import ParamSpec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), (None,), init="ones", dtype=jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def nonparam_layernorm(x, eps: float = 1e-5):
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def chunked_scan(step, init, xs, chunk: int = 128):
    """lax.scan in rematerialized chunks: backward saves carries only at
    chunk boundaries and replays the chunk forward — O(S/chunk) state
    memory instead of O(S) (the Mamba 'don't materialize h' insight,
    realized with jax.checkpoint).  xs leaves: (S, ...)."""
    S = jax.tree.leaves(xs)[0].shape[0]
    if S <= chunk:
        return jax.lax.scan(step, init, xs)
    nch = S // chunk
    rem = S - nch * chunk
    xs_main = jax.tree.map(
        lambda a: a[:nch * chunk].reshape((nch, chunk) + a.shape[1:]), xs)

    @jax.checkpoint
    def inner(carry, xs_chunk):
        return jax.lax.scan(step, carry, xs_chunk)

    carry, ys = jax.lax.scan(inner, init, xs_main)
    ys = jax.tree.map(
        lambda a: a.reshape((nch * chunk,) + a.shape[2:]), ys)
    if rem:
        tail = jax.tree.map(lambda a: a[nch * chunk:], xs)
        carry, ys_tail = jax.lax.scan(step, carry, tail)
        ys = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), ys, ys_tail)
    return carry, ys


def make_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return rmsnorm_spec(d), rmsnorm
    if kind == "layernorm_nonparam":
        return {}, lambda p, x: nonparam_layernorm(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions[..., None].astype(F32) * freqs          # (..., S, half)
    angles = angles[..., None, :]                              # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(F32), x[..., half:2 * half].astype(F32)
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rest = x[..., 2 * half:]
    return jnp.concatenate(
        [out1.astype(x.dtype), out2.astype(x.dtype), rest], axis=-1)


# ---------------------------------------------------------------------------
# GQA attention (chunked online-softmax for long sequences)
# ---------------------------------------------------------------------------

def attention_spec(d_model: int, n_heads: int, n_kv: int,
                   head_dim: int) -> Dict[str, ParamSpec]:
    return {
        "wq": ParamSpec((d_model, n_heads, head_dim), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv_heads", None)),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }


def _qkv(p, x, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, kv_chunk: int = 1024,
                      q_positions=None, kv_positions=None):
    """Memory-efficient attention: scan over kv chunks with running
    (max, denom, acc) — O(S * kv_chunk) live logits instead of O(S^2).

    q: (B, Sq, H, dh); k, v: (B, Skv, KV, dh) with H % KV == 0.
    """
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(dh)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
    nchunks = (Skv + kv_chunk - 1) // kv_chunk
    pad = nchunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
    kc = k.reshape(B, nchunks, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(nchunks, kv_chunk)
    qg = q.reshape(B, Sq, KV, G, dh)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp                                 # (B,ck,KV,dh), (ck,)
        logits = jnp.einsum("bskgd,bckd->bskgc", qg.astype(F32),
                            kb.astype(F32)) * scale       # (B,Sq,KV,G,ck)
        mask = pb[None, None, None, None, :] >= 0
        if causal:
            mask = mask & (pb[None, :] <= q_positions[:, None]
                           )[None, :, None, None, :]
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m - m_new)
        probs = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + jnp.sum(probs, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", probs, vb.astype(F32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), -1e30, F32)
    l0 = jnp.zeros((B, Sq, KV, G), F32)
    acc0 = jnp.zeros((B, Sq, KV, G, dh), F32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, dh)


def attention_block(p, x, *, positions, causal: bool, theta: float,
                    kv_chunk: int = 1024):
    q, k, v = _qkv(p, x, positions, theta)
    out = chunked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk,
                            q_positions=positions[0] if positions.ndim > 1 else positions,
                            kv_positions=positions[0] if positions.ndim > 1 else positions)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])


def attention_decode_stacked(p, x, k_cache, v_cache, pos, *,
                             theta: float):
    """One-token decode against a PER-LAYER (B, S, KV, dh) cache buffer.

    The new k/v token is written with a tiny dynamic_update_slice directly
    into the buffer; the read is the buffer itself (zero-copy).  Earlier
    designs that carried a stacked (periods, ...) cache through a scan and
    sliced periods in/out forced XLA to double-buffer the whole cache
    (measured: +0.5-1 TB of copies per step on granite-34b decode_32k —
    see EXPERIMENTS.md §Perf).

    ``pos`` is a scalar (whole batch at one position — the classic path)
    or a (B,) vector of per-row positions (continuous batching: every slot
    is at a different depth).  The scalar path is left byte-for-byte
    unchanged so existing baked plans keep matching.
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    if per_slot:
        write = jax.vmap(
            lambda c, t, pp: jax.lax.dynamic_update_slice(c, t, (pp, 0, 0)))
        k_cache = write(k_cache, k.astype(k_cache.dtype), pos)
        v_cache = write(v_cache, v.astype(v_cache.dtype), pos)
    else:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    ck, cv = k_cache, v_cache
    Smax, KV = ck.shape[1], ck.shape[2]
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, -1)
    logits = jnp.einsum("bskgd,bckd->bskgc", qg.astype(F32),
                        ck.astype(F32)) / np.sqrt(q.shape[-1])
    if per_slot:
        mask = (jnp.arange(Smax)[None, None, None, None, :]
                <= pos[:, None, None, None, None])
    else:
        mask = jnp.arange(Smax)[None, None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgc,bckd->bskgd", probs, cv.astype(F32))
    out = out.reshape(B, 1, H, -1).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, k_cache, v_cache


def attention_decode(p, x, cache, pos, *, theta: float):
    """One-token decode against a KV cache.

    x: (B, 1, D); cache: {"k","v"): (B, Smax, KV, dh)}; pos: scalar int32.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, positions, theta)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, pos, 0, 0))
    Smax, KV = ck.shape[1], ck.shape[2]
    H = q.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, -1)
    logits = jnp.einsum("bskgd,bckd->bskgc", qg.astype(F32),
                        ck.astype(F32)) / np.sqrt(q.shape[-1])
    mask = jnp.arange(Smax)[None, None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgc,bckd->bskgd", probs, cv.astype(F32))
    out = out.reshape(B, 1, H, -1).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "wg": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wu": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wd": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_block(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    u = jnp.einsum("bsd,df->bsf", x, p["wu"])
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])


# ---------------------------------------------------------------------------
# MoE: naive (dense dispatch), lilac (detected+rewritten), grouped (direct)
# ---------------------------------------------------------------------------

def moe_spec(d_model: int, d_ff: int, n_experts: int) -> Dict[str, ParamSpec]:
    return {
        "router": ParamSpec((d_model, n_experts), ("embed", "expert"),
                            dtype=jnp.float32),
        "wg": ParamSpec((n_experts, d_model, d_ff), ("expert", "embed", "mlp")),
        "wu": ParamSpec((n_experts, d_model, d_ff), ("expert", "embed", "mlp")),
        "wd": ParamSpec((n_experts, d_ff, d_model), ("expert", "mlp", "embed")),
    }


def moe_router(p, x, topk: int):
    """returns (gate (B,S,K) f32 normalized, idx (B,S,K) int32, aux_loss)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, topk)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    E = p["router"].shape[-1]
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=F32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return gate, idx.astype(jnp.int32), aux


def _moe_naive_2d(x, gate, idx, wg, wu, wd):
    """The canonical naive formulation — EXACTLY the form the LiLAC
    detector's moe_ffn matcher targets (see core/detect.py MoeMatcher)."""
    E = wg.shape[0]
    onehot = jax.nn.one_hot(idx, E, dtype=x.dtype)
    combine = jnp.einsum("tke,tk->te", onehot, gate.astype(x.dtype))
    g = jnp.einsum("td,edf->etf", x, wg)
    u = jnp.einsum("td,edf->etf", x, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("etf,efd->etd", h, wd)
    return jnp.einsum("te,etd->td", combine, y)


def _moe_grouped_2d(x, gate, idx, wg, wu, wd, capacity_factor: float = 2.0):
    """Capacity-bucketed grouped dispatch over one token group (T, D):
    compute scales with top-k instead of E. Host/CPU path (the harness
    `jnp.capacity` uses the same algorithm); the distributed path is the
    batched `_moe_grouped_batched` below."""
    out = _moe_grouped_batched(x[None], gate[None], idx[None], wg, wu, wd,
                               capacity_factor=capacity_factor)
    return out[0]


def _wsc(v, pspec, enabled: bool):
    if not enabled or pspec is None:
        return v
    return jax.lax.with_sharding_constraint(v, pspec)


def _moe_grouped_batched(x, gate, idx, wg, wu, wd,
                         capacity_factor: float = 2.0,
                         shard: bool = False,
                         batch_axis="data", model_axis="model"):
    """Batched grouped dispatch: groups = leading dim (sequences or the
    whole decode batch).  Fully GSPMD-shardable: tokens stay on their
    group's shard until the explicitly-constrained (B, E@model, C, D)
    bucket tensor forces the EP dispatch collective; the combine gather
    routes results back.  No vmap, no segment_sum — scatter/gather with a
    leading batch dim plus a top-k reduction.

    x: (B, T, D); gate/idx: (B, T, K). Returns (B, T, D).
    """
    from jax.sharding import PartitionSpec as P

    B, T, D = x.shape
    K = idx.shape[-1]
    E = wg.shape[0]
    C = int(np.ceil(T * K / E * capacity_factor))
    C = max(4, min(C, T * K))
    TK = T * K
    flat_e = idx.reshape(B, TK)                                  # (B, TK)
    flat_g = gate.reshape(B, TK)
    # rank of each (token,k) within its expert queue, per group
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (B, TK, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - onehot,
                              flat_e[..., None], axis=2)[..., 0]  # (B, TK)
    keep = pos < C
    # Unique slots (dropped pairs get unique out-of-bounds slots) keep the
    # scatter a plain parallel scatter — duplicate indices would force XLA
    # into a sort-based distributed scatter (catastrophic collectives).
    oob = E * C + jnp.arange(TK, dtype=jnp.int32)[None, :]
    slot = jnp.where(keep, flat_e * C + pos, oob)                # (B, TK)
    xtok = jnp.repeat(x, K, axis=1)                              # (B, TK, D)
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    xb = jnp.zeros((B, E * C, D), x.dtype).at[bidx, slot].set(
        xtok, mode="drop", unique_indices=True)
    xb = xb.reshape(B, E, C, D)
    xb = _wsc(xb, P(batch_axis, model_axis, None, None), shard)  # EP dispatch
    g = jnp.einsum("becd,edf->becf", xb, wg)
    u = jnp.einsum("becd,edf->becf", xb, wu)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    y = jnp.einsum("becf,efd->becd", h, wd)
    y = _wsc(y, P(batch_axis, model_axis, None, None), shard)
    y = y.reshape(B, E * C, D)
    y = jnp.concatenate([y, jnp.zeros((B, 1, D), y.dtype)], axis=1)
    back = y[bidx, jnp.where(keep, slot, E * C)]                 # (B, TK, D)
    back = jnp.where(keep[..., None], back, 0)
    back = _wsc(back, P(batch_axis, None, None), shard)          # EP combine
    contrib = back.astype(F32) * flat_g[..., None]
    out = jnp.sum(contrib.reshape(B, T, K, D), axis=2)
    return out.astype(x.dtype)


_LILAC_MOE_CACHE: Dict[int, Any] = {}


def _lilac_moe_2d():
    """lilac.compile applied to the naive form — the paper's compiler pass
    running inside the LM framework. Cached module-level (detection runs
    once per shape signature)."""
    if 0 not in _LILAC_MOE_CACHE:
        from repro import lilac
        _LILAC_MOE_CACHE[0] = lilac.compile(_moe_naive_2d)
    return _LILAC_MOE_CACHE[0]


def _moe_grouped_shardmap(x, gate, idx, wg, wu, wd, *,
                          capacity_factor: float,
                          batch_axes=("data",), model_axis="model",
                          model_size: int = 1,
                          combine_bf16: bool = False):
    """Expert-parallel grouped MoE via shard_map (Megatron-style EP).

    Tokens are batch-sharded; experts are model-sharded.  Every model shard
    dispatches its (replicated-over-model) local tokens into buckets for
    ITS OWN E/m experts only — dispatch needs NO collective.  Expert FFNs
    run local; the combine is one psum over the model axis per layer (the
    same cost class as a Megatron TP all-reduce).  Expert counts that
    don't divide the model axis are zero-padded (granite-moe: 40 -> 48);
    padded experts are never routed to, their buckets stay empty.
    """
    from jax.sharding import PartitionSpec as P

    B, T, D = x.shape
    K = idx.shape[-1]
    E = wg.shape[0]
    E_pad = ((E + model_size - 1) // model_size) * model_size
    if E_pad != E:
        padw = ((0, E_pad - E), (0, 0), (0, 0))
        wg, wu, wd = (jnp.pad(w, padw) for w in (wg, wu, wd))
    E_loc = E_pad // model_size
    C = int(np.ceil(T * K / E * capacity_factor))
    C = max(4, min(C, T * K))

    def local_fn(x, gate, idx, wg, wu, wd):
        # x: (B_loc, T, D) — replicated over model; wg: (E_loc, D, F)
        Bl = x.shape[0]
        TK = T * K
        eix = jax.lax.axis_index(model_axis)
        e0 = eix * E_loc
        flat_e = idx.reshape(Bl, TK) - e0                 # local expert ids
        flat_g = gate.reshape(Bl, TK)
        valid = (flat_e >= 0) & (flat_e < E_loc)
        e_cl = jnp.clip(flat_e, 0, E_loc - 1)
        onehot = jax.nn.one_hot(e_cl, E_loc, dtype=jnp.int32) \
            * valid[..., None].astype(jnp.int32)
        pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=1) - onehot,
                                  e_cl[..., None], axis=2)[..., 0]
        keep = valid & (pos < C)
        oob = E_loc * C + jnp.arange(TK, dtype=jnp.int32)[None, :]
        slot = jnp.where(keep, e_cl * C + pos, oob)
        xtok = jnp.repeat(x, K, axis=1)                   # (B_loc, TK, D)
        bidx = jnp.arange(Bl, dtype=jnp.int32)[:, None]
        xb = jnp.zeros((Bl, E_loc * C, D), x.dtype).at[bidx, slot].set(
            xtok, mode="drop", unique_indices=True)
        xb = xb.reshape(Bl, E_loc, C, D)
        g = jnp.einsum("becd,edf->becf", xb, wg)
        u = jnp.einsum("becd,edf->becf", xb, wu)
        h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
        y = jnp.einsum("becf,efd->becd", h, wd).reshape(Bl, E_loc * C, D)
        y = jnp.concatenate([y, jnp.zeros((Bl, 1, D), y.dtype)], axis=1)
        back = y[bidx, jnp.where(keep, slot, E_loc * C)]  # (B_loc, TK, D)
        back = jnp.where(keep[..., None], back, 0)
        partial = jnp.sum((back.astype(F32)
                           * flat_g[..., None]).reshape(Bl, T, K, D), axis=2)
        if combine_bf16:
            partial = partial.astype(x.dtype)   # halve the EP psum bytes
        return jax.lax.psum(partial, model_axis).astype(x.dtype)

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
              *([None] * 2))
    wspec = P(model_axis, None, None)
    from repro import compat
    return compat.shard_map(
        local_fn,
        in_specs=(bspec, bspec, bspec, wspec, wspec, wspec),
        out_specs=bspec,
    )(x, gate, idx, wg, wu, wd)


def moe_block(p, x, *, topk: int, impl: str = "grouped",
              capacity_factor: float = 2.0, shard_ctx=None):
    """x: (B, S, D). Groups = sequences (train/prefill) — dispatch is
    per-sequence so no cross-batch communication is needed to form buckets;
    decode callers pass S=1 groups of the whole batch instead.

    shard_ctx: None (single host) or dict(batch_axes, model_axis,
    model_size) — selects the shard_map EP path."""
    B, S, D = x.shape
    gate, idx, aux = moe_router(p, x, topk)
    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    if impl == "naive":
        fn = functools.partial(_moe_naive_2d, wg=wg, wu=wu, wd=wd)
        out = jax.vmap(lambda xx, gg, ii: fn(xx, gg, ii))(x, gate, idx)
    elif impl == "lilac":
        lf = _lilac_moe_2d()
        out = jax.vmap(lambda xx, gg, ii: lf(xx, gg, ii, wg, wu, wd))(
            x, gate, idx)
    elif impl == "grouped" and shard_ctx:
        out = _moe_grouped_shardmap(x, gate, idx, wg, wu, wd,
                                    capacity_factor=capacity_factor,
                                    **shard_ctx)
    elif impl == "grouped":
        out = _moe_grouped_batched(x, gate, idx, wg, wu, wd,
                                   capacity_factor=capacity_factor,
                                   shard=False)
    elif impl == "grouped_flat":
        # one global group (decode): flatten groups into a single bucket set
        out = _moe_grouped_batched(x.reshape(1, B * S, D),
                                   gate.reshape(1, B * S, -1),
                                   idx.reshape(1, B * S, -1), wg, wu, wd,
                                   capacity_factor=capacity_factor,
                                   shard=False)
        out = out.reshape(B, S, D)
    elif impl == "naive_flat":
        # one flat naive call over all B*S tokens — the exact 2-D dense
        # dispatch the LiLAC detector matches, so compiling a decode step
        # that uses this impl exposes the MoE to detect/tune/bake
        out = _moe_naive_2d(x.reshape(B * S, D), gate.reshape(B * S, -1),
                            idx.reshape(B * S, -1), wg, wu, wd)
        out = out.reshape(B, S, D)
    else:
        raise ValueError(impl)
    return out, aux
