"""Block assembly and the generic LM: spec building, scan-over-layers
forward, chunked LM loss, prefill and decode.

Layer stacking: the model is a scan over "periods".  A period is the
repeating unit of the architecture — 1 block for homogeneous stacks, 8
blocks for Jamba (1 attention + 7 mamba, MoE on odd indices).  Period
parameters are stacked on a leading "layers" axis, so the HLO contains one
period body regardless of depth (compile time and code size stay flat from
olmo-1b to mistral-large-123b).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.spec import ParamSpec

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Pattern: which blocks make up one period
# ---------------------------------------------------------------------------

def arch_pattern(cfg) -> List[Tuple[str, str]]:
    """[(mixer_kind, ffn_kind)] per layer within one period."""
    if cfg.family == "ssm":                       # rwkv6
        return [("rwkv", "channelmix")]
    if cfg.family == "hybrid":                    # jamba: attn @ idx 4 of 8
        period = cfg.attn_layer_period or 8
        out = []
        for i in range(period):
            mixer = "attn" if i == (cfg.attn_layer_offset or 4) else "mamba"
            ffn = "moe" if (cfg.moe_experts and i % (cfg.moe_layer_period or 2)
                            == 1) else "mlp"
            out.append((mixer, ffn))
        return out
    ffn = "moe" if cfg.moe_experts else "mlp"
    return [("attn", ffn)]


def n_periods(cfg) -> int:
    period = len(arch_pattern(cfg))
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period


# ---------------------------------------------------------------------------
# Spec building
# ---------------------------------------------------------------------------

def _norm_spec(cfg):
    s, _ = L.make_norm(cfg.norm, cfg.d_model)
    return s


def block_spec(cfg, mixer: str, ffn: str) -> Dict[str, Any]:
    d = cfg.d_model
    hd = cfg.head_dim or d // cfg.n_heads
    spec: Dict[str, Any] = {"ln1": _norm_spec(cfg)}
    if mixer == "attn":
        spec["attn"] = L.attention_spec(d, cfg.n_heads, cfg.n_kv_heads, hd)
    elif mixer == "mamba":
        spec["mamba"] = M.mamba_spec(d, d_state=cfg.d_state)
    elif mixer == "rwkv":
        spec["tm"] = R.timemix_spec(d, cfg.n_heads)
    else:
        raise ValueError(mixer)
    spec["ln2"] = _norm_spec(cfg)
    if ffn == "mlp":
        spec["mlp"] = L.mlp_spec(d, cfg.d_ff)
    elif ffn == "moe":
        spec["moe"] = L.moe_spec(d, cfg.d_ff, cfg.moe_experts)
    elif ffn == "channelmix":
        spec["cm"] = R.channelmix_spec(d, cfg.d_ff)
    else:
        raise ValueError(ffn)
    return spec


def _stack_spec(spec_tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            dtype=s.dtype, init=s.init, scale=s.scale),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_spec(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    pattern = arch_pattern(cfg)
    period_spec = {f"b{i}": block_spec(cfg, mx, ff)
                   for i, (mx, ff) in enumerate(pattern)}
    spec: Dict[str, Any] = {
        "blocks": _stack_spec(period_spec, n_periods(cfg)),
        "final_norm": _norm_spec(cfg),
        "unembed": ParamSpec((d, cfg.vocab), ("embed", "vocab")),
    }
    if cfg.frontend == "none":
        spec["embed"] = ParamSpec((cfg.vocab, d), ("vocab", "embed"))
    # stub frontends feed precomputed embeddings; no embed table needed for
    # the fwd path, but decode still consumes tokens -> keep a table for vlm
    elif cfg.family == "vlm":
        spec["embed"] = ParamSpec((cfg.vocab, d), ("vocab", "embed"))
    return spec


# ---------------------------------------------------------------------------
# Block application (train / prefill share code; decode is separate)
# ---------------------------------------------------------------------------

def _norm_apply(cfg, p, x):
    _, fn = L.make_norm(cfg.norm, cfg.d_model)
    return fn(p, x)


def _axis_sizes(cfg) -> Dict[str, int]:
    return dict(cfg.mesh_axis_sizes)


def _constrain(cfg, spec_tree, params):
    """Compute-time weight resolution (no-op unless cfg.spmd_constraints).

    Weights whose storage sharding uses the FSDP ("data") axis are gathered
    with an explicit shard_map all_gather — its transpose is a
    psum_scatter, so each layer's weight gradient is reduce-scattered over
    the data axis (exact ZeRO-3 semantics, in the weight dtype).  Leaving
    this to with_sharding_constraint lets the scan-backward accumulator
    round-trip full f32 gradients through all-gathers instead.
    """
    if not cfg.spmd_constraints:
        return params
    from jax.sharding import PartitionSpec as P
    from repro.models import spec as S
    sizes = _axis_sizes(cfg)
    storage_rules = S.MULTI_POD_RULES if "pod" in sizes else S.SINGLE_POD_RULES

    def resolve(spec_leaf, value):
        storage = S.spec_to_pspec_sizes(spec_leaf, sizes, storage_rules)
        compute = S.spec_to_pspec_sizes(spec_leaf, sizes, S.COMPUTE_RULES)
        fsdp_axes = [i for i, (s, c) in enumerate(zip(storage, compute))
                     if s == "data" and c is None]
        if not fsdp_axes or sizes.get("data", 1) == 1:
            return jax.lax.with_sharding_constraint(value, compute)
        ax = fsdp_axes[0]

        def local(w):
            return jax.lax.all_gather(w, "data", axis=ax, tiled=True)

        from repro import compat
        return compat.shard_map(local, in_specs=storage, out_specs=compute,
                                check_vma=False)(value)

    return jax.tree.map(
        resolve, spec_tree, params,
        is_leaf=lambda x: isinstance(x, S.ParamSpec))


def _constrain_leaf(cfg, spec_leaf, value):
    if not cfg.spmd_constraints:
        return value
    return _constrain(cfg, spec_leaf, value)


def _moe_shard_ctx(cfg):
    """shard_map EP context for MoE layers (None on single host)."""
    if not cfg.spmd_constraints:
        return None
    sizes = _axis_sizes(cfg)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    return {"batch_axes": batch_axes, "model_axis": "model",
            "model_size": sizes.get("model", 1),
            "combine_bf16": cfg.moe_combine_bf16}


def _use_sp(cfg) -> bool:
    """Sequence-parallel activation carries: shard the (B, S, D) residual
    stream over the model axis between layers.  Essential for deep/wide
    models (88 x 1.6 GB carries would blow HBM on mistral-large) and it
    turns TP all-reduces into all-gather/reduce-scatter pairs.  Disabled
    for recurrent mixers (rwkv/mamba scan over a sharded time axis would
    force per-step collectives)."""
    return (cfg.spmd_constraints
            and cfg.seq_parallel
            and cfg.family not in ("ssm", "hybrid")
            and dict(cfg.mesh_axis_sizes).get("model", 1) > 1)


def _sp_constrain(cfg, x, batch_ok: bool = True):
    if not _use_sp(cfg):
        return x
    sizes = _axis_sizes(cfg)
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    b = (batch_axes if len(batch_axes) > 1 else batch_axes[0]) \
        if (batch_ok and batch_axes) else None
    if x.shape[1] % sizes.get("model", 1) != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(b, "model", None))


def apply_block(cfg, bp, x, *, mixer: str, ffn: str, positions,
                moe_impl: Optional[str] = None):
    """Full-sequence block application. Returns (x, aux_loss, cache_entry)."""
    cache_entry = {}
    h = _norm_apply(cfg, bp["ln1"], x)
    if mixer == "attn":
        q, k, v = L._qkv(bp["attn"], h, positions, cfg.rope_theta)
        out = L.chunked_attention(
            q, k, v, causal=cfg.causal, kv_chunk=cfg.kv_chunk,
            q_positions=positions[0] if positions.ndim > 1 else positions,
            kv_positions=positions[0] if positions.ndim > 1 else positions)
        out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype),
                         bp["attn"]["wo"])
        cache_entry = {"k": k.astype(x.dtype), "v": v.astype(x.dtype)}
    elif mixer == "mamba":
        B = x.shape[0]
        di = bp["mamba"]["in_proj"].shape[1] // 2
        state = (jnp.zeros((B, di, cfg.d_state), F32),
                 jnp.zeros((B, M.CONV_K - 1, di), F32))
        out, state = M.mamba_block(bp["mamba"], h, state, cfg.d_state)
        cache_entry = {"ssm": state[0], "conv": state[1]}
    elif mixer == "rwkv":
        B = x.shape[0]
        hd = cfg.d_model // cfg.n_heads
        state = jnp.zeros((B, cfg.n_heads, hd, hd), F32)
        out, state, last_x = R.timemix(bp["tm"], h, state, cfg.n_heads)
        cache_entry = {"s": state, "last_tm": last_x}
    else:
        raise ValueError(mixer)
    x = x + out
    aux = jnp.zeros((), F32)
    h = _norm_apply(cfg, bp["ln2"], x)
    if ffn == "mlp":
        x = x + L.mlp_block(bp["mlp"], h)
    elif ffn == "moe":
        out, aux = L.moe_block(bp["moe"], h, topk=cfg.moe_topk,
                               impl=moe_impl or cfg.moe_impl,
                               capacity_factor=cfg.capacity_factor,
                               shard_ctx=_moe_shard_ctx(cfg))
        x = x + out
    elif ffn == "channelmix":
        out, last_cm = R.channelmix(bp["cm"], h)
        x = x + out
        cache_entry["last_cm"] = last_cm
    return x, aux, cache_entry


def forward(cfg, params, inputs: Dict[str, Any], *, collect_cache: bool = False):
    """Full-sequence forward (training / prefill).

    inputs: {"tokens": (B,S) int32} or {"embeds": (B,S,D)} for stub
    frontends; optional "positions" (B,S).
    Returns (x_final (B,S,D), aux_loss, cache or None).
    """
    pattern = arch_pattern(cfg)
    if "embeds" in inputs:
        x = inputs["embeds"].astype(cfg.param_dtype)
    else:
        embed = _constrain_leaf(
            cfg, ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
            params["embed"])
        x = embed[inputs["tokens"]]
    B, S = x.shape[0], x.shape[1]
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    period_specs = {f"b{i}": block_spec(cfg, mx, ff)
                    for i, (mx, ff) in enumerate(pattern)}

    batch_ok = inputs.get("_batch_shardable", True)

    def period_fn(carry, period_params):
        x, aux = carry
        caches = {}
        x = _sp_constrain(cfg, x, batch_ok)
        for i, (mx, ff) in enumerate(pattern):
            bp = _constrain(cfg, period_specs[f"b{i}"], period_params[f"b{i}"])
            x, a, ce = apply_block(cfg, bp, x,
                                   mixer=mx, ffn=ff, positions=positions)
            aux = aux + a
            if collect_cache:
                caches[f"b{i}"] = ce
        x = _sp_constrain(cfg, x, batch_ok)
        return (x, aux), caches if collect_cache else None

    body = period_fn
    if cfg.remat:
        body = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.save_only_these_names())
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), F32)),
                                    params["blocks"])
    x = _norm_apply(cfg, params["final_norm"], x)
    return x, aux, caches


# ---------------------------------------------------------------------------
# Chunked LM loss (vocab logits never fully materialized)
# ---------------------------------------------------------------------------

def lm_loss(cfg, params, x_final, labels, *, chunk: int = 512):
    """Cross-entropy over the vocab, computed in sequence chunks so the
    (B, S, V) logits tensor never exists; mask = labels >= 0."""
    B, S, D = x_final.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x_final = jnp.pad(x_final, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nch = x_final.shape[1] // chunk
    xc = x_final.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nch, chunk).transpose(1, 0, 2)
    unembed = _constrain_leaf(
        cfg, ParamSpec((D, cfg.vocab), ("embed", "vocab")), params["unembed"])

    def body(carry, inp):
        tot, cnt = carry
        xck, lck = inp
        logits = jnp.einsum("bsd,dv->bsv", xck.astype(F32),
                            unembed.astype(F32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lck, 0)[..., None], axis=-1)[..., 0]
        mask = (lck >= 0).astype(F32)
        tot = tot + jnp.sum((lse - picked) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), F32), jnp.zeros((), F32)),
                                 (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_logits_last(cfg, params, x_final):
    """Logits of the last position only (prefill -> first generated token)."""
    xl = x_final[:, -1, :]
    return jnp.einsum("bd,dv->bv", xl.astype(F32),
                      params["unembed"].astype(F32))


# ---------------------------------------------------------------------------
# Decode (single new token, cache carried)
# ---------------------------------------------------------------------------

def init_cache(cfg, B: int, max_seq: int) -> Dict[str, Any]:
    """Per-layer-instance cache: {"p{j}": {"b{i}": entries}} with NO
    stacked periods dim — separate buffers alias cleanly under donation
    (stacked scan-carried caches get double-buffered; §Perf)."""
    pattern = arch_pattern(cfg)
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    di = 2 * cfg.d_model
    cache: Dict[str, Any] = {}
    for j in range(n_periods(cfg)):
        period_cache = {}
        for i, (mx, ff) in enumerate(pattern):
            ce: Dict[str, Any] = {}
            if mx == "attn":
                ce = {"k": jnp.zeros((B, max_seq, cfg.n_kv_heads, hd),
                                     cfg.cache_dtype),
                      "v": jnp.zeros((B, max_seq, cfg.n_kv_heads, hd),
                                     cfg.cache_dtype)}
            elif mx == "mamba":
                ce = {"ssm": jnp.zeros((B, di, cfg.d_state), F32),
                      "conv": jnp.zeros((B, M.CONV_K - 1, di), F32)}
            elif mx == "rwkv":
                ce = {"s": jnp.zeros((B, cfg.n_heads, hd, hd), F32),
                      "last_tm": jnp.zeros((B, cfg.d_model),
                                           cfg.param_dtype)}
            if ff == "channelmix":
                ce["last_cm"] = jnp.zeros((B, cfg.d_model), cfg.param_dtype)
            period_cache[f"b{i}"] = ce
        cache[f"p{j}"] = period_cache
    return cache


def decode_block(cfg, bp, x, ce, pos, *, mixer: str, ffn: str):
    """One decode block against its own per-layer cache entry."""
    h = _norm_apply(cfg, bp["ln1"], x)
    new_ce = dict(ce)
    if mixer == "attn":
        out, kc, vc = L.attention_decode_stacked(
            bp["attn"], h, ce["k"], ce["v"], pos, theta=cfg.rope_theta)
        new_ce["k"], new_ce["v"] = kc, vc
    elif mixer == "mamba":
        out, (ssm, conv) = M.mamba_block(
            bp["mamba"], h, (ce["ssm"], ce["conv"]), cfg.d_state)
        new_ce["ssm"], new_ce["conv"] = ssm, conv
    elif mixer == "rwkv":
        out, s, last = R.timemix(bp["tm"], h, ce["s"], cfg.n_heads,
                                 x_prev=ce["last_tm"])
        new_ce["s"], new_ce["last_tm"] = s, last.astype(ce["last_tm"].dtype)
    x = x + out
    h = _norm_apply(cfg, bp["ln2"], x)
    if ffn == "mlp":
        x = x + L.mlp_block(bp["mlp"], h)
    elif ffn == "moe":
        out, _ = L.moe_block(bp["moe"], h, topk=cfg.moe_topk,
                             impl=cfg.moe_decode_impl,
                             capacity_factor=cfg.capacity_factor)
        x = x + out
    elif ffn == "channelmix":
        out, last = R.channelmix(bp["cm"], h, x_prev=ce["last_cm"])
        x = x + out
        new_ce["last_cm"] = last.astype(ce["last_cm"].dtype)
    return x, new_ce


def decode_step(cfg, params, cache, tokens, pos):
    """tokens: (B, 1) int32; pos: scalar int32 (whole batch at one write
    position) or (B,) int32 (per-slot positions — continuous batching).
    Returns (logits (B, V), new_cache).

    The period loop is UNROLLED (static Python loop over statically-sliced
    stacked params): each per-layer cache buffer gets exactly one tiny
    in-place dynamic_update_slice, which XLA aliases with the donated
    input.  Scanning with the cache as carry instead double-buffers the
    whole cache each step (§Perf: ~1 TB/step of copies on 32k decode)."""
    pattern = arch_pattern(cfg)
    embed = _constrain_leaf(
        cfg, ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed")),
        params["embed"])
    x = embed[tokens]
    period_specs = {f"b{i}": block_spec(cfg, mx, ff)
                    for i, (mx, ff) in enumerate(pattern)}

    new_cache: Dict[str, Any] = {}
    for j in range(n_periods(cfg)):
        period_params = jax.tree.map(lambda a: a[j], params["blocks"])
        new_period = {}
        for i, (mx, ff) in enumerate(pattern):
            bp = _constrain(cfg, period_specs[f"b{i}"], period_params[f"b{i}"])
            x, new_period[f"b{i}"] = decode_block(
                cfg, bp, x, cache[f"p{j}"][f"b{i}"], pos, mixer=mx, ffn=ff)
        new_cache[f"p{j}"] = new_period
    x = _norm_apply(cfg, params["final_norm"], x)
    unembed = _constrain_leaf(
        cfg, ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab")),
        params["unembed"])
    logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(F32),
                        unembed.astype(F32))
    return logits, new_cache
