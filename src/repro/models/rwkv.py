"""RWKV6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

Faithful to arXiv:2404.05892's computation structure (token-shift lerp,
per-channel data-dependent decay w_t = exp(-exp(.)), per-head matrix-valued
state S += k^T v with diagonal decay, bonus term u) with one simplification
recorded in DESIGN.md: the low-rank (LoRA-style) parameterizations of the
mix/decay projections are replaced by single matrices — same dataflow and
state recurrence, fewer small einsums.

State per head: (dh, dh) — O(d_model * head_dim) per layer total, which is
why long_500k decoding is trivially feasible for this arch.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec

F32 = jnp.float32


def timemix_spec(d: int, n_heads: int) -> Dict[str, ParamSpec]:
    return {
        "mix_r": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "mix_k": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "mix_v": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "mix_w": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "mix_g": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "ww": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "w_bias": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "u": ParamSpec((d,), (None,), init="zeros", dtype=F32),  # bonus
        "ln_scale": ParamSpec((d,), (None,), init="ones", dtype=F32),
    }


def channelmix_spec(d: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "mix_k": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "mix_r": ParamSpec((d,), (None,), init="zeros", dtype=F32),
        "wk": ParamSpec((d, d_ff), ("embed", "mlp")),
        "wv": ParamSpec((d_ff, d), ("mlp", "embed")),
        "wr": ParamSpec((d, d), ("embed", "embed")),
    }


def _token_shift(x, x_prev_last=None):
    """shift sequence right by one; x_prev_last is the carry for decode."""
    if x_prev_last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = x_prev_last[:, None, :]
    return prev


def _lerp(x, prev, mix):
    return x + (prev - x) * mix.astype(x.dtype)


def timemix(p, x, state, n_heads: int, x_prev=None):
    """x: (B, S, D); state: (B, H, dh, dh) f32. Returns (out, new_state,
    last_x) — scan over time (the sequential recurrence is the baseline;
    chunked parallel scan is a §Perf lever)."""
    B, S, D = x.shape
    dh = D // n_heads
    prev = _token_shift(x, x_prev)
    r = jnp.einsum("bsd,de->bse", _lerp(x, prev, p["mix_r"]), p["wr"])
    k = jnp.einsum("bsd,de->bse", _lerp(x, prev, p["mix_k"]), p["wk"])
    v = jnp.einsum("bsd,de->bse", _lerp(x, prev, p["mix_v"]), p["wv"])
    g = jnp.einsum("bsd,de->bse", _lerp(x, prev, p["mix_g"]), p["wg"])
    wdec = jnp.einsum("bsd,de->bse", _lerp(x, prev, p["mix_w"]), p["ww"])
    w = jnp.exp(-jnp.exp(wdec.astype(F32) + p["w_bias"]))      # (B,S,D) in (0,1)

    rh = r.reshape(B, S, n_heads, dh).astype(F32)
    kh = k.reshape(B, S, n_heads, dh).astype(F32)
    vh = v.reshape(B, S, n_heads, dh).astype(F32)
    wh = w.reshape(B, S, n_heads, dh)
    uh = p["u"].reshape(n_heads, dh)

    def step(s, inp):
        rt, kt, vt, wt = inp                       # (B,H,dh) each
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,dh,dh)
        out = jnp.einsum("bhi,bhij->bhj", rt, s + uh[..., None] * kv)
        s_new = wt[..., None] * s + kv
        return s_new, out

    from repro.models.layers import chunked_scan
    xs = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
          vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    state, outs = chunked_scan(step, state, xs)
    out = outs.transpose(1, 0, 2, 3).reshape(B, S, D)
    # per-head group norm
    oh = out.reshape(B, S, n_heads, dh)
    mu = jnp.mean(oh, axis=-1, keepdims=True)
    var = jnp.var(oh, axis=-1, keepdims=True)
    oh = (oh - mu) * jax.lax.rsqrt(var + 64e-5)
    out = (oh.reshape(B, S, D) * p["ln_scale"]).astype(x.dtype)
    out = out * jax.nn.silu(g.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out, p["wo"])
    return out, state, x[:, -1, :]


def channelmix(p, x, x_prev=None):
    prev = _token_shift(x, x_prev)
    xk = _lerp(x, prev, p["mix_k"])
    xr = _lerp(x, prev, p["mix_r"])
    k = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]).astype(F32))
    return (r.astype(x.dtype) * kv), x[:, -1, :]
