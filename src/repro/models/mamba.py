"""Mamba selective SSM block (Jamba's recurrent layer, arXiv:2403.19887).

Structure: in_proj -> (x, z); causal depthwise conv (k=4) + SiLU on x;
data-dependent (dt, B, C); diagonal selective scan
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D x_t
out = (y * SiLU(z)) @ out_proj.

State: (B, d_inner, N) + conv tail (B, 3, d_inner) -> O(1) per token, which
is what makes jamba's long_500k decode shape feasible.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec

F32 = jnp.float32
CONV_K = 4


def mamba_spec(d: int, expand: int = 2, d_state: int = 16,
               dt_rank: int = 0) -> Dict[str, ParamSpec]:
    di = expand * d
    dt_rank = dt_rank or max(16, d // 16)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((CONV_K, di), (None, "mlp"), dtype=F32),
        "conv_b": ParamSpec((di,), ("mlp",), init="zeros", dtype=F32),
        "wx_dbc": ParamSpec((di, dt_rank + 2 * d_state), ("mlp", None)),
        "dt_proj": ParamSpec((dt_rank, di), (None, "mlp"), dtype=F32),
        "dt_bias": ParamSpec((di,), ("mlp",), init="zeros", dtype=F32),
        "a_log": ParamSpec((di, d_state), ("mlp", None), init="zeros",
                           dtype=F32),
        "d_skip": ParamSpec((di,), ("mlp",), init="ones", dtype=F32),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, b, tail=None):
    """x: (B, S, di); w: (K, di) depthwise. tail: (B, K-1, di) carry."""
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(CONV_K))
    new_tail = xp[:, -(CONV_K - 1):, :] if CONV_K > 1 else None
    return out + b.astype(x.dtype), new_tail


def mamba_block(p, x, state: Tuple, d_state: int = 16):
    """x: (B,S,D); state = (ssm (B,di,N) f32, conv_tail (B,K-1,di) f32)."""
    B, S, D = x.shape
    ssm, conv_tail = state
    di = p["in_proj"].shape[1] // 2
    dt_rank = p["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]
    xi, new_tail = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_tail)
    xi = jax.nn.silu(xi.astype(F32)).astype(x.dtype)
    dbc = jnp.einsum("bse,ef->bsf", xi, p["wx_dbc"]).astype(F32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dbc[..., :dt_rank], p["dt_proj"])
        + p["dt_bias"])                                         # (B,S,di)
    Bm = dbc[..., dt_rank:dt_rank + d_state]                    # (B,S,N)
    Cm = dbc[..., dt_rank + d_state:]                           # (B,S,N)
    A = -jnp.exp(p["a_log"])                                    # (di,N)
    xf = xi.astype(F32)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp          # (B,di),(B,N),(B,N),(B,di)
        da = jnp.exp(dt_t[..., None] * A)                       # (B,di,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("ben,bn->be", h, c_t)
        return h, y

    from repro.models.layers import chunked_scan
    xs = (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), xf.transpose(1, 0, 2))
    ssm, ys = chunked_scan(step, ssm, xs)
    y = ys.transpose(1, 0, 2) + xf * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (ssm, new_tail if new_tail is not None
                 else jnp.zeros((B, CONV_K - 1, di), F32))
