"""Mamba selective SSM block (Jamba's recurrent layer, arXiv:2403.19887).

Structure: in_proj -> (x, z); causal depthwise conv (k=4) + SiLU on x;
data-dependent (dt, B, C); diagonal selective scan
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t ;  y_t = C_t . h_t + D x_t
out = (y * SiLU(z)) @ out_proj.

State: (B, d_inner, N) + conv tail (B, 3, d_inner) -> O(1) per token, which
is what makes jamba's long_500k decode shape feasible.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.spec import ParamSpec

F32 = jnp.float32
CONV_K = 4


def mamba_spec(d: int, expand: int = 2, d_state: int = 16,
               dt_rank: int = 0) -> Dict[str, ParamSpec]:
    di = expand * d
    dt_rank = dt_rank or max(16, d // 16)
    # out_proj is a residual-stream writer gated by y * SiLU(z): at unit
    # init scale the block amplifies the residual ~4x per layer (16 layers
    # -> |x| ~ 1e9 in fp32, where prefill-vs-decode program-shape
    # reassociation noise flips predictions).  The GPT-2-style down-scaled
    # residual projection keeps the stream O(10) at init.
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamSpec((CONV_K, di), (None, "mlp"), dtype=F32),
        "conv_b": ParamSpec((di,), ("mlp",), init="zeros", dtype=F32),
        # small init scale keeps the data-dependent (dt, B, C) projections
        # near the reference Mamba operating point: dt = softplus(~0 +
        # dt_bias) ~ dt_init instead of the softplus linear regime (dt~20,
        # which drives |h| to ~1e5 and makes the C.h contraction cancel
        # catastrophically).
        "wx_dbc": ParamSpec((di, dt_rank + 2 * d_state), ("mlp", None),
                            scale=0.1),
        # Jamba §3 stabilization (HF JambaMambaMixer dt/b/c_layernorm):
        # RMSNorm the data-dependent (dt, B, C) before the scan.  Without
        # it, near-zero-dt channels act as integrators with ~1/dt gain and
        # the state reaches 1e4..1e6, where the C.h contraction amplifies
        # fp32 reassociation noise into prediction flips.
        "dt_norm": ParamSpec((dt_rank,), (None,), init="ones", dtype=F32),
        "b_norm": ParamSpec((d_state,), (None,), init="ones", dtype=F32),
        "c_norm": ParamSpec((d_state,), (None,), init="ones", dtype=F32),
        "dt_proj": ParamSpec((dt_rank, di), (None, "mlp"), dtype=F32),
        "dt_bias": ParamSpec((di,), ("mlp",), init="dt_bias", scale=0.01,
                             dtype=F32),
        "a_log": ParamSpec((di, d_state), ("mlp", None), init="arange_log",
                           dtype=F32),
        "d_skip": ParamSpec((di,), ("mlp",), init="ones", dtype=F32),
        "out_proj": ParamSpec((di, d), ("mlp", "embed"), scale=0.125),
    }


def _rms(x, eps: float = 1e-6):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps)


def _causal_conv(x, w, b, tail=None):
    """x: (B, S, di); w: (K, di) depthwise. tail: (B, K-1, di) carry."""
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(CONV_K))
    new_tail = xp[:, -(CONV_K - 1):, :] if CONV_K > 1 else None
    return out + b.astype(x.dtype), new_tail


def mamba_block(p, x, state: Tuple, d_state: int = 16):
    """x: (B,S,D); state = (ssm (B,di,N) f32, conv_tail (B,K-1,di) f32)."""
    B, S, D = x.shape
    ssm, conv_tail = state
    di = p["in_proj"].shape[1] // 2
    dt_rank = p["dt_proj"].shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :di], xz[..., di:]
    xi, new_tail = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_tail)
    xi = jax.nn.silu(xi.astype(F32)).astype(x.dtype)
    dbc = jnp.einsum("bse,ef->bsf", xi, p["wx_dbc"]).astype(F32)
    dt_in = _rms(dbc[..., :dt_rank]) * p["dt_norm"]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, p["dt_proj"])
        + p["dt_bias"])                                         # (B,S,di)
    Bm = _rms(dbc[..., dt_rank:dt_rank + d_state]) * p["b_norm"]   # (B,S,N)
    Cm = _rms(dbc[..., dt_rank + d_state:]) * p["c_norm"]          # (B,S,N)
    A = -jnp.exp(p["a_log"])                                    # (di,N)
    xf = xi.astype(F32)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp          # (B,di),(B,N),(B,N),(B,di)
        da = jnp.exp(dt_t[..., None] * A)                       # (B,di,N)
        h = da * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("ben,bn->be", h, c_t)
        return h, y

    from repro.models.layers import chunked_scan
    xs = (dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), xf.transpose(1, 0, 2))
    ssm, ys = chunked_scan(step, ssm, xs)
    y = ys.transpose(1, 0, 2) + xf * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(F32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (ssm, new_tail if new_tail is not None
                 else jnp.zeros((B, CONV_K - 1, di), F32))
