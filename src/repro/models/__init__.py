"""Model zoo: every assigned architecture family, built functionally.

spec.py        ParamSpec trees: shapes + logical axes -> init / abstract /
               NamedSharding (the MaxText-style logical-axis system)
layers.py      norms, rotary, GQA attention (chunked online-softmax),
               SwiGLU MLP, MoE (naive / lilac-rewritten / grouped)
rwkv.py        RWKV6 (Finch) time-mix with data-dependent decay
mamba.py       Mamba selective SSM (Jamba's recurrent block)
transformer.py block assembly, scan-over-layers, train/prefill/decode
factory.py     build(config) -> Model
"""
from repro.models.factory import build_model  # noqa: F401
