"""Group-aligned ragged grouped matmul (gmm) — the MoE expert hot loop.

This is the TPU-native replacement for the naive dense-dispatch MoE that
the LiLAC pass detects: tokens are sorted by expert, each expert's group is
padded to a row-tile multiple so every (tm, dk) x-tile belongs to exactly
one expert, and the per-tile expert id is scalar-prefetched so the
BlockSpec index_map can steer the weight DMA (indirect addressing on the
tile->expert table, the same mechanism as bsr_spmm's block indices).

FLOPs: sum_e ceil(c_e/tm)*tm * D * F  ~=  T*K*D*F  (vs naive E*T*D*F) —
exact results, no token drops (unlike capacity-factor dispatch).

Grid: (m_tiles, n_tiles, k_tiles), k fastest -> f32 accumulation in the
output VMEM block across k steps (revisiting pattern).

Schedule parameters (``tune`` clauses in the HARNESS block): ``tm``
(token-tile rows — also the group-alignment quantum), ``fn``/``dk``
(output / contraction tile preferences), and ``dimension_semantics`` for
the m/n grid dimensions (k always 'arbitrary': it revisits the output
block).  A constraint bounds the per-step VMEM working set.

VMEM per step (tm=dk=fn=128, bf16 in / f32 acc):
    x (128x128x2) + w (128x128x2) + out (128x128x4) = 128 KiB.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import compiler_params


def _gmm_kernel(tile_expert_ref, xs_ref, w_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = xs_ref[...]                 # (tm, dk)
    w = w_ref[0]                    # (dk, fn)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "fn", "dk",
                                             "dimension_semantics",
                                             "interpret"))
def gmm_pallas(xs: jax.Array,           # (Tp, D) group-aligned rows
               w: jax.Array,            # (E, D, F)
               tile_expert: jax.Array,  # (Tp//tm,) int32
               tm: int = 128, fn: int = 128, dk: int = 128,
               dimension_semantics: Optional[Tuple[str, ...]] = None,
               interpret: bool = False) -> jax.Array:
    Tp, D = xs.shape
    E, D2, F = w.shape
    assert D == D2 and Tp % tm == 0 and D % dk == 0 and F % fn == 0, \
        (xs.shape, w.shape, (tm, dk, fn))
    grid = (Tp // tm, F // fn, D // dk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, dk), lambda i, j, k, te: (i, k)),
            pl.BlockSpec((1, dk, fn), lambda i, j, k, te: (te[i], k, j)),
        ],
        out_specs=pl.BlockSpec((tm, fn), lambda i, j, k, te: (i, j)),
    )
    fn_call = pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, F), jnp.float32),
        interpret=interpret,
        **compiler_params(dimension_semantics),
    )
    return fn_call(tile_expert, xs, w)
