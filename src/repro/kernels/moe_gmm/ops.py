"""jit'd wrapper: routing + sort + group alignment + three gmm calls.

``moe_ffn`` is numerically exact w.r.t. the naive dense-dispatch oracle
(no capacity drops) while doing ~E/K times less matmul work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.moe_gmm.kernel import gmm_pallas
from repro.kernels.moe_gmm.ref import moe_ffn_ref


def _route(idx: jax.Array, T: int, K: int, E: int, tm: int):
    """Sort (token, k) pairs by expert and compute group-aligned row slots.

    Returns (dest, tile_expert, Tp):
      dest:        (T*K,) destination row of each flat pair in the aligned
                   buffer (rows grouped by expert, groups padded to tm)
      tile_expert: (Tp//tm,) expert id of every row tile
    """
    TK = T * K
    Tp = int(np.ceil(TK / tm) * tm + (E - 1) * tm)  # worst-case alignment pad
    flat_e = idx.reshape(-1)
    counts = jnp.bincount(flat_e, length=E)                       # (E,)
    aligned = ((counts + tm - 1) // tm) * tm
    aligned = jnp.where(counts == 0, 0, aligned)
    group_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(aligned)[:-1].astype(jnp.int32)])
    # rank of each pair within its expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (TK, E)
    rank = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(TK), flat_e]
    dest = group_start[flat_e] + rank                             # (TK,)
    # expert of each row tile: search the group boundary table
    bounds = jnp.cumsum(aligned)                                  # (E,)
    tile_rows = jnp.arange(Tp // tm, dtype=jnp.int32) * tm
    tile_expert = jnp.searchsorted(bounds, tile_rows, side="right").astype(jnp.int32)
    tile_expert = jnp.minimum(tile_expert, E - 1)
    return dest, tile_expert, Tp


@functools.partial(jax.jit, static_argnames=("tm", "fn", "dk",
                                             "dimension_semantics",
                                             "interpret"))
def moe_ffn(x: jax.Array,      # (T, D)
            gate: jax.Array,   # (T, K)
            idx: jax.Array,    # (T, K) int32
            wg: jax.Array, wu: jax.Array,   # (E, D, F)
            wd: jax.Array,                  # (E, F, D)
            tm: int = 128,
            fn: int = 128, dk: int = 128,   # tile-size *preferences*
            dimension_semantics=None,
            interpret: bool = False) -> jax.Array:
    """``fn``/``dk`` are schedule preferences: each of the three grouped
    matmuls contracts/outputs over D or F, so the preference clamps to the
    largest aligned divisor of the actual dimension (`_tile`) — a swept
    schedule can therefore never produce an invalid tiling, only coincide
    with a neighbor (and be deduplicated by the sweep's argmin)."""
    T, D = x.shape
    K = idx.shape[1]
    E = wg.shape[0]
    F = wg.shape[2]
    dest, tile_expert, Tp = _route(idx, T, K, E, tm)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    xs = jnp.zeros((Tp, D), x.dtype).at[dest].set(x[flat_t])
    dims = ((dimension_semantics, dimension_semantics, "arbitrary")
            if dimension_semantics else None)
    dk_d, fn_f = _tile(D, dk), _tile(F, fn)   # contract D / output F (up)
    dk_f, fn_d = _tile(F, dk), _tile(D, fn)   # contract F / output D (down)
    g = gmm_pallas(xs, wg, tile_expert, tm=tm, fn=fn_f, dk=dk_d,
                   dimension_semantics=dims, interpret=interpret)
    u = gmm_pallas(xs, wu, tile_expert, tm=tm, fn=fn_f, dk=dk_d,
                   dimension_semantics=dims, interpret=interpret)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = gmm_pallas(h, wd, tile_expert, tm=tm, fn=fn_d, dk=dk_f,
                   dimension_semantics=dims, interpret=interpret)  # (Tp, D)
    flat_g = gate.reshape(-1).astype(jnp.float32)
    contrib = y[dest] * flat_g[:, None]
    out = jax.ops.segment_sum(contrib, flat_t, num_segments=T)
    return out.astype(x.dtype)


def _tile(n: int, pref: int = 128) -> int:
    """Largest hardware-aligned tile size dividing n (prefer ``pref``)."""
    if n % pref == 0:
        return pref
    for t in (128, 64, 32, 16, 8):
        if t < pref and n % t == 0:
            return t
    return n


def moe_ffn_oracle(x, gate, idx, wg, wu, wd):
    return moe_ffn_ref(x, gate, idx, wg, wu, wd)
