"""Pure-jnp oracles for the grouped-matmul MoE kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(xs: jax.Array, w: jax.Array, tile_expert: jax.Array,
            tm: int) -> jax.Array:
    """Group-aligned grouped matmul oracle.

    xs:          (Tp, D) rows grouped by expert, groups tile-aligned
    w:           (E, D, F)
    tile_expert: (Tp // tm,) expert id of each row tile
    returns      (Tp, F): xs[i] @ w[expert_of_row(i)]
    """
    Tp, D = xs.shape
    row_expert = jnp.repeat(tile_expert, tm, total_repeat_length=Tp)
    wr = w[row_expert]                      # (Tp, D, F)
    return jnp.einsum("td,tdf->tf", xs.astype(jnp.float32),
                      wr.astype(jnp.float32))


def moe_ffn_ref(x, gate, idx, wg, wu, wd):
    """Dense one-hot oracle — identical math to the naive formulation the
    LiLAC pass detects (harness 'dense')."""
    E = wg.shape[0]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    combine = jnp.einsum("tke,tk->te", onehot, gate.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    g = jnp.einsum("td,edf->etf", xf, wg.astype(jnp.float32))
    u = jnp.einsum("td,edf->etf", xf, wu.astype(jnp.float32))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("etf,efd->etd", h, wd.astype(jnp.float32))
    return jnp.einsum("te,etd->td", combine, y)
