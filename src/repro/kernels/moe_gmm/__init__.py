from repro.kernels.moe_gmm import kernel, ops, ref  # noqa: F401
