"""LiLAC HARNESS declaration for the ragged grouped-matmul MoE kernel."""
from __future__ import annotations

from repro.core.spec import harness


@harness("""
HARNESS pallas.gmm implements moe_ffn
  default_for tpu;
""")
def moe_gmm_pallas(b, ctx):
    from repro.kernels.moe_gmm import ops as gmm_ops
    interpret = ctx.platform != "tpu"
    return gmm_ops.moe_ffn(b["x"], b["gate"], b["idx"],
                           b["wg"], b["wu"], b["wd"],
                           interpret=interpret)
