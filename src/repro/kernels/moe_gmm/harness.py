"""LiLAC HARNESS declaration for the ragged grouped-matmul MoE kernel.

Schedule space: ``tm`` (token-tile rows / group alignment quantum) and the
``fn``/``dk`` tile preferences are tune clauses; the constraint bounds the
per-step VMEM working set (x + w + f32 out tiles).  ``dimsem`` annotates
the m/n grid dimensions for Mosaic ('parallel' lets it reorder tiles; the
k dimension stays 'arbitrary' — it revisits the accumulator).
"""
from __future__ import annotations

from repro.core.spec import harness


@harness("""
HARNESS pallas.gmm implements moe_ffn
  default_for tpu;
  tune tm in {128, 64, 256};
  tune fn in {128, 256};
  tune dk in {128, 256};
  tune dimsem in {arbitrary, parallel};
  constraint (tm * fn) + (tm * dk) + (fn * dk) <= 163840;
  vjp moe_ffn_bwd(x, gate, wg, wu, wd);
""")
def moe_gmm_pallas(b, ctx, *, tm=128, fn=128, dk=128, dimsem="arbitrary"):
    from repro.kernels.moe_gmm import ops as gmm_ops
    interpret = ctx.platform != "tpu"
    return gmm_ops.moe_ffn(b["x"], b["gate"], b["idx"],
                           b["wg"], b["wu"], b["wd"],
                           tm=tm, fn=fn, dk=dk,
                           dimension_semantics=dimsem,
                           interpret=interpret)
