"""jit'd wrapper for the ELL slab SpMV kernel: padding + variant dispatch.

All schedule parameters (``rows_per_slab``, ``dimension_semantics``) flow
through from the HARNESS block's tune clauses; this layer only normalizes
shapes (row padding to the slab size, bias padding) and picks the
VMEM-resident vs column-windowed kernel.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmv_ell.kernel import (
    spmv_ell_pallas,
    spmv_ell_windowed_pallas,
)
from repro.kernels.spmv_ell.ref import spmv_ell_ref

# Vector sizes above this use the column-windowed variant (vector slice per
# window instead of the whole vector resident in VMEM).
_VMEM_VEC_LIMIT = 1 << 20  # 1M elements (4 MiB f32)


def spmv_ell(val: jax.Array, col: jax.Array, vec: jax.Array,
             rows_per_slab: int = 256,
             dimension_semantics: Optional[str] = None,
             epilogue: Optional[str] = None,
             bias: Optional[jax.Array] = None,
             interpret: bool = False) -> jax.Array:
    """ELL SpMV with row padding to the slab size.

    ``dimension_semantics`` is the per-slab grid annotation name
    ('parallel' | 'arbitrary'); the windowed variant forces the window
    dimension to 'arbitrary' (it accumulates).  ``epilogue``/``bias``
    apply the detected fused epilogue in-register.
    """
    rows, width = val.shape
    if epilogue is not None and bias is not None and (
            getattr(bias, "ndim", 0) != 1 or bias.shape[0] != rows):
        # scalar / broadcast-shaped bias: the kernels tile a (rows,) bias
        # per slab, so anything else applies post-kernel (still correct,
        # just unfused)
        from repro.core.rewrite import apply_epilogue
        out = spmv_ell(val, col, vec, rows_per_slab=rows_per_slab,
                       dimension_semantics=dimension_semantics,
                       interpret=interpret)
        return apply_epilogue(out, bias, epilogue)
    pad = (-rows) % rows_per_slab
    if rows < rows_per_slab:
        rows_per_slab = max(8, 1 << int(np.floor(np.log2(rows))))
        pad = (-rows) % rows_per_slab
    if pad:
        val = jnp.pad(val, ((0, pad), (0, 0)))
        col = jnp.pad(col, ((0, pad), (0, 0)))
        if bias is not None:
            bias = jnp.pad(bias, (0, pad))
    if vec.shape[0] <= _VMEM_VEC_LIMIT:
        dims = (dimension_semantics,) if dimension_semantics else None
        out = spmv_ell_pallas(val, col, vec, rows_per_slab=rows_per_slab,
                              dimension_semantics=dims,
                              epilogue=epilogue, bias=bias,
                              interpret=interpret)
    else:
        out = _windowed(val, col, vec, rows_per_slab, interpret,
                        dimension_semantics=dimension_semantics,
                        epilogue=epilogue, bias=bias)
    return out[:rows]


def _windowed(val, col, vec, rows_per_slab, interpret, window: int = 1 << 16,
              dimension_semantics: Optional[str] = None,
              epilogue: Optional[str] = None,
              bias: Optional[jax.Array] = None):
    rows, width = val.shape
    v = vec.shape[0]
    pad_v = (-v) % window
    if pad_v:
        vec = jnp.pad(vec, (0, pad_v))
    n_windows = vec.shape[0] // window
    # split each row's slots by column window; pad each window's slot list
    # to `width` (worst case all slots in one window).  The marshaling layer
    # does this once per matrix; here we do it with jnp for completeness.
    wid = col // window
    val3 = jnp.zeros((rows, n_windows, width), val.dtype)
    col3 = jnp.zeros((rows, n_windows, width), col.dtype)
    # position within (row, window): stable cumsum trick
    onehot = jax.nn.one_hot(wid, n_windows, dtype=jnp.int32)     # (R,W,nw)
    pos = jnp.cumsum(onehot, axis=1) - onehot                    # (R,W,nw)
    pos = jnp.take_along_axis(pos, wid[..., None], axis=2)[..., 0]
    r = jnp.arange(rows)[:, None] + jnp.zeros_like(col)
    val3 = val3.at[r, wid, pos].set(val)
    col3 = col3.at[r, wid, pos].set(col % window)
    dims = ((dimension_semantics, "arbitrary")
            if dimension_semantics else None)
    return spmv_ell_windowed_pallas(val3, col3, vec,
                                    rows_per_slab=rows_per_slab,
                                    window=window,
                                    dimension_semantics=dims,
                                    epilogue=epilogue, bias=bias,
                                    interpret=interpret)


def spmv_ell_oracle(val, col, vec):
    return spmv_ell_ref(val, col, vec)
