"""Pure-jnp oracle for the ELL row-slab SpMV kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ell_ref(val: jax.Array, col: jax.Array, vec: jax.Array) -> jax.Array:
    """out[i] = sum_j val[i, j] * vec[col[i, j]]  (padding: val==0)."""
    return jnp.sum(val.astype(jnp.float32)
                   * vec.astype(jnp.float32)[col], axis=1)
