"""LiLAC HARNESS declarations for the ELL/JDS row-slab Pallas kernel.

The paper's "add a backend" story: a HARNESS block (the How-descriptor)
plus a kernel body, nothing else.  Marshaling for the CSR/COO entry point
is generated from the declared ``ell_pack128`` repack clause — this module
never touches the MarshalingCache directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.spec import harness


@harness("""
HARNESS pallas.ell implements spmv_ell, spmv_jds
  formats ELL, JDS;
  default_for tpu;
""")
def spmv_ell_pallas(b, ctx):
    """Direct ELL/JDS match -> VPU row-slab kernel."""
    from repro.kernels.spmv_ell import ops as ell_ops
    perm = b.get("perm")
    interpret = ctx.platform != "tpu"
    acc = ell_ops.spmv_ell(b["val"], b["col_ind"], b["vector"],
                           interpret=interpret)
    if perm is None:
        return acc
    out = jnp.zeros((b["rows"],), acc.dtype)
    return out.at[perm].set(acc)


# pallas harnesses are TPU-targeted: on CPU they run the kernel
# interpreter (correctness only, far too slow for autotune); they
# stay selectable by explicit policy name.
@harness("""
HARNESS pallas.ell implements spmv_csr, spmv_coo
  platforms tpu;
  formats CSR, COO;
  host_only;
  marshal ell = ell_pack128(a, colidx, rowstr|rowidx)
      from csr_binding to ELL128;
""")
def spmv_ell_pallas_host(b, ctx, *, ell):
    """CSR/COO match -> marshaled ELL repack -> Pallas slab kernel."""
    from repro.kernels.spmv_ell import ops as ell_ops
    interpret = ctx.platform != "tpu"
    acc = ell_ops.spmv_ell(ell.val, ell.col, b["iv"], interpret=interpret)
    out = jnp.zeros((b["rows"],), acc.dtype)
    return out.at[ell.perm].set(acc)
