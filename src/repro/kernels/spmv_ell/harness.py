"""LiLAC HARNESS declarations for the ELL/JDS row-slab Pallas kernel.

The paper's "add a backend" story: a HARNESS block (the How-descriptor)
plus a kernel body, nothing else.  Marshaling for the CSR/COO entry point
is generated from the declared ``ell_pack128`` repack clause — this module
never touches the MarshalingCache directly.

Kernel schedules are first-class: the ``tune`` clauses declare the
parameter space (the first value of each is the previously hard-coded
constant, so the default schedule is bit-identical to the old kernel), the
autotuner sweeps the cross-product, and the winning schedule arrives at
the body as keyword arguments.  ``fuse epilogue`` declares that the body
applies detected ``(+bias) -> relu|silu`` chains itself — in-register for
the direct ELL path, post-permutation for JDS/CSR (the permuted output
must exist before the bias indexes it).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.rewrite import apply_epilogue
from repro.core.spec import harness


@harness("""
HARNESS pallas.ell implements spmv_ell, spmv_jds
  formats ELL, JDS;
  default_for tpu;
  tune rows_per_slab in {256, 64, 128, 512};
  tune dimsem in {arbitrary, parallel};
  fuse epilogue;
  vjp spmv_ell_bwd(val, vector);
""")
def spmv_ell_pallas(b, ctx, *, rows_per_slab=256, dimsem="arbitrary"):
    """Direct ELL/JDS match -> VPU row-slab kernel."""
    from repro.kernels.spmv_ell import ops as ell_ops
    perm = b.get("perm")
    interpret = ctx.platform != "tpu"
    epilogue = getattr(ctx, "epilogue", None)
    bias = b.get("bias")
    if perm is None:
        # pure ELL: the epilogue fuses in-register before the only store
        return ell_ops.spmv_ell(b["val"], b["col_ind"], b["vector"],
                                rows_per_slab=rows_per_slab,
                                dimension_semantics=dimsem,
                                epilogue=epilogue, bias=bias,
                                interpret=interpret)
    acc = ell_ops.spmv_ell(b["val"], b["col_ind"], b["vector"],
                           rows_per_slab=rows_per_slab,
                           dimension_semantics=dimsem,
                           interpret=interpret)
    out = jnp.zeros((b["rows"],), acc.dtype)
    out = out.at[perm].set(acc)
    if epilogue is not None:
        # JDS: the detected bias lives in output (post-perm) space
        out = apply_epilogue(out, bias, epilogue)
    return out


# pallas harnesses are TPU-targeted: on CPU they run the kernel
# interpreter (correctness only, far too slow for autotune); they
# stay selectable by explicit policy name.
@harness("""
HARNESS pallas.ell implements spmv_csr, spmv_coo
  platforms tpu;
  formats CSR, COO;
  host_only;
  marshal ell = ell_pack128(a, colidx, rowstr|rowidx)
      from csr_binding to ELL128;
  tune rows_per_slab in {256, 64, 128, 512};
  tune dimsem in {arbitrary, parallel};
  fuse epilogue;
  vjp spmv_csr_bwd(a, iv);
""")
def spmv_ell_pallas_host(b, ctx, *, ell, rows_per_slab=256,
                         dimsem="arbitrary"):
    """CSR/COO match -> marshaled ELL repack -> Pallas slab kernel."""
    from repro.kernels.spmv_ell import ops as ell_ops
    interpret = ctx.platform != "tpu"
    acc = ell_ops.spmv_ell(ell.val, ell.col, b["iv"],
                           rows_per_slab=rows_per_slab,
                           dimension_semantics=dimsem,
                           interpret=interpret)
    out = jnp.zeros((b["rows"],), acc.dtype)
    out = out.at[ell.perm].set(acc)
    epilogue = getattr(ctx, "epilogue", None)
    if epilogue is not None:
        out = apply_epilogue(out, b.get("bias"), epilogue)
    return out
