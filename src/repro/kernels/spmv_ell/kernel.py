"""ELL/JDS row-slab SpMV on the VPU with a VMEM-resident vector.

Hardware adaptation (DESIGN.md §2): the paper's JDS layout exists to give
GPU warps coalesced loads down jagged diagonals.  On TPU the analogous
resource is VMEM locality: rows are sorted by nnz (the JDS permutation,
kept as a marshaled invariant), padded to a lane-aligned width (ELL slab),
and processed in (rows_per_slab, width) VMEM tiles.  The gather
vec[col[i,j]] stays on-chip because the full dense vector is pinned in VMEM
across the grid (BlockSpec index_map constant-0 — Pallas keeps the block
resident); for vectors larger than VMEM the ops layer falls back to the
column-windowed variant below.

Grid: (num_slabs,) over row slabs.
VMEM per step: slab val+col (2 x R x W x 4B) + vector + out row block.
For R=256, W=256, vec 64K f32: 0.5 MiB + 0.25 MiB — double-buffer safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_ell_kernel(val_ref, col_ref, vec_ref, out_ref):
    val = val_ref[...].astype(jnp.float32)       # (R, W)
    col = col_ref[...]                           # (R, W)
    vec = vec_ref[...].astype(jnp.float32)       # (V,)
    gathered = jnp.take(vec, col, axis=0)        # VMEM gather on lanes
    out_ref[...] = jnp.sum(val * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("rows_per_slab", "interpret"))
def spmv_ell_pallas(val: jax.Array,   # (rows, width)
                    col: jax.Array,   # (rows, width) int32
                    vec: jax.Array,   # (V,)
                    rows_per_slab: int = 256,
                    interpret: bool = False) -> jax.Array:
    rows, width = val.shape
    assert rows % rows_per_slab == 0, (rows, rows_per_slab)
    num_slabs = rows // rows_per_slab
    grid = (num_slabs,)
    fn = pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_slab, width), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_slab, width), lambda i: (i, 0)),
            pl.BlockSpec((vec.shape[0],), lambda i: (0,)),  # resident
        ],
        out_specs=pl.BlockSpec((rows_per_slab,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
    )
    return fn(val, col, vec)


def _spmv_ell_windowed_kernel(val_ref, col_ref, vec_ref, out_ref, *, window):
    """Column-windowed variant: the slab's column indices are window-local
    (marshaling pre-subtracts the window base), so only a (window,) slice of
    the vector is resident per step."""
    w = pl.program_id(1)
    val = val_ref[...].astype(jnp.float32)[:, 0, :]   # (R, W)
    col = col_ref[...][:, 0, :]
    vec = vec_ref[...].astype(jnp.float32)
    gathered = jnp.take(vec, col, axis=0)

    @pl.when(w == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(val * gathered, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("rows_per_slab", "window", "interpret"))
def spmv_ell_windowed_pallas(val: jax.Array,   # (rows, n_windows, width)
                             col: jax.Array,   # (rows, n_windows, width)
                             vec: jax.Array,   # (V,) with V % window == 0
                             rows_per_slab: int = 256,
                             window: int = 4096,
                             interpret: bool = False) -> jax.Array:
    rows, n_windows, width = val.shape
    assert rows % rows_per_slab == 0
    assert vec.shape[0] == n_windows * window
    grid = (rows // rows_per_slab, n_windows)
    fn = pl.pallas_call(
        functools.partial(_spmv_ell_windowed_kernel, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_slab, 1, width), lambda i, w: (i, w, 0)),
            pl.BlockSpec((rows_per_slab, 1, width), lambda i, w: (i, w, 0)),
            pl.BlockSpec((window,), lambda i, w: (w,)),
        ],
        out_specs=pl.BlockSpec((rows_per_slab,), lambda i, w: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
    )
    return fn(val, col, vec)
