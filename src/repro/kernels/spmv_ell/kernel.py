"""ELL/JDS row-slab SpMV on the VPU with a VMEM-resident vector.

Hardware adaptation (DESIGN.md §2): the paper's JDS layout exists to give
GPU warps coalesced loads down jagged diagonals.  On TPU the analogous
resource is VMEM locality: rows are sorted by nnz (the JDS permutation,
kept as a marshaled invariant), padded to a lane-aligned width (ELL slab),
and processed in (rows_per_slab, width) VMEM tiles.  The gather
vec[col[i,j]] stays on-chip because the full dense vector is pinned in VMEM
across the grid (BlockSpec index_map constant-0 — Pallas keeps the block
resident); for vectors larger than VMEM the ops layer falls back to the
column-windowed variant below.

Schedule parameters (declared as ``tune`` clauses in the HARNESS block and
swept by the autotuner — no module constants):

  rows_per_slab        rows per grid step; trades grid overhead against
                       VMEM working set per step.
  dimension_semantics  Mosaic grid annotation ('parallel' row slabs when
                       the slab-independent accumulation allows it).

Fused epilogue: the kernels optionally apply ``(+bias) -> relu|silu``
in-register before the single output store, eliminating the full
output-size HBM round-trip an unfused activation pays.

Grid: (num_slabs,) over row slabs.
VMEM per step: slab val+col (2 x R x W x 4B) + vector + out row block.
For R=256, W=256, vec 64K f32: 0.5 MiB + 0.25 MiB — double-buffer safe.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import apply_epilogue_inregister, compiler_params


def _spmv_ell_kernel(val_ref, col_ref, vec_ref, *rest, epilogue=None,
                     has_bias=False):
    bias_ref = rest[0] if has_bias else None
    out_ref = rest[-1]
    val = val_ref[...].astype(jnp.float32)       # (R, W)
    col = col_ref[...]                           # (R, W)
    vec = vec_ref[...].astype(jnp.float32)       # (V,)
    gathered = jnp.take(vec, col, axis=0)        # VMEM gather on lanes
    acc = jnp.sum(val * gathered, axis=1)
    bias = bias_ref[...].astype(jnp.float32) if has_bias else None
    out_ref[...] = apply_epilogue_inregister(acc, bias, epilogue)


@functools.partial(jax.jit, static_argnames=("rows_per_slab",
                                             "dimension_semantics",
                                             "epilogue", "interpret"))
def spmv_ell_pallas(val: jax.Array,   # (rows, width)
                    col: jax.Array,   # (rows, width) int32
                    vec: jax.Array,   # (V,)
                    rows_per_slab: int = 256,
                    dimension_semantics: Optional[Tuple[str, ...]] = None,
                    epilogue: Optional[str] = None,
                    bias: Optional[jax.Array] = None,   # (rows,)
                    interpret: bool = False) -> jax.Array:
    rows, width = val.shape
    assert rows % rows_per_slab == 0, (rows, rows_per_slab)
    num_slabs = rows // rows_per_slab
    grid = (num_slabs,)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((rows_per_slab, width), lambda i: (i, 0)),
        pl.BlockSpec((rows_per_slab, width), lambda i: (i, 0)),
        pl.BlockSpec((vec.shape[0],), lambda i: (0,)),  # resident
    ]
    args = [val, col, vec]
    if has_bias:
        in_specs.append(pl.BlockSpec((rows_per_slab,), lambda i: (i,)))
        args.append(bias)
    fn = pl.pallas_call(
        functools.partial(_spmv_ell_kernel, epilogue=epilogue,
                          has_bias=has_bias),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows_per_slab,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
        **compiler_params(dimension_semantics),
    )
    return fn(*args)


def _spmv_ell_windowed_kernel(val_ref, col_ref, vec_ref, *rest, window,
                              epilogue=None, has_bias=False):
    """Column-windowed variant: the slab's column indices are window-local
    (marshaling pre-subtracts the window base), so only a (window,) slice of
    the vector is resident per step.  The epilogue applies on the last
    window visit, when the row accumulator is complete."""
    bias_ref = rest[0] if has_bias else None
    out_ref = rest[-1]
    w = pl.program_id(1)
    nw = pl.num_programs(1)
    val = val_ref[...].astype(jnp.float32)[:, 0, :]   # (R, W)
    col = col_ref[...][:, 0, :]
    vec = vec_ref[...].astype(jnp.float32)
    gathered = jnp.take(vec, col, axis=0)

    @pl.when(w == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(val * gathered, axis=1)

    if epilogue is not None or has_bias:
        @pl.when(w == nw - 1)
        def _():
            bias = bias_ref[...].astype(jnp.float32) if has_bias else None
            out_ref[...] = apply_epilogue_inregister(out_ref[...], bias,
                                                     epilogue)


@functools.partial(jax.jit,
                   static_argnames=("rows_per_slab", "window",
                                    "dimension_semantics", "epilogue",
                                    "interpret"))
def spmv_ell_windowed_pallas(val: jax.Array,   # (rows, n_windows, width)
                             col: jax.Array,   # (rows, n_windows, width)
                             vec: jax.Array,   # (V,) with V % window == 0
                             rows_per_slab: int = 256,
                             window: int = 4096,
                             dimension_semantics: Optional[Tuple[str, ...]]
                             = None,
                             epilogue: Optional[str] = None,
                             bias: Optional[jax.Array] = None,
                             interpret: bool = False) -> jax.Array:
    rows, n_windows, width = val.shape
    assert rows % rows_per_slab == 0
    assert vec.shape[0] == n_windows * window
    grid = (rows // rows_per_slab, n_windows)
    has_bias = bias is not None
    in_specs = [
        pl.BlockSpec((rows_per_slab, 1, width), lambda i, w: (i, w, 0)),
        pl.BlockSpec((rows_per_slab, 1, width), lambda i, w: (i, w, 0)),
        pl.BlockSpec((window,), lambda i, w: (w,)),
    ]
    args = [val, col, vec]
    if has_bias:
        in_specs.append(pl.BlockSpec((rows_per_slab,), lambda i, w: (i,)))
        args.append(bias)
    fn = pl.pallas_call(
        functools.partial(_spmv_ell_windowed_kernel, window=window,
                          epilogue=epilogue, has_bias=has_bias),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rows_per_slab,), lambda i, w: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=interpret,
        **compiler_params(dimension_semantics),
    )
    return fn(*args)
