from repro.kernels.spmv_ell import kernel, ops, ref  # noqa: F401
