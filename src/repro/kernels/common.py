"""Helpers shared by the Pallas kernel packages.

Kept outside any one kernel package so siblings don't reach into each
other's internals: every kernel builds its Mosaic compiler params and its
in-register epilogue from here.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu


def apply_epilogue_inregister(acc, bias, epilogue: Optional[str]):
    """The in-register epilogue: bias add then activation, applied to a
    value that is still in VMEM/registers.  Must match
    ``repro.core.rewrite.apply_epilogue`` bit-for-bit."""
    if bias is not None:
        acc = acc + bias
    if epilogue == "relu":
        acc = jnp.maximum(acc, jnp.zeros_like(acc))
    elif epilogue == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    return acc


def compiler_params(dimension_semantics: Optional[Tuple[str, ...]]):
    """``pallas_call`` kwargs for a tuned ``dimension_semantics`` tuple
    (empty when None, so untuned calls stay byte-identical)."""
    if dimension_semantics is None:
        return {}
    return {"compiler_params": pltpu.TPUCompilerParams(
        dimension_semantics=tuple(dimension_semantics))}
