"""Pallas TPU kernels for the compute hot-spots LiLAC routes to.

Each kernel package has:
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py    — jit'd wrapper with layout/padding marshaling
    ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels (TPU-native adaptations of the paper's GPU library calls, §2 of
DESIGN.md):
    bsr_spmm — block-sparse (BCSR) x dense on the MXU, scalar-prefetched
               block indices (the cuSPARSE csrmv analogue, re-blocked for
               the systolic array)
    spmv_ell — ELL/JDS row-slab SpMV on the VPU with VMEM-resident vector
    moe_gmm  — group-aligned ragged grouped matmul (megablocks-style), the
               MoE expert FFN hot loop
"""
