"""jit'd wrapper for the BCSR SpMM kernel: layout marshaling + dispatch.

Schedule parameters (``bn``, ``dimension_semantics``) flow through from
the HARNESS tune clauses; the fused epilogue fuses in-kernel when every
block-row owns at least one stored tile (the last-visit trigger fires per
block-row) and falls back to a post-kernel application otherwise.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm.kernel import bsr_spmm_pallas
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref
from repro.sparse.formats import BCSR
from repro.sparse.ops import row_ids_from_row_ptr


def _bias_kind(bias, rows: int, n: int) -> Optional[str]:
    if bias is None or bias.ndim != 1:
        return None
    if bias.shape[0] == rows:
        return "row"
    if bias.shape[0] == n:
        return "col"
    return None


def bsr_spmm(bcsr: BCSR, dense: jax.Array, bn: int = 128,
             dimension_semantics: Optional[str] = None,
             epilogue: Optional[str] = None,
             bias: Optional[jax.Array] = None,
             bias_kind: Optional[str] = None,
             interpret: bool = False) -> jax.Array:
    """Block-sparse (BCSR) @ dense -> (rows, N) f32.

    Pads N to a multiple of bn; block_row ids are derived from the pointer
    array (a marshaled invariant when called through a LiLAC harness).
    ``epilogue``/``bias`` apply the detected fused epilogue in-register on
    the last visit to each output block-row.  ``bias_kind`` ('row'|'col')
    disambiguates a 1D bias when rows == N; by default shape resolves it,
    row-first.
    """
    from repro.core.rewrite import apply_epilogue

    rows, _ = bcsr.shape
    n = dense.shape[1]
    pad_n = (-n) % bn
    if pad_n:
        dense = jnp.pad(dense, ((0, 0), (0, pad_n)))
    block_row = row_ids_from_row_ptr(bcsr.block_rowptr, bcsr.nblocks)
    dims = ((dimension_semantics, "arbitrary")
            if dimension_semantics else None)
    kind = None if bias is None else (
        bias_kind if bias_kind is not None else _bias_kind(bias, rows, n))
    # in-kernel fusion triggers on the last stored tile of each block-row:
    # an empty block-row would never fire it, so fall back post-kernel.
    # (all_block_rows_nonempty is cached on the BCSR — one host sync per
    # packed matrix, not per call.)
    fused = (epilogue is not None
             and bool(getattr(bcsr, "all_block_rows_nonempty", False))
             and (bias is None or kind is not None))
    kbias = None
    if fused and kind == "row":
        pad_r = bcsr.block_rows * bcsr.blocks.shape[1] - bias.shape[0]
        kbias = jnp.pad(bias, (0, pad_r)) if pad_r > 0 else bias
    elif fused and kind == "col":
        kbias = jnp.pad(bias, (0, pad_n)) if pad_n else bias
    out = bsr_spmm_pallas(bcsr.blocks, bcsr.block_col, block_row, dense,
                          num_block_rows=bcsr.block_rows, bn=bn,
                          dimension_semantics=dims,
                          epilogue=epilogue if fused else None,
                          bias=kbias, bias_kind=kind if fused else None,
                          interpret=interpret)
    out = out[:rows, :n]
    if epilogue is not None and not fused:
        out = apply_epilogue(out, bias, epilogue)
    return out


def bsr_spmm_oracle(bcsr: BCSR, dense: jax.Array) -> jax.Array:
    block_row = row_ids_from_row_ptr(bcsr.block_rowptr, bcsr.nblocks)
    out = bsr_spmm_ref(bcsr.blocks, bcsr.block_col, block_row, dense,
                       bcsr.block_rows)
    return out[: bcsr.shape[0]]
