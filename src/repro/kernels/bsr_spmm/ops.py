"""jit'd wrapper for the BCSR SpMM kernel: layout marshaling + dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bsr_spmm.kernel import bsr_spmm_pallas
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref
from repro.sparse.formats import BCSR
from repro.sparse.ops import row_ids_from_row_ptr


def bsr_spmm(bcsr: BCSR, dense: jax.Array, bn: int = 128,
             interpret: bool = False) -> jax.Array:
    """Block-sparse (BCSR) @ dense -> (rows, N) f32.

    Pads N to a multiple of bn; block_row ids are derived from the pointer
    array (a marshaled invariant when called through a LiLAC harness).
    """
    rows, _ = bcsr.shape
    n = dense.shape[1]
    pad_n = (-n) % bn
    if pad_n:
        dense = jnp.pad(dense, ((0, 0), (0, pad_n)))
    block_row = row_ids_from_row_ptr(bcsr.block_rowptr, bcsr.nblocks)
    out = bsr_spmm_pallas(bcsr.blocks, bcsr.block_col, block_row, dense,
                          num_block_rows=bcsr.block_rows, bn=bn,
                          interpret=interpret)
    return out[:rows, :n]


def bsr_spmm_oracle(bcsr: BCSR, dense: jax.Array) -> jax.Array:
    block_row = row_ids_from_row_ptr(bcsr.block_rowptr, bcsr.nblocks)
    out = bsr_spmm_ref(bcsr.blocks, bcsr.block_col, block_row, dense,
                       bcsr.block_rows)
    return out[: bcsr.shape[0]]
