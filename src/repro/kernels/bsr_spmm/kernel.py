"""BCSR block-sparse x dense matmul on the MXU (TPU-native SpMM).

Hardware adaptation (DESIGN.md §2): a GPU csrmv assigns threads to rows and
gathers scalars — no TPU analogue.  Instead the sparse matrix is stored as
dense (bm, bk) tiles (BCSR) sized for the MXU; the kernel walks the stored
tiles in CSR order, streaming each tile and the matching rhs block through
VMEM and accumulating into the output block for the current block-row.

Grid: (n_tiles, nnzb) — the block index k iterates fastest, so all visits
to one output block-row are consecutive; the accumulator lives in the
output VMEM ref and is zeroed when a new block-row begins (is_first), the
standard Pallas revisiting-accumulator pattern.

Scalar prefetch: block_row (nnzb,) and block_col (nnzb,) arrive as SMEM
scalars *before* the grid runs, so the BlockSpec index_maps can use them to
steer the DMA of rhs/out tiles — this is the TPU-idiomatic equivalent of
indirect addressing.

Schedule parameters (``tune`` clauses in the HARNESS blocks, swept by the
autotuner): ``bn`` — the rhs/output block width, trading DMA granularity
against VMEM per step — and ``dimension_semantics`` for the n-tile grid
dimension (the nnzb dimension is always 'arbitrary': it revisits the
accumulator).

Fused epilogue: on the *last* visit to an output block-row (the next
stored tile belongs to a different row), the kernel applies
``(+bias) -> relu|silu`` in-register before the block leaves VMEM.  Bias
can be per-row ((rows, 1) tiles steered by block_row) or per-column
((1, bn) tiles steered by the n-tile index).

VMEM working set per grid step:
    blocks tile (bm, bk) + rhs tile (bk, bn) + out tile (bm, bn)
    = 128x128 f32 x 3 = 192 KiB  « 16 MiB VMEM -> double-buffering safe.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import apply_epilogue_inregister, compiler_params


def _bsr_spmm_kernel(block_row_ref, block_col_ref,   # scalar prefetch (SMEM)
                     *refs, epilogue=None, bias_kind=None):
    blocks_ref, rhs_ref = refs[0], refs[1]
    bias_ref = refs[2] if bias_kind else None
    out_ref = refs[-1]
    k = pl.program_id(1)
    nk = pl.num_programs(1)
    row = block_row_ref[k]
    is_first = jnp.logical_or(k == 0, block_row_ref[jnp.maximum(k - 1, 0)] != row)

    @pl.when(is_first)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = blocks_ref[0]                                # (bm, bk)
    b = rhs_ref[...]                                 # (bk, bn)
    out_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    if epilogue is not None or bias_kind:
        is_last = jnp.logical_or(
            k == nk - 1, block_row_ref[jnp.minimum(k + 1, nk - 1)] != row)

        @pl.when(is_last)
        def _():
            bias = bias_ref[...].astype(jnp.float32) if bias_kind else None
            out_ref[...] = apply_epilogue_inregister(out_ref[...], bias,
                                                     epilogue)


@functools.partial(jax.jit, static_argnames=("num_block_rows", "bn",
                                             "dimension_semantics",
                                             "epilogue", "bias_kind",
                                             "interpret"))
def bsr_spmm_pallas(blocks: jax.Array,      # (nnzb, bm, bk)
                    block_col: jax.Array,   # (nnzb,) int32
                    block_row: jax.Array,   # (nnzb,) int32, sorted
                    dense: jax.Array,       # (K, N)
                    num_block_rows: int,
                    bn: int = 128,
                    dimension_semantics: Optional[Tuple[str, ...]] = None,
                    epilogue: Optional[str] = None,
                    bias: Optional[jax.Array] = None,
                    bias_kind: Optional[str] = None,   # 'row' | 'col'
                    interpret: bool = False) -> jax.Array:
    nnzb, bm, bk = blocks.shape
    kdim, n = dense.shape
    assert kdim % bk == 0 and n % bn == 0, (dense.shape, (bk, bn))
    n_tiles = n // bn

    in_specs = [
        # one stored tile per step k
        pl.BlockSpec((1, bm, bk), lambda j, k, br, bc: (k, 0, 0)),
        # rhs block steered by the prefetched block-column index
        pl.BlockSpec((bk, bn), lambda j, k, br, bc: (bc[k], j)),
    ]
    args = [blocks, dense]
    if bias_kind == "row":
        # (rows, 1) column vector; tiles steered by the block-row index
        in_specs.append(pl.BlockSpec((bm, 1), lambda j, k, br, bc: (br[k], 0)))
        args.append(bias.reshape(-1, 1))
    elif bias_kind == "col":
        # (1, n) row vector; tiles steered by the output column tile
        in_specs.append(pl.BlockSpec((1, bn), lambda j, k, br, bc: (0, j)))
        args.append(bias.reshape(1, -1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles, nnzb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda j, k, br, bc: (br[k], j)),
    )
    out_shape = jax.ShapeDtypeStruct((num_block_rows * bm, n), jnp.float32)
    fn = pl.pallas_call(
        functools.partial(_bsr_spmm_kernel, epilogue=epilogue,
                          bias_kind=bias_kind),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        **compiler_params(dimension_semantics),
    )
    return fn(block_row, block_col, *args)
