from repro.kernels.bsr_spmm import kernel, ops, ref  # noqa: F401
