"""Pure-jnp oracle for the BCSR block-sparse matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bsr_spmm_ref(blocks: jax.Array, block_col: jax.Array, block_row: jax.Array,
                 dense: jax.Array, num_block_rows: int) -> jax.Array:
    """out[br*bm:(br+1)*bm, :] += blocks[k] @ dense[block_col[k]*bk:..., :]
    for every stored block k with block_row[k] == br.

    blocks:    (nnzb, bm, bk)
    block_col: (nnzb,)  int32
    block_row: (nnzb,)  int32 (sorted ascending — CSR block order)
    dense:     (K, N)
    returns    (num_block_rows * bm, N) in f32
    """
    nnzb, bm, bk = blocks.shape
    n = dense.shape[1]
    rhs = dense.reshape(dense.shape[0] // bk, bk, n)[block_col]     # (nnzb,bk,n)
    prod = jnp.einsum("kij,kjn->kin", blocks.astype(jnp.float32),
                      rhs.astype(jnp.float32))                      # (nnzb,bm,n)
    out = jax.ops.segment_sum(prod, block_row, num_segments=num_block_rows)
    return out.reshape(num_block_rows * bm, n)
