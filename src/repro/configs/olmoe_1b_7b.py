"""olmoe-1b-7b — 64 experts, top-8. The primary LiLAC MoE target.
[moe] 16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304
[arXiv:2409.02060; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    moe_experts=64,
    moe_topk=8,
    source="[arXiv:2409.02060; hf]",
))
