"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE 16e top-2.
[hybrid] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536
[arXiv:2403.19887; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe_experts=16,
    moe_topk=2,
    attn_layer_period=8,   # 1 attention : 7 mamba per 8-layer period
    attn_layer_offset=4,
    moe_layer_period=2,    # MoE on odd layer indices (16 of 32 layers)
    source="[arXiv:2403.19887; hf]",
))
