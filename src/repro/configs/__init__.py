"""Assigned architecture configs — one module per --arch id."""
from repro.configs.base import (  # noqa: F401
    SHAPES, ArchConfig, ShapeConfig, all_archs, get_arch, register,
    shape_skips, smoke_config,
)
# importing each module registers its config
from repro.configs import (  # noqa: F401
    rwkv6_1p6b,
    internvl2_2b,
    granite_moe_3b_a800m,
    olmoe_1b_7b,
    granite_8b,
    mistral_large_123b,
    granite_34b,
    olmo_1b,
    jamba_v0_1_52b,
    hubert_xlarge,
)
