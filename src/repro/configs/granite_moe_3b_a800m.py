"""granite-moe-3b-a800m — 40 experts, top-8.
[moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe_experts=40,
    moe_topk=8,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]",
))
