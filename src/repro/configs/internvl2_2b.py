"""internvl2-2b — InternViT frontend (STUB) + InternLM2 backbone.
[vlm] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    frontend="stub",       # precomputed patch embeddings via input_specs()
    source="[arXiv:2404.16821; hf]",
))
