"""Config dataclasses + the --arch registry."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense|moe|ssm|hybrid|encoder|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    moe_experts: int = 0
    moe_topk: int = 0
    norm: str = "rmsnorm"         # rmsnorm | layernorm_nonparam
    causal: bool = True
    frontend: str = "none"        # none | stub  (stub: precomputed embeds)
    rope_theta: float = 1e4
    d_state: int = 16             # mamba state width
    attn_layer_period: int = 0    # jamba: 8
    attn_layer_offset: int = 4
    moe_layer_period: int = 0     # jamba: 2
    moe_impl: str = "grouped"     # naive | lilac | grouped
    # MoE formulation used on the one-token decode path.  "grouped_flat"
    # (default) is the hand-written scatter dispatch; "naive_flat" emits
    # the canonical dense-dispatch einsum form so a lilac-compiled decode
    # step exposes the MoE to the detector (the serving tier uses this).
    moe_decode_impl: str = "grouped_flat"
    capacity_factor: float = 2.0
    kv_chunk: int = 1024
    remat: bool = True
    param_dtype: Any = jnp.bfloat16
    cache_dtype: Any = jnp.bfloat16
    source: str = ""              # provenance note ([arXiv/hf; tier])
    # distribution: when True, with_sharding_constraint is applied at
    # compute sites (TP-only weights inside the layer scan -> JIT per-layer
    # FSDP gathers). mesh_axis_sizes informs divisibility decisions.
    spmd_constraints: bool = False
    mesh_axis_sizes: tuple = ()   # (("data", 16), ("model", 16), ...)
    # gradient accumulation: activation memory scales 1/microbatches
    microbatches: int = 1
    # sequence-parallel activation carries between layers (§Perf lever):
    # shards the residual stream over the model axis, turning TP
    # all-reduces into all-gather/reduce-scatter pairs and dividing carry
    # memory by the model-axis size. True = optimized, False = the
    # Megatron-TP-style baseline.
    seq_parallel: bool = True
    # decode: shard the KV cache over the model axis on the SEQUENCE dim
    # when kv-heads are unshardable (MQA) — ring-style decode (§Perf lever)
    decode_cache_seq_shard: bool = False
    # MoE EP combine psum in bf16 instead of f32 (§Perf lever)
    moe_combine_bf16: bool = False

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


# The four LM shapes assigned to every architecture.
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (ensures all configs imported)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> Dict[str, ArchConfig]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


def shape_skips(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """Returns a skip reason or None (DESIGN.md §Arch-applicability)."""
    subquadratic = cfg.family in ("ssm", "hybrid")
    if shape.name == "long_500k" and not subquadratic:
        return "full-attention arch: 500k decode needs sub-quadratic mixer"
    if shape.kind == "decode" and not cfg.causal:
        return "encoder-only arch has no autoregressive decode step"
    return None


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    period = cfg.attn_layer_period or 1
    return cfg.replace(
        n_layers=2 * period if period > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=128 if not cfg.moe_experts else 32,
        vocab=256,
        head_dim=16 if cfg.head_dim else None,
        moe_experts=min(cfg.moe_experts, 8) if cfg.moe_experts else 0,
        moe_topk=min(cfg.moe_topk, 2) if cfg.moe_topk else 0,
        kv_chunk=32,
        remat=False,
        param_dtype=jnp.float32,
        cache_dtype=jnp.float32,
    )
