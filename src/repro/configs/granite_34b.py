"""granite-34b — llama-arch dense, code, MQA (kv=1).
[dense] 88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    source="[arXiv:2405.04324; hf]",
))
