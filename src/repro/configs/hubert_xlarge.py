"""hubert-xlarge — encoder-only audio (w2v2 arch), frame frontend STUB.
[audio] 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504 head_dim=80
[arXiv:2106.07447; unverified]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    causal=False,          # encoder-only: bidirectional attention, no decode
    frontend="stub",       # precomputed frame embeddings via input_specs()
    source="[arXiv:2106.07447; unverified]",
))
