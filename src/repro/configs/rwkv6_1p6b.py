"""rwkv6-1.6b — Finch, attention-free, data-dependent decay.
[ssm] 24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892; unverified]
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # rwkv6 head_dim 64
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    source="[arXiv:2404.05892; unverified]",
))
