"""repro.core — LiLAC: the paper's contribution as a composable JAX module.

Public API (prefer the ``repro.lilac`` facade):
    compile / CompileOptions   the single LiLAC entry point
    spec                       HARNESS-descriptor compiler + @harness
    Detector                   backtracking jaxpr detection
    REGISTRY / Harness         LiLAC-How backends (populated from specs)
    MarshalingCache            mprotect-analogue invariant caching
    what_lang                  the LiLAC spec language (Fig. 3 + §3.3)
    lilac_optimize/accelerate  deprecated shims over compile
"""
from repro.core.autotune import Autotuner, AutotuneCache, signature_of
from repro.core.detect import Detector, DetectionReport, Match, default_detector
from repro.core.harness import (REGISTRY, CallCtx, DuplicateHarnessError,
                                Harness, HarnessRegistry)
from repro.core.marshal import (FORMATS, GRAPH, SOURCES, ConversionEdge,
                                ConversionGraph, DataPlane, MarshalingCache,
                                MarshalPolicy, ReadObject, SparseFormat,
                                TrackedArray, fingerprint, version_token)
from repro.core.pass_manager import (CompileOptions, LilacDeprecationWarning,
                                     LilacFunction, compile, lilac_accelerate,
                                     lilac_optimize)
from repro.core.plan import (ExecutablePlan, PlanBakeError, PlanCache,
                             PlanDonationError)
from repro.core import spec
from repro.core import what_lang

# Populate REGISTRY from the builtin spec texts (jnp.* families) and the
# HARNESS blocks declared next to the Pallas kernels.
spec.register_builtins()

__all__ = [
    "Autotuner", "AutotuneCache", "signature_of",
    "Detector", "DetectionReport", "Match", "default_detector",
    "REGISTRY", "CallCtx", "DuplicateHarnessError", "Harness",
    "HarnessRegistry",
    "MarshalingCache", "DataPlane", "MarshalPolicy", "SparseFormat",
    "ConversionEdge", "ConversionGraph", "FORMATS", "GRAPH", "SOURCES",
    "ReadObject", "TrackedArray", "fingerprint", "version_token",
    "CompileOptions", "LilacDeprecationWarning", "LilacFunction", "compile",
    "lilac_accelerate", "lilac_optimize", "spec", "what_lang",
    "ExecutablePlan", "PlanCache", "PlanBakeError", "PlanDonationError",
]
