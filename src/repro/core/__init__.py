"""repro.core — LiLAC: the paper's contribution as a composable JAX module.

Public API:
    lilac_optimize(fn)    trace-mode rewritten function (jit-compatible)
    lilac_accelerate(fn)  host-mode with marshaling cache (solver apps)
    Detector              backtracking jaxpr detection
    REGISTRY / Harness    LiLAC-How backends
    MarshalingCache       mprotect-analogue invariant caching
    what_lang             the LiLAC-What language (Fig. 3)
"""
from repro.core.autotune import Autotuner, AutotuneCache, signature_of
from repro.core.detect import Detector, DetectionReport, Match, default_detector
from repro.core.harness import REGISTRY, CallCtx, Harness, HarnessRegistry
from repro.core.marshal import MarshalingCache, ReadObject, TrackedArray, fingerprint
from repro.core.pass_manager import LilacFunction, lilac_accelerate, lilac_optimize
from repro.core import what_lang

__all__ = [
    "Autotuner", "AutotuneCache", "signature_of",
    "Detector", "DetectionReport", "Match", "default_detector",
    "REGISTRY", "CallCtx", "Harness", "HarnessRegistry",
    "MarshalingCache", "ReadObject", "TrackedArray", "fingerprint",
    "LilacFunction", "lilac_accelerate", "lilac_optimize", "what_lang",
]
