"""LiLAC detection: backtracking search for What-computations in jaxprs.

The paper (§4.1) detects computations in LLVM IR after -O2 normalization:
first the control-flow skeleton is recognized, then a backtracking search
(Fig. 13) assigns the What-program's expressions to IR values one by one.

The JAX adaptation:

* Normalization  — JAX tracing is the language-independent normalizer
  (Fig. 11/12 analogue); on top of it we inline nested call primitives
  (pjit / custom_jvp / remat) so the matcher sees one flat equation list
  (`normalize_closed_jaxpr`).
* Skeletons      — vectorized JAX has two kinds of "loop nest": the batched
  dimension structure of gather/mul/scatter-add/reduce chains, and actual
  `scan` bodies for loop-style user code.  Both are matched.
* Backtracking   — pattern matching is generator-based: every commutative
  operand order, alternative idiom and candidate assignment is a backtrack
  point; the first complete, semantically validated assignment wins.
* Semantic validation — where the paper relies on exact structural match,
  we additionally *execute* risky sub-graphs (row-pointer expansion, one-hot
  dispatch construction) on random concrete inputs via eval_jaxpr and check
  them against the What-semantics.  A structural false positive therefore
  cannot silently corrupt results.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import core as jcore
from jax.extend import core as jex_core

from repro.core import what_lang as W

Atom = Any   # jex_core.Var | jex_core.Literal
Eqn = Any    # jex_core.JaxprEqn


# ---------------------------------------------------------------------------
# Normalization (the -O2 analogue): inline call-like primitives.
# ---------------------------------------------------------------------------

_INLINE_PRIMS = {
    "jit": lambda p: (p["jaxpr"].jaxpr, p["jaxpr"].consts),
    "pjit": lambda p: (p["jaxpr"].jaxpr, p["jaxpr"].consts),
    "custom_jvp_call": lambda p: (p["call_jaxpr"].jaxpr, p["call_jaxpr"].consts),
    "custom_vjp_call": lambda p: (p["call_jaxpr"].jaxpr, p["call_jaxpr"].consts),
    "remat2": lambda p: (p["jaxpr"], ()),
    "checkpoint": lambda p: (p["jaxpr"], ()),
    "closed_call": lambda p: (p["call_jaxpr"].jaxpr, p["call_jaxpr"].consts),
}


def _inlinable(eqn: Eqn):
    fn = _INLINE_PRIMS.get(eqn.primitive.name)
    if fn is None:
        return None
    try:
        return fn(eqn.params)
    except (KeyError, AttributeError):
        return None


def normalize_closed_jaxpr(cj) -> "jex_core.ClosedJaxpr":
    """Inline nested call primitives into one flat equation list."""
    gen = jcore.gensym()
    out_eqns: List[Eqn] = []
    const_vars: List[Any] = []
    const_vals: List[Any] = []

    def emit(jaxpr, consts, in_atoms):
        env: Dict[Any, Atom] = {}

        def read(atom):
            if isinstance(atom, jex_core.Literal):
                return atom
            return env[atom]

        for cv, cval in zip(jaxpr.constvars, consts):
            v = gen(cv.aval)
            const_vars.append(v)
            const_vals.append(cval)
            env[cv] = v
        for iv, at in zip(jaxpr.invars, in_atoms):
            env[iv] = at
        for eqn in jaxpr.eqns:
            sub = _inlinable(eqn)
            if sub is not None:
                inner, iconsts = sub
                outs = emit(inner, iconsts, [read(x) for x in eqn.invars])
                for ov, o in zip(eqn.outvars, outs):
                    env[ov] = o
            else:
                new_in = [read(x) for x in eqn.invars]
                new_out = [gen(ov.aval) for ov in eqn.outvars]
                out_eqns.append(eqn.replace(invars=new_in, outvars=new_out))
                for ov, nv in zip(eqn.outvars, new_out):
                    env[ov] = nv
        return [read(x) for x in jaxpr.outvars]

    new_invars = [gen(v.aval) for v in cj.jaxpr.invars]
    outs = emit(cj.jaxpr, cj.consts, new_invars)
    # Jaxpr outvars must be atoms; literals are permitted.
    new_jaxpr = jex_core.Jaxpr(
        constvars=const_vars, invars=new_invars, outvars=outs, eqns=out_eqns,
        debug_info=cj.jaxpr.debug_info,
    )
    return jex_core.ClosedJaxpr(new_jaxpr, const_vals)


# ---------------------------------------------------------------------------
# Match context: producer maps, peeling, provenance.
# ---------------------------------------------------------------------------

class Ctx:
    def __init__(self, closed: "jex_core.ClosedJaxpr"):
        self.closed = closed
        self.jaxpr = closed.jaxpr
        self.producer: Dict[Any, Eqn] = {}
        self.eqn_index: Dict[int, int] = {}
        self.consumers: Dict[Any, List[Eqn]] = {}
        for i, eqn in enumerate(self.jaxpr.eqns):
            self.eqn_index[id(eqn)] = i
            for ov in eqn.outvars:
                self.producer[ov] = eqn
            seen_here = set()
            for iv in eqn.invars:
                if isinstance(iv, jex_core.Literal) or id(iv) in seen_here:
                    continue
                seen_here.add(id(iv))
                self.consumers.setdefault(iv, []).append(eqn)
        self.invars = set(self.jaxpr.invars)
        self.outvars = {v for v in self.jaxpr.outvars
                        if not isinstance(v, jex_core.Literal)}
        self.constvar_vals = dict(zip(self.jaxpr.constvars, closed.consts))
        self.log: List[str] = []
        # Per-atom memoization: detection runs every matcher over every
        # anchor, so the same peel chains and provenance closures are
        # requested many times per jaxpr.  Keyed on id() — the atoms are
        # owned by self.jaxpr, which we hold, so ids are stable.
        self._peel_cache: Dict[int, Atom] = {}
        self._prov_cache: Dict[int, Tuple[List[Any], List[Eqn]]] = {}
        self._subjaxpr_cache: Dict[int, Any] = {}
        # semantic-validation verdicts, keyed by the validator on the
        # participating atom ids: identical subgraphs reached through
        # different patterns validate once (and reuse one sampled input
        # set) instead of re-executing per candidate
        self.validation_cache: Dict[Tuple, bool] = {}

    def prod(self, atom) -> Optional[Eqn]:
        if isinstance(atom, jex_core.Literal):
            return None
        return self.producer.get(atom)

    def sole_consumer(self, var) -> Optional[Eqn]:
        """The unique consuming equation of ``var``, or None when the value
        is multiply-consumed or escapes as a function output (fusing it
        away would then change observable results)."""
        if var in self.outvars:
            return None
        cons = self.consumers.get(var, [])
        return cons[0] if len(cons) == 1 else None

    # -- peeling ------------------------------------------------------------

    def peel(self, atom) -> Atom:
        """See through semantics-preserving wrappers:
        convert_element_type, copy, reshape-like broadcast_in_dim (adding a
        trailing unit dim), squeeze, and the negative-index normalization
        triple select_n(lt(x,0), x, x+N) -> x.  Memoized per atom."""
        cached = self._peel_cache.get(id(atom))
        if cached is not None:
            return cached
        visited = [atom]
        out = self._peel(atom, visited)
        for a in visited:
            self._peel_cache[id(a)] = out
        return out

    def _peel(self, atom, visited: List[Atom]) -> Atom:
        while True:
            cached = self._peel_cache.get(id(atom))
            if cached is not None:
                return cached
            if visited and visited[-1] is not atom:
                visited.append(atom)
            eqn = self.prod(atom)
            if eqn is None:
                return atom
            p = eqn.primitive.name
            if p in ("convert_element_type", "copy", "stop_gradient"):
                atom = eqn.invars[0]
                continue
            if p == "squeeze":
                atom = eqn.invars[0]
                continue
            if p == "reshape":
                src = eqn.invars[0]
                if _nonunit_dims(src.aval.shape) == _nonunit_dims(eqn.outvars[0].aval.shape):
                    atom = src
                    continue
                return atom
            if p == "broadcast_in_dim":
                src = eqn.invars[0]
                in_shape = getattr(src.aval, "shape", ())
                out_shape = eqn.outvars[0].aval.shape
                bdims = eqn.params["broadcast_dimensions"]
                # reshape-like: all input dims mapped in order, added dims unit
                if (tuple(bdims) == tuple(range(len(in_shape)))
                        and _nonunit_dims(in_shape) == _nonunit_dims(out_shape)):
                    atom = src
                    continue
                return atom
            if p == "select_n" and len(eqn.invars) == 3:
                pred, case_f, case_t = eqn.invars
                pe = self.prod(pred)
                if pe is not None and pe.primitive.name == "lt":
                    x, zero = pe.invars
                    x = self.peel(x)
                    if _literal_value(zero) == 0 and self.peel(case_f) is x:
                        te = self.prod(self.peel(case_t))
                        if te is not None and te.primitive.name == "add" \
                                and self.peel(te.invars[0]) is x:
                            atom = x
                            continue
                return atom
            return atom

    def is_zeros(self, atom) -> bool:
        atom = self.peel(atom)
        lit = _literal_value(atom)
        if lit is not None:
            return bool(np.all(np.asarray(lit) == 0))
        eqn = self.prod(atom)
        if eqn is not None and eqn.primitive.name == "broadcast_in_dim":
            return self.is_zeros(eqn.invars[0])
        if eqn is None and atom in self.constvar_vals:
            return bool(np.all(np.asarray(self.constvar_vals[atom]) == 0))
        return False

    # -- provenance ----------------------------------------------------------

    def provenance(self, atom) -> Tuple[List[Any], List[Eqn]]:
        """Transitive producer closure: (leaf vars [invars/constvars], eqns
        in original topological order).  Memoized per atom — callers must
        not mutate the returned lists."""
        cached = self._prov_cache.get(id(atom))
        if cached is not None:
            return cached
        eqns: Dict[int, Eqn] = {}
        leaves: List[Any] = []
        seen = set()
        stack = [atom]
        while stack:
            a = stack.pop()
            if isinstance(a, jex_core.Literal) or id(a) in seen:
                continue
            seen.add(id(a))
            eqn = self.prod(a)
            if eqn is None:
                if a not in leaves:
                    leaves.append(a)
                continue
            eqns[self.eqn_index[id(eqn)]] = eqn
            for iv in eqn.invars:
                stack.append(iv)
        ordered = [eqns[i] for i in sorted(eqns)]
        self._prov_cache[id(atom)] = (leaves, ordered)
        return leaves, ordered

    def eval_subgraph(self, out_atom, leaf_values: Dict[Any, np.ndarray]):
        """Concretely evaluate the provenance subgraph of ``out_atom`` given
        values for its leaves — the semantic validation step.  The built
        sub-jaxpr is cached per atom, so repeated validations (multiple
        trials, multiple candidate patterns over the same subgraph) only
        pay jaxpr construction once."""
        sub = self._subjaxpr_cache.get(id(out_atom))
        if sub is None:
            leaves, eqns = self.provenance(out_atom)
            # The parent's debug_info describes the parent's arity; newer
            # jax asserts arg_names/result_paths lengths match, so the
            # sub-jaxpr must drop it entirely.
            sub = jex_core.Jaxpr(
                constvars=(), invars=list(leaves), outvars=[out_atom],
                eqns=eqns, debug_info=None,
            )
            self._subjaxpr_cache[id(out_atom)] = sub
        vals = []
        for lf in sub.invars:
            if lf in leaf_values:
                vals.append(leaf_values[lf])
            elif lf in self.constvar_vals:
                vals.append(self.constvar_vals[lf])
            else:
                raise KeyError(f"no value for leaf {lf}")
        # Force concrete evaluation even when detection runs under an
        # ambient trace (jax.grad / make_jaxpr of a caller that invokes a
        # LilacFunction): all leaf values here are numpy trial inputs or
        # concrete constvars, so the binds must not be swept into the
        # outer trace — a Tracer result would fail np.asarray and make
        # semantic validation spuriously reject.
        with jax.ensure_compile_time_eval():
            (out,) = jcore.eval_jaxpr(sub, [], *vals)
        return np.asarray(out)


def _nonunit_dims(shape) -> Tuple[int, ...]:
    return tuple(d for d in shape if d != 1)


def _literal_value(atom):
    if isinstance(atom, jex_core.Literal):
        return atom.val
    return None


# ---------------------------------------------------------------------------
# Pattern combinators (generator-based backtracking — Fig. 13).
# ---------------------------------------------------------------------------

class Pat:
    def match(self, ctx: Ctx, atom, env: Dict[str, Any]) -> Iterator[Dict[str, Any]]:
        raise NotImplementedError


class B(Pat):
    """Bind the (peeled) atom to a name; if already bound, require identity."""

    def __init__(self, name: str, pred: Optional[Callable] = None):
        self.name = name
        self.pred = pred

    def match(self, ctx, atom, env):
        a = ctx.peel(atom)
        if self.pred is not None and not self.pred(ctx, a):
            return
        if self.name in env:
            if env[self.name] is a:
                yield env
            return
        e2 = dict(env)
        e2[self.name] = a
        ctx.log.append(f"  bind {self.name} := {a}")
        yield e2


class AnyP(Pat):
    def match(self, ctx, atom, env):
        yield env


class P(Pat):
    """Match the producer equation of the atom."""

    def __init__(self, prims, *operands: Pat,
                 params: Optional[Callable[[Dict], bool]] = None,
                 peel: bool = True):
        self.prims = (prims,) if isinstance(prims, str) else tuple(prims)
        self.operands = operands
        self.params = params
        self.do_peel = peel

    def match(self, ctx, atom, env):
        a = ctx.peel(atom) if self.do_peel else atom
        eqn = ctx.prod(a)
        if eqn is None or eqn.primitive.name not in self.prims:
            return
        if self.params is not None and not self.params(eqn.params):
            return
        if len(eqn.invars) < len(self.operands):
            return

        def rec(i, e):
            if i == len(self.operands):
                yield e
                return
            for e2 in self.operands[i].match(ctx, eqn.invars[i], e):
                yield from rec(i + 1, e2)

        yield from rec(0, env)


class Comm(Pat):
    """Commutative binary op: try both operand orders (backtrack point)."""

    def __init__(self, prims, p1: Pat, p2: Pat):
        self.prims = (prims,) if isinstance(prims, str) else tuple(prims)
        self.p1, self.p2 = p1, p2

    def match(self, ctx, atom, env):
        a = ctx.peel(atom)
        eqn = ctx.prod(a)
        if eqn is None or eqn.primitive.name not in self.prims or len(eqn.invars) != 2:
            return
        x, y = eqn.invars
        for first, second in ((x, y), (y, x)):
            ctx.log.append(f"  try {eqn.primitive.name}({first},{second})")
            for e1 in self.p1.match(ctx, first, env):
                for e2 in self.p2.match(ctx, second, e1):
                    yield e2
            ctx.log.append("  backtrack")


class Alt(Pat):
    def __init__(self, *pats: Pat):
        self.pats = pats

    def match(self, ctx, atom, env):
        for p in self.pats:
            yield from p.match(ctx, atom, env)


class ZerosP(Pat):
    def match(self, ctx, atom, env):
        if ctx.is_zeros(atom):
            yield env


def _is_row_gather(params) -> bool:
    dn = params.get("dimension_numbers")
    return (dn is not None
            and tuple(dn.offset_dims) == ()
            and tuple(dn.collapsed_slice_dims) == (0,)
            and tuple(dn.start_index_map) == (0,)
            and tuple(params.get("slice_sizes", ())) == (1,))


def _is_row_scatter(params) -> bool:
    dn = params.get("dimension_numbers")
    return (dn is not None
            and tuple(dn.update_window_dims) == ()
            and tuple(dn.inserted_window_dims) == (0,)
            and tuple(dn.scatter_dims_to_operand_dims) == (0,))


def _is_rowwindow_scatter(params) -> bool:
    """scatter of (nnz, n) row-windows into (rows, n) — the SpMM skeleton."""
    dn = params.get("dimension_numbers")
    return (dn is not None
            and tuple(dn.update_window_dims) == (1,)
            and tuple(dn.inserted_window_dims) == (0,)
            and tuple(dn.scatter_dims_to_operand_dims) == (0,))


def _is_rowwindow_gather(params) -> bool:
    """dense[col] with dense (C, n): rows of a matrix gathered by index."""
    dn = params.get("dimension_numbers")
    ss = tuple(params.get("slice_sizes", ()))
    return (dn is not None
            and tuple(dn.offset_dims) == (1,)
            and tuple(dn.collapsed_slice_dims) == (0,)
            and tuple(dn.start_index_map) == (0,)
            and len(ss) == 2 and ss[0] == 1)


def Gather1D(arr: Pat, idx: Pat) -> Pat:
    """vec[idx] — embedding-style row gather (any index rank)."""
    return P("gather", arr, idx, params=_is_row_gather)


# ---------------------------------------------------------------------------
# Match result
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Match:
    computation: str          # What-program name
    variant: str              # 'vectorized' | 'loop'
    format: str               # CSR / COO / ELL / JDS / DOT / GEMV / MOE
    anchor: Any               # var whose producer eqn gets replaced
    anchor_eqn: Eqn
    binding: Dict[str, Any]   # What-name -> jaxpr atom or python int
    notes: str = ""
    claimed_eqns: Tuple[Any, ...] = ()  # extra eqns covered by this match
    # Detected fused epilogue covering the consumer chain of the core
    # computation: 'relu' | 'silu' (activation, possibly after a bias add
    # bound as binding['bias']) | 'none' (bias only) | None (no epilogue).
    # The anchor is then the *final* epilogue equation: harnesses declaring
    # ``fuse epilogue`` apply it in-kernel, others get it applied by the
    # rewriter — either way the intermediate arrays never materialize in
    # host mode.
    epilogue: Optional[str] = None
    # For variant='scan_body': (normalized body ClosedJaxpr, inner matches).
    # The body was detected ONCE; the rewriter reconstructs the scan around
    # a rewritten body, so the selected kernels are reused every iteration.
    body: Optional[Tuple[Any, List["Match"]]] = None

    def __repr__(self):
        names = {k: (v if isinstance(v, int) else str(v))
                 for k, v in self.binding.items()}
        ep = f" +{self.epilogue}" if self.epilogue else ""
        return (f"Match({self.computation}/{self.format} [{self.variant}]"
                f"{ep} @ {self.anchor} {names})")


@dataclasses.dataclass
class DetectionReport:
    matches: List[Match]
    n_eqns: int
    log: List[str]

    def by_computation(self) -> Dict[str, List[Match]]:
        out: Dict[str, List[Match]] = {}
        for m in self.matches:
            out.setdefault(m.computation, []).append(m)
        return out

    def summary(self) -> str:
        lines = [f"{len(self.matches)} match(es) in {self.n_eqns} equations"]
        lines += [f"  {m!r}" for m in self.matches]
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Semantic validators
# ---------------------------------------------------------------------------

def _validate_row_expansion(ctx: Ctx, row_atom, row_ptr_var, nnz: int,
                            rows: int, trials: int = 2) -> bool:
    """Check the subgraph row_ptr -> row_ids really is CSR row expansion:
    out == repeat(arange(rows), diff(row_ptr)) for random valid row_ptrs.

    Verdicts are memoized on the (row_atom, row_ptr_var) identity: every
    pattern that reaches the same expansion subgraph (CSR SpMV, SpMM, the
    COO fallback probing) shares one concrete evaluation instead of
    re-sampling and re-executing it per candidate."""
    key = ("row_expansion", id(row_atom), id(row_ptr_var), nnz, rows)
    cached = ctx.validation_cache.get(key)
    if cached is not None:
        return cached
    rng = np.random.default_rng(0)
    ok = True
    for _ in range(trials):
        cuts = np.sort(rng.integers(0, nnz + 1, size=max(rows - 1, 0)))
        rp = np.concatenate([[0], cuts, [nnz]]).astype(np.int32)
        expect = np.repeat(np.arange(rows, dtype=np.int32), np.diff(rp))
        try:
            got = ctx.eval_subgraph(row_atom, {row_ptr_var: rp})
        except Exception:
            ok = False
            break
        if got.shape != (nnz,) or not np.array_equal(got.astype(np.int64),
                                                     expect.astype(np.int64)):
            ok = False
            break
    ctx.validation_cache[key] = ok
    return ok


def _validate_onehot_dispatch(ctx: Ctx, combine_atom, idx_var, gate_var,
                              n_experts: int) -> bool:
    """combine[t,e] must equal sum_k gate[t,k] * (idx[t,k] == e).
    Verdict memoized per (combine, idx, gate) subgraph."""
    key = ("onehot", id(combine_atom), id(idx_var), id(gate_var), n_experts)
    cached = ctx.validation_cache.get(key)
    if cached is not None:
        return cached
    t, k = idx_var.aval.shape
    rng = np.random.default_rng(0)
    idx = rng.integers(0, n_experts, size=(t, k)).astype(np.int32)
    gate = rng.standard_normal((t, k)).astype(np.float32)
    expect = np.zeros((t, n_experts), np.float32)
    for ti in range(t):
        for ki in range(k):
            expect[ti, idx[ti, ki]] += gate[ti, ki]
    try:
        got = ctx.eval_subgraph(combine_atom, {idx_var: idx, gate_var: gate})
    except Exception:
        ctx.validation_cache[key] = False
        return False
    ok = got.shape == expect.shape and np.allclose(got, expect, atol=1e-5)
    ctx.validation_cache[key] = ok
    return ok


# ---------------------------------------------------------------------------
# Matchers, generated from What-ASTs.
# ---------------------------------------------------------------------------

def _updates_pattern_from_expr(expr: W.Expr, loopvar: str) -> Pat:
    """Compile the What reduction body into a vectorized-updates pattern:
    loads indexed by the loop variable become whole-array binds; loads
    indexed through another array become gathers (Fig. 13's assignment
    targets)."""
    if isinstance(expr, W.Mul):
        return Comm("mul",
                    _updates_pattern_from_expr(expr.lhs, loopvar),
                    _updates_pattern_from_expr(expr.rhs, loopvar))
    if isinstance(expr, W.Add):
        return Comm("add",
                    _updates_pattern_from_expr(expr.lhs, loopvar),
                    _updates_pattern_from_expr(expr.rhs, loopvar))
    if isinstance(expr, W.Load):
        idx = expr.index
        if isinstance(idx, W.Var) and idx.name == loopvar:
            return B(expr.array)                       # a[j] -> whole array
        if isinstance(idx, W.Load) and isinstance(idx.index, W.Var) \
                and idx.index.name == loopvar:
            return Gather1D(B(expr.array), B(idx.array))  # iv[colidx[j]]
        # composite index (2D padded layouts): bind the whole array; the
        # skeleton match constrains the shape.
        return B(expr.array)
    if isinstance(expr, W.Var):
        return B(expr.name)
    if isinstance(expr, W.Const):
        return AnyP()
    raise TypeError(expr)


def _range_is_ragged(rng: W.Range, outer_var: str) -> bool:
    def uses_outer_load(e: W.Expr) -> bool:
        if isinstance(e, W.Load):
            return True
        if isinstance(e, (W.Add, W.Mul)):
            return uses_outer_load(e.lhs) or uses_outer_load(e.rhs)
        return False
    return uses_outer_load(rng.lo) or uses_outer_load(rng.hi)


class Matcher:
    """A generated detection function for one What-program."""

    computation: str
    anchor_prims: Tuple[str, ...] = ()

    def match_eqn(self, ctx: Ctx, eqn: Eqn) -> Optional[Match]:
        raise NotImplementedError


class RaggedRowMatcher(Matcher):
    """CSR / COO SpMV: the vectorized realization of

        forall(i) { out[i] = sum(ragged range(i)) expr(j) }

    is scatter-add(zeros, row_ids, updates).  row_ids provenance decides the
    format: a raw vector input -> COO; a validated expansion of a single
    (rows+1,) pointer vector -> CSR (binding the paper's `rowstr`)."""

    anchor_prims = ("scatter-add",)

    def __init__(self, comp: W.Computation):
        self.computation = comp.name
        stmt = comp.stmt()
        self.updates_pat = _updates_pattern_from_expr(stmt.expr, stmt.range.var)
        self.row_ptr_name = (stmt.range.lo.array
                             if isinstance(stmt.range.lo, W.Load) else "rowstr")
        self.out_name = (stmt.target.array
                         if isinstance(stmt.target, W.Load) else "output")

    def match_eqn(self, ctx, eqn):
        if eqn.primitive.name != "scatter-add" or not _is_row_scatter(eqn.params):
            return None
        operand, indices, updates = eqn.invars[:3]
        if updates.aval.ndim != 1:
            return None
        if not ctx.is_zeros(operand):
            return None
        env0: Dict[str, Any] = {}
        for env in self.updates_pat.match(ctx, updates, env0):
            row_atom = ctx.peel(indices)
            nnz = updates.aval.shape[0]
            rows = eqn.outvars[0].aval.shape[0]
            fmt, binding = self._classify_rows(ctx, row_atom, nnz, rows, env)
            if fmt is None:
                continue
            binding = dict(binding)
            binding["rows"] = rows
            binding["nnz"] = nnz
            return Match(self.computation, "vectorized", fmt,
                         eqn.outvars[0], eqn, binding)
        return None

    def _classify_rows(self, ctx, row_atom, nnz, rows, env):
        prod = ctx.prod(row_atom)
        if prod is None:
            b = dict(env)
            b["rowidx"] = row_atom
            return "COO", b
        leaves, _ = ctx.provenance(row_atom)
        ptr_leaves = [l for l in leaves
                      if getattr(l.aval, "shape", None) == (rows + 1,)
                      and np.issubdtype(l.aval.dtype, np.integer)]
        if len(ptr_leaves) == 1 and _validate_row_expansion(
                ctx, row_atom, ptr_leaves[0], nnz, rows):
            b = dict(env)
            b[self.row_ptr_name] = ptr_leaves[0]
            return "CSR", b
        # derived row vector: still COO with the intermediate var
        b = dict(env)
        b["rowidx"] = row_atom
        return "COO", b


class SpmmMatcher(Matcher):
    """SpMM (CSR x dense matrix): the doubly-forall What-program realizes
    as scatter-add of row windows:

        out = scatter-add(zeros(rows,n), row_ids,
                          mul(broadcast(a), gather_rows(dense, colidx)))
    """

    anchor_prims = ("scatter-add",)

    def __init__(self, comp: W.Computation):
        self.computation = comp.name
        self.updates_pat = Comm(
            "mul",
            P("broadcast_in_dim", B("a", pred=_is_1d), peel=False),
            P("gather", B("dense", pred=_is_2d), B("colidx"),
              params=_is_rowwindow_gather),
        )

    def match_eqn(self, ctx, eqn):
        if eqn.primitive.name != "scatter-add" \
                or not _is_rowwindow_scatter(eqn.params):
            return None
        operand, indices, updates = eqn.invars[:3]
        if updates.aval.ndim != 2 or not ctx.is_zeros(operand):
            return None
        for env in self.updates_pat.match(ctx, updates, {}):
            row_atom = ctx.peel(indices)
            nnz = updates.aval.shape[0]
            rows = eqn.outvars[0].aval.shape[0]
            leaves, _ = ctx.provenance(row_atom) \
                if ctx.prod(row_atom) is not None else ([], [])
            binding = dict(env)
            binding.update(rows=rows, nnz=nnz,
                           ncols=updates.aval.shape[1])
            ptr_leaves = [l for l in leaves
                          if getattr(l.aval, "shape", None) == (rows + 1,)
                          and np.issubdtype(l.aval.dtype, np.integer)]
            if len(ptr_leaves) == 1 and _validate_row_expansion(
                    ctx, row_atom, ptr_leaves[0], nnz, rows):
                binding["rowstr"] = ptr_leaves[0]
                return Match(self.computation, "vectorized", "CSR",
                             eqn.outvars[0], eqn, binding)
            binding["rowidx"] = row_atom
            return Match(self.computation, "vectorized", "COO",
                         eqn.outvars[0], eqn, binding)
        return None


class PaddedRowMatcher(Matcher):
    """ELL (and JDS, which adds a perm scatter on the output):

        forall(i) { out[i] = sum(0<=j<width) val2d[i,j]*vec[col2d[i,j]] }

    vectorized: reduce_sum(axis=1)(mul(val2d, gather(vec, col2d)))."""

    anchor_prims = ("reduce_sum", "scatter")

    def __init__(self, comp: W.Computation, jds: bool):
        self.computation = comp.name
        self.jds = jds
        self.core_pat = P(
            "reduce_sum",
            Comm("mul", B("val", pred=_is_2d), Gather1D(B("vector"), B("col_ind"))),
            params=lambda p: tuple(p.get("axes", ())) == (1,),
        )

    def match_eqn(self, ctx, eqn):
        if self.jds:
            # scatter(zeros, perm, core): out[perm[i]] = core[i]
            if eqn.primitive.name != "scatter" or not _is_row_scatter(eqn.params):
                return None
            operand, indices, updates = eqn.invars[:3]
            if not ctx.is_zeros(operand):
                return None
            for env in self.core_pat.match(ctx, updates, {}):
                env = dict(env)
                env["perm"] = ctx.peel(indices)
                env["rows"] = eqn.outvars[0].aval.shape[0]
                core_eqn = ctx.prod(ctx.peel(updates))
                return Match(self.computation, "vectorized", "JDS",
                             eqn.outvars[0], eqn, env,
                             claimed_eqns=(core_eqn,) if core_eqn else ())
            return None
        if eqn.primitive.name != "reduce_sum":
            return None
        for env in self.core_pat.match(ctx, eqn.outvars[0], {}):
            env = dict(env)
            env["rows"] = eqn.outvars[0].aval.shape[0]
            return Match(self.computation, "vectorized", "ELL",
                         eqn.outvars[0], eqn, env)
        return None


def _is_2d(ctx, atom):
    return getattr(atom.aval, "ndim", 0) == 2


def _is_1d(ctx, atom):
    return getattr(atom.aval, "ndim", 0) == 1


class DotMatcher(Matcher):
    """result = sum(i) a[i]*b[i] — vectorized (reduce_sum∘mul or dot_general)
    and loop (scan accumulating a[i]*b[i]) skeletons."""

    anchor_prims = ("reduce_sum", "dot_general", "scan")

    def __init__(self, comp: W.Computation):
        self.computation = comp.name
        stmt = comp.stmt()
        self.vec_pat = Alt(
            P("reduce_sum",
              Comm("mul", B("a", pred=_is_1d), B("b", pred=_is_1d)),
              params=lambda p: tuple(p.get("axes", ())) == (0,)),
            P("dot_general", B("a", pred=_is_1d), B("b", pred=_is_1d),
              params=lambda p: p.get("dimension_numbers")
              == (((0,), (0,)), ((), ()))),
        )

    def match_eqn(self, ctx, eqn):
        if eqn.primitive.name == "scan":
            return _match_scan_dot(ctx, eqn, self.computation)
        if eqn.outvars[0].aval.ndim != 0:
            return None
        for env in self.vec_pat.match(ctx, eqn.outvars[0], {}):
            env = dict(env)
            env["length"] = env["a"].aval.shape[0]
            return Match(self.computation, "vectorized", "DOT",
                         eqn.outvars[0], eqn, env)
        return None


class GemvMatcher(Matcher):
    """Dense matrix-vector product (paper: 'we fully support dense')."""

    anchor_prims = ("dot_general", "reduce_sum")

    def __init__(self, comp: W.Computation):
        self.computation = comp.name

    def match_eqn(self, ctx, eqn):
        if eqn.primitive.name == "dot_general":
            dn = eqn.params.get("dimension_numbers")
            lhs, rhs = eqn.invars
            if (dn == (((1,), (0,)), ((), ()))
                    and lhs.aval.ndim == 2 and rhs.aval.ndim == 1):
                return Match(self.computation, "vectorized", "GEMV",
                             eqn.outvars[0], eqn,
                             {"mat": ctx.peel(lhs), "vec": ctx.peel(rhs),
                              "rows": lhs.aval.shape[0],
                              "cols": lhs.aval.shape[1]})
            return None
        if eqn.primitive.name == "reduce_sum" \
                and tuple(eqn.params.get("axes", ())) == (1,):
            pat = Comm("mul", B("mat", pred=_is_2d),
                       P("broadcast_in_dim", B("vec", pred=_is_1d),
                         params=lambda p: tuple(p["broadcast_dimensions"]) == (1,),
                         peel=False))
            for env in pat.match(ctx, eqn.outvars[0], {}):
                env = dict(env)
                env["rows"] = env["mat"].aval.shape[0]
                env["cols"] = env["mat"].aval.shape[1]
                return Match(self.computation, "vectorized", "GEMV",
                             eqn.outvars[0], eqn, env)
        return None


# -- scan (loop skeleton) matching ------------------------------------------

def _elem_load(ctx: Ctx, body_ctx: "Ctx", atom, counter_var):
    """Match squeeze(dynamic_slice(ARR, counter)) inside a scan body; return
    ARR (a body var) or None."""
    a = body_ctx.peel(atom)
    eqn = body_ctx.prod(a)
    if eqn is None or eqn.primitive.name != "dynamic_slice":
        return None
    arr, idx = eqn.invars[0], eqn.invars[1]
    if body_ctx.peel(idx) is not counter_var:
        return None
    return arr


def _match_scan_coo(ctx: Ctx, eqn: Eqn, computation: str) -> Optional[Match]:
    """fori_loop COO accumulation:
        body: (i, acc) -> (i+1, scatter-add(acc, row[i], val[i]*vec[col[i]]))
    """
    params = eqn.params
    if params.get("num_carry", 0) != 2:
        return None
    body = params["jaxpr"].jaxpr
    nconsts = params["num_consts"]
    body_ctx = Ctx(jex_core.ClosedJaxpr(body, params["jaxpr"].consts))
    counter_in, acc_in = body.invars[nconsts], body.invars[nconsts + 1]
    counter_out, acc_out = body.outvars[0], body.outvars[1]
    # counter increments by one
    ce = body_ctx.prod(body_ctx.peel(counter_out))
    if ce is None or ce.primitive.name != "add" \
            or body_ctx.peel(ce.invars[0]) is not counter_in:
        return None
    se = body_ctx.prod(body_ctx.peel(acc_out))
    if se is None or se.primitive.name != "scatter-add" \
            or not _is_row_scatter(se.params):
        return None
    operand, indices, updates = se.invars[:3]
    if body_ctx.peel(operand) is not acc_in:
        return None
    row_arr = _elem_load(ctx, body_ctx, indices, counter_in)
    if row_arr is None:
        return None
    ue = body_ctx.prod(body_ctx.peel(updates))
    if ue is None or ue.primitive.name != "mul":
        return None
    for val_at, gather_at in ((ue.invars[0], ue.invars[1]),
                              (ue.invars[1], ue.invars[0])):
        val_arr = _elem_load(ctx, body_ctx, val_at, counter_in)
        if val_arr is None:
            continue
        ge = body_ctx.prod(body_ctx.peel(gather_at))
        if ge is None or ge.primitive.name != "dynamic_slice":
            continue
        vec_arr, vidx = ge.invars[0], body_ctx.peel(ge.invars[1])
        col_arr = _elem_load(ctx, body_ctx, vidx, counter_in)
        if col_arr is None:
            continue
        # map body consts back to outer atoms
        def outer(v):
            i = body.invars.index(v)
            if i >= nconsts:
                return None
            return eqn.invars[i]
        o_row, o_val, o_col, o_vec = map(outer, (row_arr, val_arr, col_arr, vec_arr))
        if None in (o_row, o_val, o_col, o_vec):
            continue
        init_acc = eqn.invars[nconsts + 1]
        if not ctx.is_zeros(init_acc):
            continue
        binding = {"a": ctx.peel(o_val), "rowidx": ctx.peel(o_row),
                   "colidx": ctx.peel(o_col), "iv": ctx.peel(o_vec),
                   "rows": eqn.outvars[1].aval.shape[0],
                   "nnz": params["length"]}
        return Match(computation, "loop", "COO", eqn.outvars[1], eqn, binding,
                     notes="fori_loop skeleton")
    return None


def _match_scan_dot(ctx: Ctx, eqn: Eqn, computation: str) -> Optional[Match]:
    """fori_loop dot product: body: (i, acc) -> (i+1, acc + a[i]*b[i])."""
    params = eqn.params
    if params.get("num_carry", 0) != 2:
        return None
    body = params["jaxpr"].jaxpr
    nconsts = params["num_consts"]
    body_ctx = Ctx(jex_core.ClosedJaxpr(body, params["jaxpr"].consts))
    counter_in, acc_in = body.invars[nconsts], body.invars[nconsts + 1]
    acc_out = body.outvars[1]
    if getattr(acc_out.aval, "ndim", None) != 0:
        return None
    ae = body_ctx.prod(body_ctx.peel(acc_out))
    if ae is None or ae.primitive.name != "add":
        return None
    for acc_at, prod_at in ((ae.invars[0], ae.invars[1]),
                            (ae.invars[1], ae.invars[0])):
        if body_ctx.peel(acc_at) is not acc_in:
            continue
        me = body_ctx.prod(body_ctx.peel(prod_at))
        if me is None or me.primitive.name != "mul":
            continue
        a_arr = _elem_load(ctx, body_ctx, me.invars[0], counter_in)
        b_arr = _elem_load(ctx, body_ctx, me.invars[1], counter_in)
        if a_arr is None or b_arr is None:
            continue

        def outer(v):
            i = body.invars.index(v)
            return eqn.invars[i] if i < nconsts else None

        o_a, o_b = outer(a_arr), outer(b_arr)
        if o_a is None or o_b is None:
            continue
        if not ctx.is_zeros(eqn.invars[nconsts + 1]):
            continue
        return Match(computation, "loop", "DOT", eqn.outvars[1], eqn,
                     {"a": ctx.peel(o_a), "b": ctx.peel(o_b),
                      "length": params["length"]},
                     notes="fori_loop skeleton")
    return None


class CooLoopMatcher(Matcher):
    anchor_prims = ("scan",)

    def __init__(self, comp: W.Computation):
        self.computation = comp.name

    def match_eqn(self, ctx, eqn):
        if eqn.primitive.name != "scan":
            return None
        return _match_scan_coo(ctx, eqn, self.computation)


class MoeMatcher(Matcher):
    """The MoE expert FFN with one-hot dispatch (naive dense realization):

        combine (T,E) = einsum('tke,tk->te', onehot(idx), gate)
        g = einsum('td,edf->etf', x, wg); u = einsum('td,edf->etf', x, wu)
        y = einsum('etf,efd->etd', silu(g)*u, wd)
        out = einsum('te,etd->td', combine, y)

    Anchored at the final batched dot_general; the combine operand is
    semantically validated to be a top-k one-hot dispatch of (idx, gate)."""

    anchor_prims = ("dot_general",)

    def __init__(self, comp: W.Computation):
        self.computation = comp.name

        def expert_mm(w):
            # einsum('td,edf->etf', x, w) lowers to
            # transpose(0,2,1)(dot_general(w, x; contract d, no batch))
            inner = P("dot_general", B(w), B("x", pred=_is_2d),
                      params=lambda p: p.get("dimension_numbers")
                      == (((1,), (1,)), ((), ())))
            return Alt(
                P("transpose", inner,
                  params=lambda p: tuple(p.get("permutation", ())) == (0, 2, 1)),
                P("dot_general", B("x", pred=_is_2d), B(w),
                  params=lambda p: p.get("dimension_numbers")
                  == (((1,), (1,)), ((), ()))),
            )

        h_pat = Comm("mul",
                     Comm("mul", expert_mm("wg"), P("logistic", expert_mm("wg"))),
                     expert_mm("wu"))
        self.y_pat = P(
            "dot_general", h_pat, B("wd"),
            params=lambda p: p.get("dimension_numbers")
            == (((2,), (1,)), ((0,), (0,))))

    def match_eqn(self, ctx, eqn):
        if eqn.primitive.name != "dot_general":
            return None
        dn = eqn.params.get("dimension_numbers")
        # einsum('te,etd->td'): contract e, batch t
        if dn != (((1,), (0,)), ((0,), (1,))):
            return None
        combine, y = eqn.invars
        if combine.aval.ndim != 2 or y.aval.ndim != 3:
            return None
        n_experts = combine.aval.shape[1]
        for env in self.y_pat.match(ctx, y, {}):
            leaves, _ = ctx.provenance(ctx.peel(combine))
            int_leaves = [l for l in leaves
                          if np.issubdtype(getattr(l.aval, "dtype", np.float32),
                                           np.integer)]
            float_leaves = [l for l in leaves if l not in int_leaves]
            if len(int_leaves) != 1 or len(float_leaves) != 1:
                continue
            idx_var, gate_var = int_leaves[0], float_leaves[0]
            if not _validate_onehot_dispatch(ctx, ctx.peel(combine),
                                             idx_var, gate_var, n_experts):
                continue
            binding = dict(env)
            binding.update(idx=idx_var, gate=gate_var,
                           experts=n_experts,
                           tokens=combine.aval.shape[0],
                           topk=idx_var.aval.shape[-1])
            return Match(self.computation, "vectorized", "MOE",
                         eqn.outvars[0], eqn, binding)
        return None


# ---------------------------------------------------------------------------
# Matcher generation (What-AST -> detection function) + top-level detect().
# ---------------------------------------------------------------------------

def generate_matcher(comp: W.Computation) -> List[Matcher]:
    """The paper generates C++ detection functions from LiLAC-What at LLVM
    build time; we generate matcher objects from the AST at import time."""
    if comp.name == "moe_ffn":
        return [MoeMatcher(comp)]
    foralls = comp.foralls()
    stmt = comp.stmt()
    if len(foralls) == 2 and _range_is_ragged(stmt.range, foralls[0].range.var):
        return [SpmmMatcher(comp)]   # doubly-parallel ragged = SpMM
    if not foralls and isinstance(stmt.target, W.Var):
        return [DotMatcher(comp)]
    if len(foralls) == 1:
        # permuted output target (JDS) takes precedence: its inner range is
        # "ragged" in the What-text (nzcnt[i]) but the vectorized realization
        # is the padded 2D layout with a perm scatter.
        if isinstance(stmt.target, W.Load) and isinstance(stmt.target.index, W.Load):
            return [PaddedRowMatcher(comp, jds=True)]
        if _range_is_ragged(stmt.range, foralls[0].range.var):
            return [RaggedRowMatcher(comp), CooLoopMatcher(comp)]
        if comp.name == "gemv":
            return [GemvMatcher(comp)]
        # dense inner range with 2D loads -> padded rows
        return [PaddedRowMatcher(comp, jds=False)]
    raise NotImplementedError(f"cannot generate matcher for {comp.name}")


# ---------------------------------------------------------------------------
# Fused-epilogue extension: grow spmv/spmm matches down their consumer chain
# through (+bias) -> (relu | silu), so the harness replaces the whole fused
# subgraph and the intermediate output-size arrays never round-trip memory.
# ---------------------------------------------------------------------------

_EPILOGUE_COMPS = ("spmv_csr", "spmv_coo", "spmm_csr", "spmv_ell", "spmv_jds")


def _broadcastable_to(shape, out_shape) -> bool:
    try:
        return np.broadcast_shapes(tuple(shape), tuple(out_shape)) \
            == tuple(out_shape)
    except ValueError:
        return False


def _is_relu(ctx: Ctx, eqn: Eqn, cur) -> bool:
    """max(cur, 0) in either operand order (jax.nn.relu normalizes here)."""
    if eqn.primitive.name != "max" or len(eqn.invars) != 2:
        return False
    x, y = eqn.invars
    if ctx.peel(x) is cur:
        return ctx.is_zeros(y)
    if ctx.peel(y) is cur:
        return ctx.is_zeros(x)
    return False


def extend_epilogue(ctx: Ctx, m: Match) -> Match:
    """Walk the sole-consumer chain of a vectorized spmv/spmm match through
    an optional bias add and an optional relu/silu activation; on success,
    return a widened match anchored at the chain's last equation with the
    original anchor (and intermediates) claimed.  Escaping values (multiple
    consumers, function outputs) stop the walk — fusing them away would
    change observable results."""
    if m.computation not in _EPILOGUE_COMPS or m.variant != "vectorized":
        return m
    cur_eqn = m.anchor_eqn
    cur = cur_eqn.outvars[0]
    out_shape = tuple(getattr(cur.aval, "shape", ()))
    claimed: List[Eqn] = []
    bias = None
    epilogue: Optional[str] = None
    while epilogue is None:
        if cur in ctx.outvars:
            break
        cons = [e for e in ctx.consumers.get(cur, ())]
        if len(cons) == 1:
            e = cons[0]
            p = e.primitive.name
            if p in ("convert_element_type", "copy"):
                claimed.append(e)
                cur_eqn, cur = e, e.outvars[0]
                continue
            if p == "add" and bias is None:
                x, y = e.invars
                other = y if ctx.peel(x) is cur else (
                    x if ctx.peel(y) is cur else None)
                if other is None:
                    break
                b = ctx.peel(other)
                bshape = tuple(getattr(b.aval, "shape", ()))
                if not _broadcastable_to(bshape, out_shape):
                    break
                bias = b
                claimed.append(e)
                cur_eqn, cur = e, e.outvars[0]
                continue
            if _is_relu(ctx, e, cur):
                epilogue = "relu"
                claimed.append(e)
                cur_eqn, cur = e, e.outvars[0]
                continue
            break
        if len(cons) == 2:
            # silu: cur feeds both logistic(cur) and mul(cur, logistic(cur))
            log_e = next((e for e in cons
                          if e.primitive.name == "logistic"), None)
            mul_e = next((e for e in cons if e.primitive.name == "mul"), None)
            if log_e is None or mul_e is None:
                break
            log_out = log_e.outvars[0]
            if ctx.sole_consumer(log_out) is not mul_e:
                break
            operands = {id(ctx.peel(v)) for v in mul_e.invars}
            if operands != {id(cur), id(ctx.peel(log_out))}:
                break
            epilogue = "silu"
            claimed.extend([log_e, mul_e])
            cur_eqn, cur = mul_e, mul_e.outvars[0]
            continue
        break
    if bias is None and epilogue is None:
        return m
    binding = dict(m.binding)
    if bias is not None:
        binding["bias"] = bias
    return dataclasses.replace(
        m, anchor=cur, anchor_eqn=cur_eqn, binding=binding,
        epilogue=epilogue or "none",
        claimed_eqns=m.claimed_eqns + (m.anchor_eqn,)
        + tuple(e for e in claimed if e is not cur_eqn),
        notes=(m.notes + " " if m.notes else "") + "fused epilogue")


_DEFAULT_PRIORITY = ["moe_ffn", "spmm_csr", "spmv_csr", "spmv_jds",
                     "spmv_ell", "spmv_coo", "gemv", "dotproduct"]


class Detector:
    def __init__(self, computations: Optional[Sequence[W.Computation]] = None,
                 fuse_epilogues: bool = True, scan_bodies: bool = True):
        self.fuse_epilogues = fuse_epilogues
        self.scan_bodies = scan_bodies
        if computations is not None:
            comps = list(computations)
            lenient = False
        else:
            # priority order first, then any spec-registered extras
            names = [n for n in _DEFAULT_PRIORITY if n in W.BUILTINS]
            names += [n for n in W.BUILTINS if n not in names]
            comps = [W.BUILTINS[n] for n in names]
            lenient = True
        self.matchers: List[Matcher] = []
        self.unmatchable: List[str] = []
        for c in comps:
            try:
                self.matchers.extend(generate_matcher(c))
            except NotImplementedError:
                # a spec-registered computation with no matcher skeleton
                # must not break detection of everything else
                if not lenient:
                    raise
                self.unmatchable.append(c.name)

    def detect(self, closed_jaxpr, normalize: bool = True) -> DetectionReport:
        cj = normalize_closed_jaxpr(closed_jaxpr) if normalize else closed_jaxpr
        ctx = Ctx(cj)
        matches: List[Match] = []
        claimed: set = set()
        # matcher-major iteration: matchers are in priority order (e.g. JDS
        # outranks its own ELL core; CSR outranks COO-as-fallback).
        for m in self.matchers:
            for eqn in cj.jaxpr.eqns:
                if m.anchor_prims and eqn.primitive.name not in m.anchor_prims:
                    continue
                if id(eqn) in claimed:
                    continue
                found = m.match_eqn(ctx, eqn)
                if found is not None:
                    matches.append(found)
                    claimed.add(id(eqn))
                    for ce in found.claimed_eqns:
                        claimed.add(id(ce))
        if self.scan_bodies:
            matches += self._detect_scan_bodies(cj, claimed)
        if self.fuse_epilogues:
            matches = [extend_epilogue(ctx, m) for m in matches]
        matches.sort(key=lambda mm: ctx.eqn_index.get(id(mm.anchor_eqn), 0))
        return DetectionReport(matches=matches, n_eqns=len(cj.jaxpr.eqns),
                               log=ctx.log)

    def _detect_scan_bodies(self, cj, claimed: set) -> List[Match]:
        """Descend into unclaimed ``scan`` equations (training loops,
        microbatch accumulation) and detect inside the body jaxpr — once.
        The whole scan becomes one ``variant='scan_body'`` match carrying
        the normalized body and its inner matches; the rewriter rebuilds
        the scan around a rewritten body, so the kernels selected here are
        reused on every iteration instead of being re-detected."""
        out: List[Match] = []
        for eqn in cj.jaxpr.eqns:
            if eqn.primitive.name != "scan" or id(eqn) in claimed:
                continue
            try:
                body_closed = eqn.params["jaxpr"]
                norm = normalize_closed_jaxpr(body_closed)
            except Exception:
                continue
            sub = self.detect(norm, normalize=False)
            if not sub.matches:
                continue
            # the scan's operands must stay live through the rewrite: bind
            # them so needed_eqn_ids keeps their producers
            binding = {f"scan_in{i}": v for i, v in enumerate(eqn.invars)
                       if not isinstance(v, jex_core.Literal)}
            out.append(Match(
                computation="scan_body", variant="scan_body", format="SCAN",
                anchor=eqn.outvars[0], anchor_eqn=eqn, binding=binding,
                notes=f"{len(sub.matches)} match(es) in scan body",
                body=(norm, sub.matches)))
            claimed.add(id(eqn))
        return out

    def detect_fn(self, fn: Callable, *example_args, **kw) -> DetectionReport:
        cj = jax.make_jaxpr(fn)(*example_args, **kw)
        return self.detect(cj)


_default_detector: Optional[Detector] = None


def default_detector() -> Detector:
    global _default_detector
    if _default_detector is None:
        _default_detector = Detector()
    return _default_detector


def reset_default_detector() -> None:
    """Drop the cached detector so newly spec-registered computations are
    picked up by the next ``default_detector()`` call."""
    global _default_detector
    _default_detector = None
