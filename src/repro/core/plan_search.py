"""Joint whole-program plan optimization (ROADMAP: Linnea-inspired).

Per-match selection (``autotune.Autotuner.select``) is a greedy argmin:
each match independently minimizes ``kernel + marshal/reuse`` as if it
were the only harness call in the program.  But PR 3's shared data plane
makes choices *coupled*: picking BCSR for match A turns match B's
CSR->BCSR repack into a cost-0 ride on A's cached buffer, so the
independently-optimal picks can be jointly wrong — a program with two
spmv matches on the same matrix may greedily pick the repack-free backend
twice when paying one shared repack and running the faster kernel twice
is cheaper end to end (Linnea, arXiv:1912.12924: generalized-cost search
over whole-program variant assignments beats local greedy choices).

This module is that search, run by the pass manager once per
``CompiledEntry`` after every match has a definitive per-match decision:

* one :class:`Candidate` per measured (harness, schedule, fuse) variant,
  built from the autotune cache's schema-4 per-candidate components —
  nothing is re-timed;
* marshal requirements (:class:`MarshalReq`) carry the *matrix identity*
  (the binding atoms the repack keys on), so the cost model knows when
  two matches marshal the same operand;
* :func:`search` beam-searches joint assignments over all matches.  The
  shared marshal term uses ``ConversionGraph.plan_cost`` as the oracle:
  a format another assignment already builds enters at cost 0, a partial
  prefix (e.g. a cached DENSE when BCSR is wanted) enters at the
  remaining edges' EWMA cost, everything amortized by
  ``MarshalPolicy.reuse``;
* per-match priors (the pinned winners) rank first in every candidate
  table, and the result is clamped to never cost more than the greedy
  baselines — widening the beam can only help.

Knob: ``LILAC_SEARCH_BEAM`` — beam width (default 8); ``0`` disables the
joint pass entirely (pure per-match greedy, the pre-search behavior).
See docs/tuning.md ("Joint plan search").
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

ENV_BEAM = "LILAC_SEARCH_BEAM"
DEFAULT_BEAM = 8


def beam_width() -> int:
    """Joint-search beam width from ``LILAC_SEARCH_BEAM`` (default 8;
    0 disables the joint pass)."""
    try:
        return int(os.environ.get(ENV_BEAM, DEFAULT_BEAM))
    except ValueError:
        return DEFAULT_BEAM


# ---------------------------------------------------------------------------
# Search space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MarshalReq:
    """One marshal clause of a candidate, as the cost model sees it.

    ``matrix`` is a hashable identity of the operands the repack keys on
    (binding atoms) — two requirements with equal ``(matrix, src)`` feed
    from the same cached intermediates.  ``full_s`` is the measured
    full-path cost from the binding; ``scale`` converts the conversion
    graph's (EWMA-estimated) path costs into the same units, so partial
    prefix rides are priced consistently with the measured total.  Legacy
    format-less clauses have ``src = dst = None``: fixed cost, never
    shared."""
    matrix: Any
    src: Optional[str]
    dst: Optional[str]
    full_s: float = 0.0
    scale: float = 1.0


@dataclasses.dataclass
class Candidate:
    """One measured (harness, schedule, fuse) variant of one match."""
    harness: str
    kernel_s: float
    schedule: Optional[Dict[str, Any]] = None
    fuse: Optional[bool] = None
    reqs: Tuple[MarshalReq, ...] = ()

    def pin(self) -> Tuple[str, Optional[Dict[str, Any]], Optional[bool]]:
        return (self.harness, self.schedule, self.fuse)


#: a beam state's "what is already materialized": (matrix, src, format)
BuiltSet = FrozenSet[Tuple[Any, Optional[str], str]]


def _req_cost(req: MarshalReq, built: BuiltSet, graph, sources
              ) -> Tuple[float, Tuple[Tuple[Any, Optional[str], str], ...]]:
    """Seconds to satisfy one marshal requirement given what earlier
    assignments already build, plus the (matrix, src, format) nodes doing
    so would materialize.  Exact hit -> 0; partial prefix -> remaining
    path cost via ``graph.plan_cost``; otherwise the measured full-path
    cost from the binding."""
    if req.src is None or req.dst is None:
        return req.full_s, ()
    have = {fmt for (mk, s, fmt) in built
            if mk == req.matrix and s == req.src}
    if req.dst in have:
        return 0.0, ()
    cost, produced = req.full_s, None
    if have and graph is not None:
        res = graph.plan_cost({f: 0.0 for f in have}, req.dst)
        if res is not None:
            ride = res[0] * req.scale
            if ride < cost:
                cost, produced = ride, res[1]
    if produced is None:
        # full path from the binding loader: record the intermediates the
        # data plane will cache along the way (later matches ride them)
        produced = (req.dst,)
        loader = (sources or {}).get(req.src)
        if loader is not None and graph is not None:
            res = graph.plan_cost({loader.fmt: loader.cost()}, req.dst)
            if res is not None:
                produced = res[1]
    return cost, tuple((req.matrix, req.src, f) for f in produced)


def assignment_step(cand: Candidate, built: BuiltSet, graph, sources,
                    reuse: float) -> Tuple[float, BuiltSet]:
    """Amortized cost of adding ``cand`` to a partial assignment whose
    materialized formats are ``built``; returns (cost, updated built)."""
    rate = max(float(reuse or 1.0), 1.0)
    cost = cand.kernel_s
    new_built = set(built)
    for req in cand.reqs:
        c, produced = _req_cost(req, frozenset(new_built), graph, sources)
        cost += c / rate
        new_built.update(produced)
    return cost, frozenset(new_built)


def cost_of_assignment(picks: Sequence[Candidate], graph, sources,
                       reuse: float) -> float:
    """End-to-end amortized cost of a full assignment WITH sharing — the
    data plane shares cached intermediates at runtime no matter how the
    decisions were made, so even independently-chosen picks are priced
    with the ride."""
    built: BuiltSet = frozenset()
    total = 0.0
    for cand in picks:
        c, built = assignment_step(cand, built, graph, sources, reuse)
        total += c
    return total


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def independent_assignment(tables: Sequence[Sequence[Candidate]],
                           graph=None, sources=None, reuse: float = 1.0
                           ) -> Tuple[List[Candidate], float]:
    """The pre-joint behavior: each match independently minimizes its own
    amortized cost with the full repack charged (sharing-blind), exactly
    what per-match ``Autotuner.select`` does.  The returned cost evaluates
    the resulting assignment WITH sharing (the runtime shares regardless),
    so it is directly comparable to :func:`search`'s."""
    picks = [min(cands, key=lambda c: assignment_step(
        c, frozenset(), graph, sources, reuse)[0]) for cands in tables]
    return picks, cost_of_assignment(picks, graph, sources, reuse)


def greedy_assignment(tables: Sequence[Sequence[Candidate]],
                      graph=None, sources=None, reuse: float = 1.0
                      ) -> Tuple[List[Candidate], float]:
    """Sequential local argmin with shared state: match i sees what
    matches < i built.  Equivalent to :func:`search` at beam width 1."""
    built: BuiltSet = frozenset()
    picks: List[Candidate] = []
    total = 0.0
    for cands in tables:
        best = None
        for cand in cands:
            c, nb = assignment_step(cand, built, graph, sources, reuse)
            if best is None or c < best[0]:
                best = (c, cand, nb)
        total += best[0]
        picks.append(best[1])
        built = best[2]
    return picks, total


# ---------------------------------------------------------------------------
# The search
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchResult:
    assignment: List[Candidate]
    cost: float
    greedy_cost: float        # sequential shared-state baseline (beam=1)
    independent_cost: float   # per-match sharing-blind argmin (pre-joint)
    beam_width: int
    explored: int             # states expanded
    frontier: List[Dict[str, Any]]   # surviving final beam states

    @property
    def joint_vs_independent(self) -> float:
        """Speedup of the joint assignment over independent per-match
        winners (>= 1.0 by construction)."""
        return (self.independent_cost / self.cost) if self.cost > 0 else 1.0

    def report(self) -> Dict[str, Any]:
        """JSON-serializable summary for ``plan_info()`` / benchmarks."""
        return {
            "assignment": [[c.harness, c.schedule, c.fuse]
                           for c in self.assignment],
            "cost_s": self.cost,
            "greedy_cost_s": self.greedy_cost,
            "independent_cost_s": self.independent_cost,
            "joint_vs_independent": self.joint_vs_independent,
            "beam_width": self.beam_width,
            "explored": self.explored,
            "frontier": self.frontier,
        }


def search(tables: Sequence[Sequence[Candidate]], graph=None, sources=None,
           reuse: float = 1.0, width: Optional[int] = None) -> SearchResult:
    """Beam search over joint assignments: one candidate per match, costed
    with shared marshal state.  ``tables[i]`` lists match i's candidates,
    prior (currently pinned winner) first — ties keep table order, so the
    prior wins when the model is indifferent.  The result never costs
    more than either baseline: both are in the search space, and the
    final answer is clamped to the best of (beam, greedy, independent)."""
    width = beam_width() if width is None else width
    width = max(1, int(width))
    explored = 0
    # states: (cost, built, pick indices) — dominance-pruned on built
    beam: List[Tuple[float, BuiltSet, List[int]]] = [(0.0, frozenset(), [])]
    for cands in tables:
        expanded: List[Tuple[float, BuiltSet, List[int]]] = []
        for cost0, built, picks in beam:
            for idx, cand in enumerate(cands):
                c, nb = assignment_step(cand, built, graph, sources, reuse)
                expanded.append((cost0 + c, nb, picks + [idx]))
                explored += 1
        expanded.sort(key=lambda s: s[0])   # stable: ties keep prior first
        beam, seen = [], set()
        for state in expanded:
            if state[1] in seen:    # same built set, costlier prefix:
                continue            # dominated, identical future costs
            seen.add(state[1])
            beam.append(state)
            if len(beam) >= width:
                break
    best_cost, _, best_idx = beam[0] if beam else (float("inf"), None, [])
    assignment = [tables[i][j] for i, j in enumerate(best_idx)]
    g_picks, g_cost = greedy_assignment(tables, graph, sources, reuse)
    i_picks, i_cost = independent_assignment(tables, graph, sources, reuse)
    # never-worse guarantee: a pruned-too-early beam falls back to the
    # better baseline rather than regressing below it
    for alt_cost, alt_picks in ((g_cost, g_picks), (i_cost, i_picks)):
        if alt_cost < best_cost:
            best_cost, assignment = alt_cost, list(alt_picks)
    frontier = [{"cost_s": c,
                 "assignment": [[tables[i][j].harness,
                                 tables[i][j].schedule,
                                 tables[i][j].fuse]
                                for i, j in enumerate(idxs)]}
                for c, _, idxs in beam[:width]]
    return SearchResult(assignment=assignment, cost=best_cost,
                        greedy_cost=g_cost, independent_cost=i_cost,
                        beam_width=width, explored=explored,
                        frontier=frontier)


# ---------------------------------------------------------------------------
# CompiledEntry adapter (pass_manager hook)
# ---------------------------------------------------------------------------

def _matrix_key(match, clause) -> Tuple:
    """Identity of the operands a marshal clause keys on: the binding
    *atoms* (jaxpr vars / literals), so two matches over the same arrays
    in one program — the coupled case — share the key."""
    parts: List[Any] = [clause.repack]
    for alts in getattr(clause, "keys", ()) or ():
        for k in alts:
            if k in match.binding:
                v = match.binding[k]
                parts.append(v if isinstance(v, (int, float, bool, str))
                             else id(v))
                break
    return tuple(parts)


def _reqs_for(harness, match, rec_marshal_s: Optional[float], cache
              ) -> Tuple[MarshalReq, ...]:
    """Marshal requirements of one harness at one match, priced from the
    conversion graph's measured path costs and rescaled so the clause
    total matches the record's measured ``marshal_s`` (single-clause
    harnesses — all the builtins — get exactly the measured figure)."""
    from repro.core.marshal import FORMATS, SOURCES

    clauses = getattr(harness, "marshal", ()) or ()
    if not clauses:
        return ()
    graph = getattr(cache, "graph", None)
    raw: List[Tuple[Any, Optional[str], Optional[str], float]] = []
    for cl in clauses:
        src = getattr(cl, "src", None)
        dst = getattr(cl, "dst", None)
        mkey = _matrix_key(match, cl)
        if src in SOURCES and dst in FORMATS and graph is not None:
            loader = SOURCES[src]
            full = graph.full_path_cost(loader.fmt, dst,
                                        entry_cost=loader.cost())
            if full is not None:
                raw.append((mkey, src, dst, full))
                continue
        # legacy / unpathable clause: last measured repack seconds, not
        # shareable through the graph
        est = 0.0
        if cache is not None and hasattr(cache, "marshal_seconds"):
            est = cache.marshal_seconds([getattr(cl, "repack", str(cl))])
        raw.append((mkey, None, None, est))
    graph_total = sum(c for _, _, _, c in raw)
    scale = 1.0
    if rec_marshal_s is not None and rec_marshal_s > 0 and graph_total > 0:
        scale = rec_marshal_s / graph_total
    return tuple(MarshalReq(mk, src, dst, full_s=c * scale, scale=scale)
                 for mk, src, dst, c in raw)


def candidates_for_match(match, rec: Dict[str, Any], harnesses, cache,
                         prior: Optional[Tuple] = None) -> List[Candidate]:
    """Build match's candidate table from its autotune record's measured
    components (schema 4 ``variants`` when present, per-harness bests
    otherwise).  ``prior`` — the currently pinned (harness, schedule,
    fuse) — ranks first; the rest sort by kernel time."""
    timings = rec.get("timings") or {}
    schedules = rec.get("schedules") or {}
    fuses = rec.get("fuses") or {}
    variants = rec.get("variants") or {}
    out: List[Candidate] = []
    for h in harnesses:
        t = timings.get(h.name)
        if t is None:
            continue
        reqs = _reqs_for(h, match, (rec.get("marshal_s") or {}).get(h.name),
                         cache)
        fam = getattr(h, "schedules", ()) or ()
        vs = variants.get(h.name) or [[schedules.get(h.name),
                                       fuses.get(h.name), t]]
        for sched, fuse, vt in vs:
            if sched is not None and fam and sched not in fam:
                continue        # tune space changed since the record
            out.append(Candidate(harness=h.name, kernel_s=float(vt),
                                 schedule=sched, fuse=fuse, reqs=reqs))
    def rank(c: Candidate):
        is_prior = (prior is not None and c.pin() == tuple(prior))
        return (not is_prior, c.kernel_s)
    out.sort(key=rank)
    return out


def optimize_entry(flat_matches, pins: Dict[int, Tuple], *, registry,
                   tuner, platform: str, mode: str, cache,
                   reuse: float, width: Optional[int] = None
                   ) -> Optional[SearchResult]:
    """Run the joint search for a fully-pinned ``CompiledEntry``: rebuild
    every match's candidate table from recorded measurements (zero
    re-timing) and beam-search the joint assignment.  Returns None when
    any match lacks a servable record or candidates — the per-match pins
    stand in that case."""
    from repro.core.autotune import signature_of
    from repro.core.marshal import SOURCES

    graph = getattr(cache, "graph", None)
    tables: List[List[Candidate]] = []
    for i, m in enumerate(flat_matches):
        sig = signature_of(m.computation, m.format, platform, m.binding,
                           epilogue=m.epilogue)
        rec = tuner.cache.get(sig, mode)
        if rec is None:
            return None
        cands = registry.candidates(m.computation, m.format, platform, mode)
        table = candidates_for_match(m, rec, cands, cache,
                                     prior=pins.get(i))
        if not table:
            return None
        tables.append(table)
    return search(tables, graph=graph, sources=SOURCES, reuse=reuse,
                  width=width)
