"""LiLAC spec compilation: How-descriptors -> executable harnesses (§3.3).

The paper's promise is that a library implementer writes a *one-off LiLAC
description* — a What-clause (the computation) and a How-clause (harness,
marshaling, persistence) — and the compiler does the rest.  This module is
the How-compiler:

* ``build_harnesses`` turns a parsed ``HarnessDecl`` plus a Python kernel
  body into registered :class:`~repro.core.harness.Harness` objects.  The
  marshaling wrapper is *generated* from the declared ``marshal`` clauses:
  each clause names a registered repack function and the binding keys whose
  content fingerprints gate recomputation, and the wrapper routes the
  repack through the per-call :class:`MarshalingCache` (the mprotect
  analogue, paper Fig. 8-10) — backends no longer open-code cache lookups.
* ``@harness(...)`` is the decorator form: put the HARNESS block text right
  above the kernel body (see ``repro/kernels/*/harness.py``); the body is
  compiled and registered at import time.  "Add a backend" is therefore a
  spec-plus-function change, which is the paper's whole point.
* ``@repack(name)`` / ``@hook(name)`` register the named format-conversion
  and BeforeFirstExecution/AfterLastExecution functions that spec texts
  refer to.
* ``register_builtins`` populates a registry from the builtin spec texts
  (``what_lang.BUILTIN_SPECS`` for the jnp.* backends, plus the HARNESS
  blocks declared next to the Pallas kernels), replacing the hand-wired
  ``register()`` calls of earlier revisions.  Spec-driven registration
  produces byte-identical registry fingerprints, so persisted autotune
  decisions carry over.

New COMPUTATION programs in a registered spec are added to
``what_lang.BUILTINS`` and the default detector is rebuilt, so detection
picks them up without touching compiler internals.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Union

from repro.core import harness as H
from repro.core import marshal as M
from repro.core import what_lang as W


class SpecError(ValueError):
    """A spec references something the How-compiler cannot resolve."""


# ---------------------------------------------------------------------------
# Repack + hook registries (the names spec texts refer to).
# ---------------------------------------------------------------------------

REPACKS: Dict[str, Callable[[H.Binding], Any]] = {}
HOOKS: Dict[str, Callable[[Dict[str, Any]], Any]] = {}
VJPS: Dict[str, Callable] = {}


def repack(name: str, *, override: bool = False):
    """Register a marshaling repack function ``binding -> packed value``
    under ``name`` so ``marshal x = name(...)`` clauses can refer to it."""
    def deco(fn):
        if name in REPACKS and REPACKS[name] is not fn and not override:
            raise SpecError(f"repack {name!r} is already registered")
        REPACKS[name] = fn
        return fn
    return deco


def hook(name: str, *, override: bool = False):
    """Register a persistence hook ``persistent_state_dict -> None`` for
    BeforeFirstExecution / AfterLastExecution clauses."""
    def deco(fn):
        if name in HOOKS and HOOKS[name] is not fn and not override:
            raise SpecError(f"hook {name!r} is already registered")
        HOOKS[name] = fn
        return fn
    return deco


def vjp(name: str, *, override: bool = False):
    """Register a custom backward body so ``vjp <name>(wrt...)`` clauses can
    refer to it.  The body has signature::

        bwd(binding, ctx, primal_out, cotangent) -> {wrt_key: grad, ...}

    It runs under the backward trace, so it must be traceable in
    ``cotangent`` (and the wrt binding values) — pure jnp over whatever
    concrete index structure it pulls from the binding / marshaling cache.
    The returned dict must supply a gradient for every declared wrt key."""
    def deco(fn):
        if name in VJPS and VJPS[name] is not fn and not override:
            raise SpecError(f"vjp {name!r} is already registered")
        VJPS[name] = fn
        return fn
    return deco


# Builtin backward bodies (repro.core.harness.BUILTIN_VJPS) enter the
# registry at import so every HARNESS block — builtin spec text or kernel
# package — can cite them without registration-order footwork.
VJPS.update(H.BUILTIN_VJPS)


# ---------------------------------------------------------------------------
# Descriptor -> Harness compilation
# ---------------------------------------------------------------------------

def _resolve_key(binding: H.Binding, alternatives) -> Any:
    for k in alternatives:
        if k in binding:
            return binding[k]
    raise KeyError(
        f"marshal key {'|'.join(alternatives)!r} not found in binding "
        f"(has {sorted(binding)})")


def _marshaled_fn(decl: W.HarnessDecl, body: Callable) -> Callable:
    """Generate the execution wrapper for a HARNESS descriptor: marshaled
    inputs and tuned schedule parameters both arrive at the kernel body as
    keyword arguments.

    *Marshal clauses*: each marshaled input is computed by its repack
    function, memoized in the call's cache on the fingerprints of the
    declared key arrays.  Clauses that declare ``from <src> to <dst>``
    route through the shared plan-level
    :class:`~repro.core.marshal.DataPlane`: the conversion graph plans the
    cheapest path to ``dst`` (riding intermediates another harness already
    cached), with the clause's repack function as the fallback when no
    path exists.

    *Tune clauses*: the body receives every declared tune param as a
    keyword argument — the default schedule (first declared values)
    overlaid with the caller's ``ctx.schedule``, which is how the
    autotuner's swept winner reaches the kernel.  Unknown schedule keys
    raise (a pinned variant must never silently no-op)."""
    clauses = decl.marshal
    default_schedule = decl.default_schedule()
    tune_names = frozenset(default_schedule)

    def fn(binding: H.Binding, ctx: H.CallCtx):
        marshaled = {}
        cache = ctx.cache if ctx is not None else None
        for cl in clauses:
            pack = REPACKS.get(cl.repack)
            if pack is None:
                raise SpecError(
                    f"harness {decl.name!r}: unknown repack {cl.repack!r}")
            keys = tuple(_resolve_key(binding, alts) for alts in cl.keys)
            if cache is None:
                marshaled[cl.name] = pack(binding)
            elif cl.src and cl.dst and hasattr(cache, "ensure"):
                marshaled[cl.name] = cache.ensure(
                    cl.src, cl.dst, keys, binding,
                    fallback=lambda p=pack: p(binding))
            else:
                marshaled[cl.name] = cache.get(
                    cl.repack, keys, lambda p=pack: p(binding))
        if tune_names:
            sched = dict(default_schedule)
            override = getattr(ctx, "schedule", None) if ctx is not None \
                else None
            if override:
                unknown = set(override) - tune_names
                if unknown:
                    raise SpecError(
                        f"harness {decl.name!r}: schedule has unknown "
                        f"param(s) {sorted(unknown)} "
                        f"(declared: {sorted(tune_names)})")
                sched.update(override)
            marshaled.update(sched)
        return body(binding, ctx, **marshaled)

    fn.__name__ = getattr(body, "__name__", decl.name)
    fn.__qualname__ = getattr(body, "__qualname__", decl.name)
    return fn


def build_harnesses(decl: W.HarnessDecl, body: Callable, *,
                    hooks: Optional[Dict[str, Callable]] = None,
                    ) -> List[H.Harness]:
    """Compile one HARNESS descriptor + kernel body into Harness objects
    (one per implemented computation)."""
    table = {**HOOKS, **(hooks or {})}
    setup = teardown = None
    if decl.before_first is not None:
        setup = table.get(decl.before_first)
        if setup is None:
            raise SpecError(f"harness {decl.name!r}: unknown "
                            f"BeforeFirstExecution hook {decl.before_first!r}")
    if decl.after_last is not None:
        teardown = table.get(decl.after_last)
        if teardown is None:
            raise SpecError(f"harness {decl.name!r}: unknown "
                            f"AfterLastExecution hook {decl.after_last!r}")
    # Eagerly materialize the schedule family: a tune/constraint mistake
    # (symbolic value in an arithmetic constraint, or constraints so tight
    # the default schedule itself is pruned) must fail at registration, not
    # mid-sweep inside the autotuner.
    schedules = ()
    if decl.tune:
        try:
            schedules = W.enumerate_schedules(decl.tune, decl.constraints)
        except W.ParseError as e:
            raise SpecError(f"harness {decl.name!r}: {e}")
        if not schedules:
            raise SpecError(
                f"harness {decl.name!r}: constraints prune every schedule "
                f"variant")
        if schedules[0] != decl.default_schedule():
            raise SpecError(
                f"harness {decl.name!r}: the default schedule (first "
                f"declared values) violates a constraint")
    fn = _marshaled_fn(decl, body) if (decl.marshal or decl.tune) else body
    # One HARNESS block describes ONE backend, however many computations it
    # implements: the Harness objects share a single persistent-state dict
    # and a single lifecycle flag, so the hooks run once per backend (first
    # call anywhere sets up, release anywhere tears down for all, and a
    # later call sets up again), not once per computation.
    persistent = {k: None for k in decl.persistent}
    lifecycle = {"up": False} if len(decl.implements) > 1 else None
    return [
        H.Harness(decl.name, comp, fn, jit_safe=decl.jit_safe,
                  platforms=decl.platforms, formats=decl.formats,
                  persistent=persistent, setup=setup, teardown=teardown,
                  lifecycle=lifecycle, marshal=decl.marshal,
                  tune=decl.tune, constraints=decl.constraints,
                  fuse_epilogue=decl.fuse_epilogue, vjp=decl.vjp,
                  _schedules=schedules or None)
        for comp in decl.implements
    ]


# Every spec registered against the global REGISTRY is logged so that
# register_builtins can replay the full builtin surface into a fresh
# registry (parity tests, isolated experiments).
_GLOBAL_SPEC_LOG: List[tuple] = []


def register_spec(spec: Union[str, W.Spec], bodies: Dict[str, Callable], *,
                  registry: Optional[H.HarnessRegistry] = None,
                  hooks: Optional[Dict[str, Callable]] = None,
                  override: bool = False) -> List[H.Harness]:
    """Register a full LiLAC spec: new computations go to the What-language
    builtins (rebuilding the default detector), and every HARNESS block is
    compiled against its kernel body from ``bodies`` and registered."""
    if isinstance(spec, str):
        spec = W.parse_spec(spec)
    reg = registry if registry is not None else H.REGISTRY
    is_global = reg is H.REGISTRY

    # Phase 1 — validate and build with NO side effects, so a bad spec
    # raises without leaving computations published, the detector rebuilt,
    # or a prefix of its harnesses registered.
    local_comps = {c.name for c in spec.computations}
    for comp in spec.computations:
        known = W.BUILTINS.get(comp.name)
        if known is not None and known != comp:
            raise SpecError(
                f"computation {comp.name!r} conflicts with an existing "
                f"definition; rename it or match the builtin text")
    staged: List[tuple] = []    # (decl, [Harness, ...])
    seen: set = set()           # (implements, name) within this spec
    for decl in spec.harnesses:
        for target in decl.implements:
            if target not in W.BUILTINS and target not in local_comps:
                raise SpecError(
                    f"HARNESS {decl.name!r} implements unknown computation "
                    f"{target!r}")
        body = bodies.get(decl.name)
        if body is None:
            raise SpecError(
                f"no kernel body bound for HARNESS {decl.name!r} "
                f"(bodies has {sorted(bodies)})")
        if decl.vjp is not None and decl.vjp.name not in VJPS:
            # eager, like repacks: a typo'd backward must fail at
            # registration, not the first time someone differentiates
            raise SpecError(
                f"HARNESS {decl.name!r}: unknown vjp {decl.vjp.name!r} "
                f"(register it with @vjp before the harness)")
        for cl in decl.marshal:
            # eager, like hooks: a typo'd repack must fail at registration,
            # not be silently disqualified by the autotuner at call time
            if cl.repack not in REPACKS:
                raise SpecError(
                    f"HARNESS {decl.name!r}: unknown repack {cl.repack!r} "
                    f"(register it with @repack before the harness)")
            # declared formats must resolve against the data plane so the
            # conversion graph is built from specs, not hand-wiring
            if cl.src is not None and cl.src not in M.SOURCES:
                raise SpecError(
                    f"HARNESS {decl.name!r}: unknown marshal source "
                    f"{cl.src!r} (register it with register_source)")
            if cl.dst is not None and cl.dst not in M.FORMATS:
                raise SpecError(
                    f"HARNESS {decl.name!r}: unknown marshal target format "
                    f"{cl.dst!r} (register it with register_format)")
            if cl.src is not None and cl.dst is not None:
                start = M.SOURCES[cl.src].fmt
                if M.GRAPH.full_path_cost(start, cl.dst) is None:
                    raise SpecError(
                        f"HARNESS {decl.name!r}: no conversion path "
                        f"{cl.src}({start}) -> {cl.dst} in the graph")
        hs = build_harnesses(decl, body, hooks=hooks)
        for h in hs:
            key = (h.implements, h.name)
            already = any(ex.name == h.name
                          for ex in reg.harnesses_for(h.implements))
            if key in seen or (already and not override):
                raise H.DuplicateHarnessError(
                    f"harness {h.name!r} is already registered for "
                    f"{h.implements!r}; pass override=True to replace it")
            seen.add(key)
        staged.append((decl, hs))

    # Phase 2 — commit.  Registering against the global REGISTRY publishes
    # new computations to the What-language builtins (and rebuilds the
    # default detector) so they become detectable everywhere.  A
    # caller-supplied registry stays fully isolated: its spec's
    # computations resolve locally and never touch process-global state.
    new_comp = False
    for comp in spec.computations:
        if comp.name not in W.BUILTINS and is_global:
            W.BUILTINS[comp.name] = comp
            new_comp = True
    if new_comp:
        from repro.core import detect as D
        D.reset_default_detector()
    registered: List[H.Harness] = []
    for decl, hs in staged:
        for h in hs:
            reg.register(h, default_for=decl.default_for, override=override)
            registered.append(h)
    if is_global:
        _GLOBAL_SPEC_LOG.append((spec, dict(bodies), dict(hooks or {})))
    return registered


def harness(decl: Union[str, W.HarnessDecl], *,
            registry: Optional[H.HarnessRegistry] = None,
            hooks: Optional[Dict[str, Callable]] = None,
            override: bool = False):
    """Decorator: compile and register the kernel body under a HARNESS
    declaration (text or parsed).  The text may also carry COMPUTATION
    blocks, making a new backend a self-contained spec-plus-function::

        @lilac.harness('''
        HARNESS pallas.ell implements spmv_ell, spmv_jds
          formats ELL, JDS;
          default_for tpu;
        ''')
        def pallas_ell(binding, ctx):
            ...
    """
    if isinstance(decl, W.HarnessDecl):
        spec = W.Spec((), (decl,))
    else:
        spec = W.parse_spec(decl)
    if len(spec.harnesses) != 1:
        raise SpecError("@harness expects exactly one HARNESS block")
    name = spec.harnesses[0].name

    def deco(body):
        register_spec(spec, {name: body}, registry=registry, hooks=hooks,
                      override=override)
        return body

    return deco


# ---------------------------------------------------------------------------
# The builtin data plane: source loaders (binding -> format) and conversion
# edges (format value -> format value).  Marshal clauses name these via
# ``from <source> to <format>``; the legacy repack functions below remain as
# single-hop fallbacks and as the reference implementations the property
# tests compare planned paths against.
# ---------------------------------------------------------------------------

M.register_source("csr_binding", "CSR", H._binding_to_csr)
M.register_source("csr_binding_mm", "CSR", H._binding_to_csr_spmm)


@M.edge("CSR", "ELL8", name="csr_to_ell8")
def _csr_to_ell8(csr):
    from repro.sparse.convert import csr_to_ell
    return csr_to_ell(csr)


@M.edge("CSR", "ELL128", name="csr_to_ell128")
def _csr_to_ell128(csr):
    from repro.sparse.convert import csr_to_ell
    return csr_to_ell(csr, lane=128)


@M.edge("CSR", "DENSE", name="csr_todense")
def _csr_todense(csr):
    return csr.todense()


@M.edge("CSR", "JDS", name="csr_to_jds")
def _csr_to_jds(csr):
    from repro.sparse.convert import csr_to_jds
    return csr_to_jds(csr)


def _dense_to_bcsr(dense, block_shape):
    """Pad to block multiples and tile (csr_to_bcsr's second half, so
    CSR->DENSE->BCSR* composes to exactly the legacy one-hop repack and
    the DENSE intermediate is shareable with the jnp.dense harness)."""
    import numpy as np

    from repro.sparse.formats import bcsr_from_dense
    d = np.asarray(dense)
    bm, bn = block_shape
    rows, cols = d.shape
    pr = (-rows) % bm
    pc = (-cols) % bn
    if pr or pc:
        d = np.pad(d, ((0, pr), (0, pc)))
    return bcsr_from_dense(d, block_shape)


@M.edge("DENSE", "BCSR8x128", name="dense_to_bcsr8x128")
def _dense_to_bcsr8(dense):
    return _dense_to_bcsr(dense, (8, 128))


@M.edge("DENSE", "BCSR128x128", name="dense_to_bcsr128x128")
def _dense_to_bcsr128(dense):
    return _dense_to_bcsr(dense, (128, 128))


# ---------------------------------------------------------------------------
# Builtin repacks (single-hop fallbacks; also the graph-equivalence oracle).
# ---------------------------------------------------------------------------

@repack("ell_pack")
def _ell_pack(b: H.Binding):
    from repro.sparse.convert import csr_to_ell
    return csr_to_ell(H._binding_to_csr(b))


@repack("ell_pack128")
def _ell_pack128(b: H.Binding):
    from repro.sparse.convert import csr_to_ell
    return csr_to_ell(H._binding_to_csr(b), lane=128)


@repack("bcsr_pack")
def _bcsr_pack(b: H.Binding):
    from repro.sparse.convert import csr_to_bcsr
    return csr_to_bcsr(H._binding_to_csr(b), block_shape=(8, 128))


@repack("bcsr_pack128")
def _bcsr_pack128(b: H.Binding):
    from repro.sparse.convert import csr_to_bcsr
    return csr_to_bcsr(H._binding_to_csr(b), block_shape=(128, 128))


@repack("densify")
def _densify(b: H.Binding):
    return H._binding_to_csr(b).todense()


@repack("bcsr_pack_mm")
def _bcsr_pack_mm(b: H.Binding):
    from repro.sparse.convert import csr_to_bcsr
    return csr_to_bcsr(H._binding_to_csr_spmm(b), block_shape=(8, 128))


@repack("bcsr_pack_mm128")
def _bcsr_pack_mm128(b: H.Binding):
    from repro.sparse.convert import csr_to_bcsr
    return csr_to_bcsr(H._binding_to_csr_spmm(b), block_shape=(128, 128))


# ---------------------------------------------------------------------------
# Builtin registration
# ---------------------------------------------------------------------------

_builtins_done = False


def register_builtins(registry: Optional[H.HarnessRegistry] = None):
    """Populate ``registry`` (default: the global REGISTRY) with every
    builtin backend, entirely from spec texts.

    Order matters for candidate enumeration: the jnp.* families from
    ``what_lang.BUILTIN_SPECS`` first, then the Pallas kernels' own HARNESS
    blocks (imported from the kernel packages, whose ``@harness``
    decorators register against the global REGISTRY and are logged for
    replay into custom registries)."""
    global _builtins_done
    if registry is None or registry is H.REGISTRY:
        if _builtins_done:
            return H.REGISTRY
        # override=True makes a retry after a mid-way failure (e.g. a
        # kernel-package ImportError) idempotent for the family specs; the
        # done flag is only set once everything registered, so a partial
        # first attempt fails loudly on retry instead of silently leaving
        # the pallas.* backends missing.
        for family, text in W.BUILTIN_SPECS.items():
            if family in W.POST_KERNEL_FAMILIES:
                continue
            register_spec(text, H.BUILTIN_BODIES.get(family, {}),
                          override=True)
        # The pallas.* backends self-register on import via @harness.
        from repro.kernels.spmv_ell import harness as _ell  # noqa: F401
        from repro.kernels.bsr_spmm import harness as _bsr  # noqa: F401
        from repro.kernels.moe_gmm import harness as _gmm   # noqa: F401
        # Baselines come last so candidate (and autotune-exploration)
        # order matches the pre-spec hand-wired registry exactly.
        for family in W.POST_KERNEL_FAMILIES:
            register_spec(W.BUILTIN_SPECS[family],
                          H.BUILTIN_BODIES.get(family, {}), override=True)
        _builtins_done = True
        return H.REGISTRY
    # Fresh registry: replay the global registration log.  Replay with
    # override=True — a spec re-loaded globally via the override escape
    # hatch appears twice in the log, and the later entry must win here
    # exactly as it did on the global registry.
    register_builtins(None)
    for spec, bodies, hooks in _GLOBAL_SPEC_LOG:
        register_spec(spec, bodies, registry=registry, hooks=hooks,
                      override=True)
    return registry
