"""repro.core.jsonstore — the shared on-disk JSON store protocol.

Both of LiLAC's persistent caches — tuning decisions
(:class:`repro.core.autotune.AutotuneCache`) and resolved plans
(:class:`repro.core.plan.PlanCache`) — follow one disk protocol, factored
here so the concurrency and invalidation story exists exactly once:

* **Document layout**: a single JSON object
  ``{"schema": <int>, "registry": "<fingerprint>", "entries": {...}}``.
  The schema version gates structural compatibility; the registry
  fingerprint ties every record to the harness set that produced it — a
  mismatch on either drops the whole file (records are only as durable as
  the specs behind them).
* **Migration**: subclasses may declare older ``readable_schemas`` and a
  ``_migrate`` hook; an old-but-readable file is upgraded in memory on
  load instead of being discarded (the autotune cache migrates schema-1/2
  records into re-measurable priors this way).
* **Atomic merge-on-save**: ``save`` re-reads the file under an advisory
  ``flock``, merges the in-memory entries over it, and atomically
  replaces the file (tempfile in the same directory + ``os.replace``).
  Concurrent processes never corrupt the store and rarely lose each
  other's entries.  Losing the lock (non-POSIX platforms) degrades to
  last-writer-wins, never to corruption.
* **Best-effort persistence**: an unwritable cache location degrades to
  an in-memory store — a failed save is counted, not raised, because the
  cache always serves a computation that must not fail on cache trouble.

Subclass surface: set ``schema_version`` (and optionally
``readable_schemas``), implement ``default_path``; override ``_migrate``
for old-schema upgrades, ``_merge`` when entries nest (the autotune
cache merges per ``(signature, mode)``, not per top-level key), and the
``_note_*`` hooks to feed the subclass's stats counters.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

try:  # POSIX advisory locking for concurrent writers; harmless to lose.
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None


class JsonStore:
    """Versioned, registry-fingerprinted JSON entry store (see module
    docstring for the protocol)."""

    #: schema written by ``save`` and required (or migratable) on read
    schema_version: int = 1
    #: older schemas ``_read_disk`` accepts and feeds through ``_migrate``
    readable_schemas: Tuple[int, ...] = ()

    def __init__(self, path: Optional[os.PathLike] = None,
                 registry_fingerprint: str = ""):
        self.path = Path(path) if path is not None else self.default_path()
        self.registry_fingerprint = registry_fingerprint
        self.entries: Dict[str, Any] = {}
        self.loaded = False

    # -- subclass surface ----------------------------------------------------

    def default_path(self) -> Path:
        raise NotImplementedError

    def _migrate(self, entries: Dict[str, Any], schema: int
                 ) -> Dict[str, Any]:
        """Upgrade entries read from an older (readable) schema."""
        return entries

    def _merge(self, base: Dict[str, Any], incoming: Dict[str, Any],
               overwrite: bool):
        """Merge ``incoming`` entries into ``base`` in place.  The default
        is flat per-key; subclasses with nested entries override.  With
        ``overwrite=False`` existing keys win (warm-start: disk under
        memory); with ``overwrite=True`` incoming wins (save: memory over
        disk)."""
        for k, v in incoming.items():
            if overwrite or k not in base:
                base[k] = v

    def _note_invalidation(self):
        """A whole-file drop: schema or registry-fingerprint mismatch."""

    def _note_save_error(self):
        """Persistence failed (unwritable path); store stays in-memory."""

    def _note_corrupt_recovery(self):
        """A torn/corrupt file was quarantined to a ``*.corrupt`` sidecar."""

    # -- disk protocol -------------------------------------------------------

    def _quarantine_corrupt(self):
        """A file that exists but does not parse is a torn or corrupted
        write (power loss mid-rename, a buggy external writer, disk rot).
        It must never poison future processes: move it aside to a
        ``*.corrupt`` sidecar — kept for post-mortem, out of the read
        path — and start fresh.  Renaming (vs deleting) also stops two
        concurrent readers from both re-discovering the same bad file."""
        sidecar = self.path.with_suffix(self.path.suffix + ".corrupt")
        try:
            os.replace(self.path, sidecar)
        except OSError:
            try:
                os.unlink(self.path)
            except OSError:
                pass
        self._note_corrupt_recovery()

    def _read_disk(self) -> Dict[str, Any]:
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except OSError:
            return {}
        except (json.JSONDecodeError, UnicodeDecodeError, ValueError):
            self._quarantine_corrupt()
            return {}
        schema = doc.get("schema") if isinstance(doc, dict) else None
        if not isinstance(doc, dict) \
                or schema not in (self.schema_version, *self.readable_schemas):
            self._note_invalidation()
            return {}
        if doc.get("registry") != self.registry_fingerprint:
            self._note_invalidation()
            return {}
        entries = doc.get("entries", {})
        if not isinstance(entries, dict):
            return {}
        if schema != self.schema_version:
            entries = self._migrate(entries, schema)
        return entries

    def load(self) -> "JsonStore":
        """Warm-start: merge on-disk entries under the in-memory ones."""
        self._merge(self.entries, self._read_disk(), overwrite=False)
        self.loaded = True
        return self

    def save(self):
        """Best-effort persistence: an unwritable cache location degrades
        to an in-memory store (counted via ``_note_save_error``) instead
        of failing the computation the cache is serving."""
        try:
            self._save()
        except OSError:
            self._note_save_error()

    def _save(self):
        self.path.parent.mkdir(parents=True, exist_ok=True)
        lock_path = self.path.with_suffix(self.path.suffix + ".lock")
        lock_f = None
        try:
            if fcntl is not None:
                lock_f = open(lock_path, "a+")
                fcntl.flock(lock_f.fileno(), fcntl.LOCK_EX)
            merged = self._read_disk()
            self._merge(merged, self.entries, overwrite=True)
            doc = {"schema": self.schema_version,
                   "registry": self.registry_fingerprint,
                   "entries": merged}
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w", encoding="utf-8") as f:
                    json.dump(doc, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
                self._maybe_tear()
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        finally:
            if lock_f is not None:
                fcntl.flock(lock_f.fileno(), fcntl.LOCK_UN)
                lock_f.close()

    def _maybe_tear(self):
        """Chaos hook (``cache_torn_write``): the tempfile + ``os.replace``
        protocol cannot tear in real life on POSIX, so the injection
        simulates the larger world — NFS, crashed writers, other tools —
        by truncating the just-written file to half its bytes.  Site name
        is the file stem (``autotune``, ``plans``, ``quarantine``)."""
        from repro.core import faults
        if faults.ACTIVE is None:
            return
        if not faults.check("cache_torn_write", self.path.stem):
            return
        try:
            size = os.path.getsize(self.path)
            with open(self.path, "r+b") as f:
                f.truncate(max(1, size // 2))
        except OSError:
            pass
