"""Code replacement (paper §4.1.2): splice harness calls into jaxprs.

The paper inserts a harness call before the matched loop nest, removes the
result store, and lets DCE sweep the rest.  Here the rewritten program is a
re-interpretation of the normalized jaxpr: every equation is re-emitted
except the matched anchors, whose outputs come from the selected harness.
Orphaned producers are removed by XLA DCE at jit time (trace mode) or simply
never contribute (their values are still computed in host mode only if
needed by unmatched consumers — the interpreter is demand-agnostic but XLA
under jit removes them; host mode runs eqn-by-eqn and skips equations whose
outputs feed only matched anchors).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax import core as jcore
from jax.extend import core as jex_core

from repro.core.detect import Match
from repro.core.harness import CallCtx, Harness


def needed_eqn_ids(closed_jaxpr, matches: List[Match]) -> frozenset:
    """``id``s of the equations the rewritten program must still evaluate:
    everything live through the function outputs or a harness binding atom,
    minus the replaced anchors and the producers that only fed them.

    Pure function of ``(closed_jaxpr, matches)`` — the pass manager
    memoizes it per ``CompiledEntry`` so repeat host-mode calls (and every
    baked-plan trace) skip the backward liveness walk."""
    jaxpr = closed_jaxpr.jaxpr
    anchor_ids = {id(m.anchor_eqn) for m in matches}
    # keep anything a harness binding refers to
    binding_atoms = set()
    for m in matches:
        for v in m.binding.values():
            # Literals (e.g. a scalar epilogue bias) are constants: they
            # need no liveness root and are unhashable anyway
            if not isinstance(v, (int, float, bool, jex_core.Literal)):
                binding_atoms.add(v)
    live = {v for v in jaxpr.outvars if not isinstance(v, jex_core.Literal)}
    live |= binding_atoms
    needed = set()
    for eqn in reversed(jaxpr.eqns):
        if id(eqn) in anchor_ids:
            continue
        if any(ov in live for ov in eqn.outvars):
            needed.add(id(eqn))
            for iv in eqn.invars:
                if not isinstance(iv, jex_core.Literal):
                    live.add(iv)
    return frozenset(needed)


def run_rewritten(closed_jaxpr,
                  matches: List[Match],
                  select: Callable[[Match], Harness],
                  args: List[Any],
                  ctx_factory: Callable[[Match], CallCtx],
                  on_select: Optional[Callable[[Match, Harness], None]] = None,
                  needed: Optional[frozenset] = None,
                  contain: Optional[Callable] = None,
                  ) -> List[Any]:
    """Evaluate ``closed_jaxpr`` with matched anchors replaced by harness
    calls.  Traceable: under jit this builds the rewritten HLO.

    ``on_select`` (if given) observes every (match, chosen harness, call
    ctx) triple — the pass manager uses it to pin autotuned winners (and
    their schedule variants, carried on ``ctx.schedule``) into the rewrite
    and benchmarks use it to report which backend actually ran.

    ``needed`` (if given) is a precomputed :func:`needed_eqn_ids` result
    for exactly this ``(closed_jaxpr, matches)`` pair.

    ``contain`` (if given) is a :class:`repro.core.resilience.Containment`
    -shaped callable ``(m, harness, ctx, binding_vals, attempt, on_select)
    -> out``: every anchor invocation routes through it so a failing
    harness can be retried with another candidate or escalated to
    :class:`~repro.core.resilience.ReferenceFallback` instead of
    surfacing to the user.  When containment retries, it re-issues
    ``on_select`` for each candidate it tries — observers must treat a
    repeated (match, ...) as a replacement, not a new site."""
    jaxpr = closed_jaxpr.jaxpr
    env: Dict[Any, Any] = {}

    def read(atom):
        if isinstance(atom, jex_core.Literal):
            return atom.val
        return env[atom]

    def write(var, val):
        env[var] = val

    for cv, cval in zip(jaxpr.constvars, closed_jaxpr.consts):
        write(cv, cval)
    assert len(jaxpr.invars) == len(args), (len(jaxpr.invars), len(args))
    for iv, a in zip(jaxpr.invars, args):
        write(iv, a)

    anchor_map = {id(m.anchor_eqn): m for m in matches}
    if needed is None:
        needed = needed_eqn_ids(closed_jaxpr, matches)

    for eqn in jaxpr.eqns:
        m = anchor_map.get(id(eqn))
        if m is not None:
            if m.variant == "scan_body":
                _eval_scan_body(eqn, m, select, read, write, ctx_factory,
                                on_select, contain)
            else:
                _eval_anchor(eqn, m, select, read, write, ctx_factory,
                             on_select, contain)
            continue
        if id(eqn) not in needed:
            continue
        subfuns, bind_params = eqn.primitive.get_bind_params(eqn.params)
        ans = eqn.primitive.bind(*subfuns, *map(read, eqn.invars), **bind_params)
        if eqn.primitive.multiple_results:
            for ov, v in zip(eqn.outvars, ans):
                write(ov, v)
        else:
            write(eqn.outvars[0], ans)

    return [read(v) for v in jaxpr.outvars]


def apply_epilogue(out, bias, epilogue: str):
    """The detected fused epilogue, applied at the jnp level: the unfused
    realization for harnesses that don't declare ``fuse epilogue`` (and the
    reference semantics the fused kernels must reproduce).  ``epilogue`` is
    'relu' | 'silu' | 'none' (bias only)."""
    if bias is not None:
        out = out + bias
    if epilogue == "relu":
        out = jnp.maximum(out, 0)
    elif epilogue == "silu":
        out = out * jax.nn.sigmoid(out)
    return out


def effective_fuse(harness, ctx) -> bool:
    """Whether this call applies the detected epilogue IN-KERNEL.  The
    harness must be fuse-capable (``fuse epilogue`` in its spec); given
    that, ``ctx.fuse`` overrides the declared default — the autotuner
    sweeps both realizations and the joint plan search pins the faster
    one, so fusion is a measured decision, not a flag."""
    if not getattr(harness, "fuse_epilogue", False):
        return False
    f = getattr(ctx, "fuse", None)
    return True if f is None else bool(f)


def _call_with_vjp(harness: Harness, binding_vals: Dict[str, Any],
                   ctx: CallCtx):
    """Wrap the harness call in ``jax.custom_vjp`` per its declared vjp
    clause: the forward becomes opaque to AD (host marshaling and Pallas
    bodies are never differentiated through) and the registered backward
    body supplies sparse-aware gradients for the wrt binding keys.  Keys
    not listed — index structure, routing tables, shape ints — are closed
    over as non-differentiable constants."""
    from repro.core.spec import VJPS
    clause = harness.vjp
    bwd_body = VJPS[clause.name]
    # Only values that are live tracers become custom_vjp formal args:
    # a concrete operand (say, a constant sparse matrix) stays a closure
    # capture, so marshal clauses can still fingerprint and repack it —
    # custom_vjp abstracts ALL formal args inside its fwd trace, which
    # would otherwise break host marshaling for operands that were never
    # differentiated in the first place.
    wrt = tuple(k for k in clause.wrt if k in binding_vals
                and isinstance(binding_vals[k], jcore.Tracer))
    nondiff = {k: v for k, v in binding_vals.items() if k not in wrt}

    def base(*dv):
        b = dict(nondiff)
        b.update(zip(wrt, dv))
        return harness(b, ctx)

    def fwd(*dv):
        return base(*dv), dv

    def bwd(res, ct):
        b = dict(nondiff)
        b.update(zip(wrt, res))
        grads = bwd_body(b, ctx, None, ct)
        missing = [k for k in wrt if k not in grads]
        if missing:
            raise ValueError(
                f"vjp {clause.name!r} returned no gradient for "
                f"{missing} (declared wrt: {list(clause.wrt)})")
        return tuple(grads[k] for k in wrt)

    run = jax.custom_vjp(base)
    run.defvjp(fwd, bwd)
    return run(*(binding_vals[k] for k in wrt))


def _eval_anchor(eqn, m: Match, select, read, write, ctx_factory,
                 on_select=None, contain=None):
    binding_vals = {
        k: (v if isinstance(v, (int, float, bool)) else read(v))
        for k, v in m.binding.items()
    }
    ctx = ctx_factory(m)
    harness = select(m, binding_vals, ctx)

    def attempt(h: Harness, c: CallCtx):
        """The full invoke path for one candidate — containment retries
        this with other (harness, ctx) pairs on failure."""
        clause = getattr(h, "vjp", None)
        wrap = clause is not None and any(
            isinstance(binding_vals.get(k), jcore.Tracer) for k in clause.wrt)
        if wrap:
            # Unfuse any detected epilogue under differentiation: the
            # declared backward covers the core computation only, so the
            # epilogue is applied outside the opaque call where jax can
            # transpose it.
            inner_ctx = (dataclasses.replace(c, epilogue=None)
                         if c.epilogue is not None else c)
            out = _call_with_vjp(h, binding_vals, inner_ctx)
            if m.epilogue is not None:
                out = apply_epilogue(out, binding_vals.get("bias"),
                                     m.epilogue)
        else:
            fused = effective_fuse(h, c)
            if (m.epilogue is not None and not fused
                    and getattr(h, "fuse_epilogue", False)
                    and c.epilogue is not None):
                # fuse-capable harness pinned UNFUSED: the body must not
                # see the epilogue (it would apply it in-kernel)
                c = dataclasses.replace(c, epilogue=None)
            out = h(binding_vals, c)
            if m.epilogue is not None and not fused:
                out = apply_epilogue(out, binding_vals.get("bias"),
                                     m.epilogue)
        return out

    if contain is not None:
        out = contain(m, harness, ctx, binding_vals, attempt, on_select)
    else:
        if on_select is not None:
            on_select(m, harness, ctx)
        out = attempt(harness, ctx)
    if m.variant == "loop":
        # scan anchor: outvars = (final counter, final accumulator)
        counter_init = None
        nconsts = eqn.params["num_consts"]
        counter_init = read(eqn.invars[nconsts])
        length = eqn.params["length"]
        counter_fin = (jnp.asarray(counter_init)
                       + jnp.asarray(length).astype(eqn.outvars[0].aval.dtype))
        write(eqn.outvars[0], counter_fin.astype(eqn.outvars[0].aval.dtype))
        anchor_var = eqn.outvars[1]
        write(anchor_var, _coerce(out, anchor_var.aval))
        # any extra outvars (shouldn't exist for matched skeleta)
        for ov in eqn.outvars[2:]:
            raise NotImplementedError("unexpected extra scan outputs")
    else:
        write(eqn.outvars[0], _coerce(out, eqn.outvars[0].aval))


def _eval_scan_body(eqn, m: Match, select, read, write, ctx_factory,
                    on_select=None, contain=None):
    """Rebuild a ``lax.scan`` around a rewritten body (variant='scan_body'
    matches): the body was detected once; tracing it here selects kernels
    once, and the compiled loop reuses them on every iteration.  Operands
    closed over as scan consts stay concrete inside the body trace, so
    host-marshaling harnesses still work for loop-invariant sparse data."""
    params = eqn.params
    nconsts = params["num_consts"]
    ncarry = params["num_carry"]
    invals = [read(x) for x in eqn.invars]
    consts = invals[:nconsts]
    init = invals[nconsts:nconsts + ncarry]
    xs = invals[nconsts + ncarry:]
    body_cj, body_matches = m.body
    needed = needed_eqn_ids(body_cj, body_matches)

    def body_fn(carry, x):
        flat = list(consts) + list(carry) + list(x)
        outs = run_rewritten(body_cj, body_matches, select, flat,
                             ctx_factory, on_select, needed, contain)
        return tuple(outs[:ncarry]), tuple(outs[ncarry:])

    carry_out, ys = jax.lax.scan(
        body_fn, tuple(init), tuple(xs),
        length=params["length"], reverse=params["reverse"],
        unroll=params.get("unroll", 1))
    for ov, v in zip(eqn.outvars, list(carry_out) + list(ys)):
        write(ov, _coerce(v, ov.aval))


def _coerce(val, aval):
    val = jnp.asarray(val)
    if val.dtype != aval.dtype:
        val = val.astype(aval.dtype)
    if tuple(val.shape) != tuple(aval.shape):
        val = val.reshape(aval.shape)
    return val
