"""Contained execution and harness quarantine (the fail-safe layer).

LiLAC's contract is that accelerating a program must never make it worse
than the un-rewritten original: a harness that raises, returns the wrong
shape, or emits non-finite values is *our* failure, not the user's.  This
module supplies the two pieces that enforce it:

* :class:`Containment` — the wrapper every anchor invocation in
  :func:`repro.core.rewrite.run_rewritten` runs under.  A failed attempt
  (exception, non-finite output, output-size mismatch) quarantines that
  ``(computation, harness, variant)`` and retries the anchor with the
  next-best candidate, default variant first.  When candidates exhaust it
  raises :class:`ReferenceFallback`, which the pass manager catches by
  disabling the match — the anchor then evaluates as an ordinary jaxpr
  equation, i.e. the un-rewritten reference path, the always-available
  floor.
* :class:`QuarantineStore` — persisted quarantine records (reason, site,
  timestamp, TTL) on the shared :class:`~repro.core.jsonstore.JsonStore`
  disk protocol, so a harness that misbehaved in one process is not
  re-tried by the next until its TTL lapses.  The registry fingerprint is
  pinned to ``""``: quarantines deliberately survive harness-set changes
  — a record names its harness explicitly, and a crash yesterday is
  evidence today regardless of what else was registered.

Records expire (``LILAC_QUARANTINE_TTL`` seconds, default 3600) so a
transient fault — an OOM under memory pressure, a driver hiccup — does
not permanently forfeit the fastest kernel; re-admission goes back
through autotuning, which re-measures rather than trusting stale pins.

A third piece rides on the first two: :class:`AdaptiveShadowRate`, the
controller behind sampled shadow verification.  The env rate
(``LILAC_SHADOW_RATE`` for dispatch-level shadowing,
``LILAC_REQUEST_SHADOW_RATE`` for the serving tier) is a *floor*, re-read
on every dispatch; an incident — a shadow divergence or a containment
quarantine — spikes the effective rate by ``LILAC_SHADOW_SPIKE`` (default
16), and a streak of clean shadow checks decays it geometrically by
``LILAC_SHADOW_DECAY`` (default 0.5 per clean check) back to the floor.
Verification effort concentrates exactly when trust is lowest.

Env knobs: ``LILAC_QUARANTINE_CACHE`` (store path),
``LILAC_QUARANTINE_TTL`` (seconds; ``<= 0`` means never expire),
``LILAC_SHADOW_SPIKE`` / ``LILAC_SHADOW_DECAY`` (adaptive controller).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.jsonstore import JsonStore

_ENV_PATH = "LILAC_QUARANTINE_CACHE"
_ENV_TTL = "LILAC_QUARANTINE_TTL"
_ENV_SPIKE = "LILAC_SHADOW_SPIKE"
_ENV_DECAY = "LILAC_SHADOW_DECAY"
DEFAULT_TTL_S = 3600.0
DEFAULT_SHADOW_SPIKE = 16.0
DEFAULT_SHADOW_DECAY = 0.5


def default_quarantine_path() -> Path:
    env = os.environ.get(_ENV_PATH)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "lilac" / "quarantine.json"


def default_ttl_s() -> float:
    try:
        return float(os.environ.get(_ENV_TTL, DEFAULT_TTL_S))
    except ValueError:
        return DEFAULT_TTL_S


@dataclasses.dataclass
class QuarantineStats:
    added: int = 0
    hits: int = 0            # lookups answered "yes, quarantined"
    expired: int = 0         # records lazily purged on lookup
    invalidations: int = 0
    save_errors: int = 0
    corrupt_recoveries: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class QuarantineStore(JsonStore):
    """Persistent ``(computation, harness, variant) -> incident`` records.

    Layout::

        {"schema": 1, "registry": "",
         "entries": {"spmv.csr|pallas.ell|default": {
             "reason": "exception: ...", "site": "pallas.ell",
             "t": 1754640000.0, "ttl": 3600.0}}}

    ``variant`` is :func:`repro.core.autotune.variant_key` of the
    (schedule, fuse) the harness ran with — a bad schedule quarantines
    that schedule, not the harness wholesale; the ``"default"`` variant
    is what containment fallback and candidate filtering consult.
    """

    schema_version = 1

    def __init__(self, path: Optional[os.PathLike] = None):
        self.stats = QuarantineStats()   # before super(): _note_* hooks
        super().__init__(path, registry_fingerprint="")

    def default_path(self) -> Path:
        return default_quarantine_path()

    def _note_invalidation(self):
        self.stats.invalidations += 1

    def _note_save_error(self):
        self.stats.save_errors += 1

    def _note_corrupt_recovery(self):
        self.stats.corrupt_recoveries += 1

    # -- record surface ------------------------------------------------------

    @staticmethod
    def key_of(comp: str, harness: str, vkey: str = "default") -> str:
        return f"{comp}|{harness}|{vkey}"

    def _ensure_loaded(self):
        if not self.loaded:
            self.load()

    def _expired(self, rec: Dict[str, Any], now: Optional[float] = None
                 ) -> bool:
        ttl = float(rec.get("ttl", DEFAULT_TTL_S))
        if ttl <= 0:
            return False
        t = float(rec.get("t", 0.0))
        return (time.time() if now is None else now) - t > ttl

    def add(self, comp: str, harness: str, vkey: str = "default", *,
            reason: str, site: str = "", ttl: Optional[float] = None,
            persist: bool = True) -> str:
        self._ensure_loaded()
        key = self.key_of(comp, harness, vkey)
        self.entries[key] = {
            "reason": str(reason)[:500],
            "site": site,
            "t": time.time(),
            "ttl": float(ttl if ttl is not None else default_ttl_s()),
        }
        self.stats.added += 1
        if persist:
            self.save()
        return key

    def is_quarantined(self, comp: str, harness: str,
                       vkey: str = "default") -> bool:
        self._ensure_loaded()
        key = self.key_of(comp, harness, vkey)
        rec = self.entries.get(key)
        if rec is None:
            return False
        if self._expired(rec):
            del self.entries[key]
            self.stats.expired += 1
            return False
        self.stats.hits += 1
        return True

    def active(self) -> Dict[str, Dict[str, Any]]:
        """All unexpired records (purging expired ones as a side effect)."""
        self._ensure_loaded()
        now = time.time()
        dead = [k for k, r in self.entries.items() if self._expired(r, now)]
        for k in dead:
            del self.entries[k]
            self.stats.expired += 1
        return dict(self.entries)


_SHARED: Dict[str, QuarantineStore] = {}


def shared_quarantine(path: Optional[os.PathLike] = None) -> QuarantineStore:
    """Process-wide QuarantineStore per file: every compiled function and
    the autotuner consult one in-memory view (an incident observed by one
    function immediately protects the others)."""
    key = str(Path(path) if path is not None else default_quarantine_path())
    q = _SHARED.get(key)
    if q is None:
        q = _SHARED[key] = QuarantineStore(key)
    return q


def reset_shared_quarantine():
    """Drop the process-wide views (tests; an externally rewritten store
    file is otherwise invisible to functions compiled afterwards)."""
    _SHARED.clear()


# ---------------------------------------------------------------------------
# Contained anchor execution
# ---------------------------------------------------------------------------

class ReferenceFallback(Exception):
    """Every candidate for an anchor failed; the pass manager must disable
    the match so the anchor evaluates as a plain jaxpr equation."""

    def __init__(self, match, reason: str):
        super().__init__(
            f"all harness candidates failed for {match.computation} "
            f"({reason}); falling back to reference")
        self.match = match
        self.reason = reason


@dataclasses.dataclass
class ContainmentStats:
    contained_exceptions: int = 0
    nonfinite_outputs: int = 0
    shape_mismatches: int = 0
    quarantines: int = 0
    fallbacks: int = 0       # anchors that exhausted every candidate
    shadow_checks: int = 0
    shadow_divergences: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class Containment:
    """The per-anchor retry loop :func:`~repro.core.rewrite.run_rewritten`
    calls instead of invoking a harness directly.

    ``attempt(h, ctx)`` (supplied by the rewriter) runs the full invoke
    path — vjp wrapping, fusion gating, epilogue — for one candidate.
    Containment validates what comes back: a raised exception, an output
    whose element count cannot coerce to the anchor's output aval, or a
    concrete non-finite float array all count as failures; a tracer output
    is size-checked only (its values do not exist yet — runtime NaNs on
    the jitted path are the shadow verifier's and the baked-plan guards'
    job).  Each failure quarantines the exact ``(computation, harness,
    variant)`` and moves on; success returns the output unchanged, so the
    no-fault path adds one try/except frame and one size compare.
    """

    def __init__(self, registry, quarantine: QuarantineStore,
                 on_quarantine: Optional[Callable[..., None]] = None,
                 stats: Optional[ContainmentStats] = None):
        self.registry = registry
        self.quarantine = quarantine
        self.on_quarantine = on_quarantine
        self.stats = stats if stats is not None else ContainmentStats()

    def __call__(self, m, harness, ctx, binding_vals, attempt,
                 on_select=None):
        from repro.core.autotune import variant_key
        eqn = m.anchor_eqn
        aval = (eqn.outvars[1].aval if m.variant == "loop"
                else eqn.outvars[0].aval)
        tried = set()
        h, c = harness, ctx
        while True:
            if on_select is not None:
                on_select(m, h, c)
            tried.add(h.name)
            vkey = variant_key(getattr(c, "schedule", None),
                               getattr(c, "fuse", None))
            reason = None
            try:
                out = attempt(h, c)
            except Exception as e:  # containment boundary: degrade, never die
                self.stats.contained_exceptions += 1
                reason = f"exception: {type(e).__name__}: {e}"[:300]
                out = None
            if reason is None:
                reason = self._validate(out, aval)
            if reason is None:
                return out
            self._record(m, h, vkey, reason)
            nxt = self._next_candidate(m, c, tried)
            if nxt is None:
                self.stats.fallbacks += 1
                raise ReferenceFallback(m, reason)
            h, c = nxt

    def _validate(self, out, aval) -> Optional[str]:
        import jax
        import jax.numpy as jnp
        try:
            shape = getattr(out, "shape", None)
            if shape is None:
                return f"non-array output: {type(out).__name__}"
            if math.prod(shape) != math.prod(aval.shape):
                self.stats.shape_mismatches += 1
                return (f"shape mismatch: got {tuple(shape)}, "
                        f"anchor wants {tuple(aval.shape)}")
            if isinstance(out, jax.core.Tracer):
                return None
            dtype = getattr(out, "dtype", None)
            if dtype is not None and jnp.issubdtype(dtype, jnp.floating):
                if not bool(jnp.isfinite(out).all()):
                    self.stats.nonfinite_outputs += 1
                    return "non-finite output"
        except Exception:
            # the validator itself must never fail a healthy call
            return None
        return None

    def _record(self, m, h, vkey: str, reason: str):
        self.stats.quarantines += 1
        self.quarantine.add(m.computation, h.name, vkey,
                            reason=reason, site=h.name)
        if self.on_quarantine is not None:
            try:
                self.on_quarantine(m, h, vkey, reason)
            except Exception:
                pass

    def _next_candidate(self, m, ctx, tried) -> Optional[Tuple[Any, Any]]:
        """Next harness to try for this anchor: the platform default first
        (it is the best-vetted body), then registration order; always at
        the default (schedule=None, fuse=None) variant — a pinned schedule
        that just failed is no basis for trusting another tuned one."""
        cands = self.registry.candidates(m.computation, m.format,
                                         ctx.platform, ctx.mode)
        dname = self.registry.default_name(m.computation, ctx.platform)
        ordered = sorted(cands, key=lambda h: h.name != dname)
        for h in ordered:
            if h.name in tried:
                continue
            if self.quarantine.is_quarantined(m.computation, h.name):
                continue
            return h, dataclasses.replace(ctx, schedule=None, fuse=None)
        return None


def outputs_close(got, want, rtol: float = 1e-4, atol: float = 1e-5) -> bool:
    """Leafwise comparison for shadow verification: every pair of leaves
    must match in total size and (for floats) be ``allclose``; NaN in the
    accelerated output where the reference is finite is a divergence."""
    import numpy as np
    import jax
    g_leaves = jax.tree_util.tree_leaves(got)
    w_leaves = jax.tree_util.tree_leaves(want)
    if len(g_leaves) != len(w_leaves):
        return False
    for g, w in zip(g_leaves, w_leaves):
        ga, wa = np.asarray(g), np.asarray(w)
        if ga.size != wa.size:
            return False
        ga = ga.reshape(wa.shape)
        if np.issubdtype(wa.dtype, np.floating) \
                or np.issubdtype(wa.dtype, np.complexfloating):
            if not np.allclose(ga, wa, rtol=rtol, atol=atol, equal_nan=True):
                return False
            # equal_nan tolerates NaN only where the REFERENCE has NaN
            if np.isnan(ga).any() and not np.isnan(wa).any():
                return False
        else:
            if not (ga == wa).all():
                return False
    return True


# ---------------------------------------------------------------------------
# Adaptive shadow rate
# ---------------------------------------------------------------------------

def shadow_spike() -> float:
    """``LILAC_SHADOW_SPIKE``: incident multiplier (default 16, min 1)."""
    try:
        return max(1.0, float(os.environ.get(_ENV_SPIKE,
                                             DEFAULT_SHADOW_SPIKE)))
    except ValueError:
        return DEFAULT_SHADOW_SPIKE


def shadow_decay() -> float:
    """``LILAC_SHADOW_DECAY``: per-clean-check multiplier decay factor
    (default 0.5, clamped to (0, 1))."""
    try:
        d = float(os.environ.get(_ENV_DECAY, DEFAULT_SHADOW_DECAY))
    except ValueError:
        return DEFAULT_SHADOW_DECAY
    return min(max(d, 1e-6), 0.999999)


class AdaptiveShadowRate:
    """Incident-driven controller for sampled shadow verification.

    The env rate (``env_var``, or the explicit ``floor`` override) is a
    *floor*, not the rate: ``effective() = min(1, floor * multiplier)``.
    An incident (:meth:`spike` — a shadow divergence or a containment
    quarantine) raises the multiplier to ``LILAC_SHADOW_SPIKE``; each
    verified-clean shadow check (:meth:`clean`) decays it geometrically by
    ``LILAC_SHADOW_DECAY``.  Decay is evidence-driven — only a check that
    actually ran and matched counts, not mere passage of dispatches.

    The floor is re-read from the environment on every call, so operators
    can turn verification up on a live process; the re-read is an identity
    check on the cached env string, one dict lookup on the hot path.
    """

    def __init__(self, env_var: str = "LILAC_SHADOW_RATE",
                 floor: Optional[float] = None):
        self.env_var = env_var
        self._floor_override = floor
        self._raw: Optional[str] = object()  # sentinel != any env string
        self._floor_cached = 0.0
        self.multiplier = 1.0
        self.peak_multiplier = 1.0
        self.incidents = 0
        self.clean_streak = 0
        self.checks = 0

    def floor(self) -> float:
        if self._floor_override is not None:
            return min(max(float(self._floor_override), 0.0), 1.0)
        raw = os.environ.get(self.env_var)
        if raw is not self._raw:
            self._raw = raw
            try:
                self._floor_cached = min(max(float(raw or 0.0), 0.0), 1.0)
            except ValueError:
                self._floor_cached = 0.0
        return self._floor_cached

    def effective(self) -> float:
        return min(1.0, self.floor() * self.multiplier)

    def spike(self, reason: str = ""):
        self.incidents += 1
        self.clean_streak = 0
        self.multiplier = max(self.multiplier, shadow_spike())
        self.peak_multiplier = max(self.peak_multiplier, self.multiplier)

    def clean(self):
        self.checks += 1
        self.clean_streak += 1
        if self.multiplier > 1.0:
            self.multiplier = max(1.0, self.multiplier * shadow_decay())

    def snapshot(self) -> Dict[str, Any]:
        return {
            "floor": self.floor(),
            "multiplier": self.multiplier,
            "peak_multiplier": self.peak_multiplier,
            "effective": self.effective(),
            "incidents": self.incidents,
            "clean_streak": self.clean_streak,
            "checks": self.checks,
            "spike": shadow_spike(),
            "decay": shadow_decay(),
        }
