"""Executable plans: zero-overhead steady-state dispatch (paper §4.2/§5).

The paper's runtime claim is that harness insertion is *free at run time*:
LiLAC "maintains state between calls and minimizes data transfers", so the
accelerated library call costs no more than a hand-written integration.
Our reproduction picks the right (harness, schedule) winners (autotune)
and amortizes repacks (the data plane), but the rewritten program itself
was still *interpreted* — every call re-walked the jaxpr equation by
equation in Python.  This module turns a fully resolved rewrite into a
compile-once artifact, in two layers:

* :class:`ExecutablePlan` — once every match in a ``CompiledEntry`` has a
  definitive ``(harness, schedule)`` selection, the rewritten program is
  baked into ONE ``jax.jit``-compiled callable.  Marshaled operands (the
  ELL/BCSR buffers the data plane built) are hoisted out of the traced
  body as captured device-resident constants; fused epilogues trace
  in-line.  Steady-state dispatch is then: cheap guard check → one jitted
  call.  Guards are O(arity): aval (shape/dtype) checks on every leaf,
  plus *identity* checks on the leaves that feed marshal clauses — JAX
  arrays are immutable, so object identity proves the hoisted buffers are
  still valid; :class:`~repro.core.marshal.TrackedArray` operands are
  guarded by their O(1) version instead, so a functional matrix update
  busts the baked plan exactly like an mprotect fault would.
* :class:`PlanCache` — a schema-versioned JSON store
  (``~/.cache/lilac/plans.json``, overridable via ``LILAC_PLAN_CACHE``)
  mapping ``(jaxpr fingerprint, platform, mode, policy, declared marshal
  reuse)`` — under a registry-fingerprint header — to the serialized
  detection report and the
  pinned ``(harness, schedule)`` decisions.  A warm process re-traces the
  user function (cheap), fingerprints the jaxpr, and rehydrates matches +
  pins from disk: detection and tuning are skipped entirely and the first
  call goes straight to plan baking.

Environment knobs:

  LILAC_PLAN_CACHE          plan-cache file path
                            (default ~/.cache/lilac/plans.json)
  LILAC_PLAN_CACHE_DISABLE  "1" -> never read or persist plans
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.jsonstore import JsonStore
from repro.core.marshal import TrackedArray, fingerprint, version_token

SCHEMA_VERSION = 1
_ENV_PATH = "LILAC_PLAN_CACHE"
_ENV_DISABLE = "LILAC_PLAN_CACHE_DISABLE"

#: writable numpy closure captures above this size refuse to bake: their
#: const guard must hash exactly (the interpreter re-reads captures
#: exactly), and exact hashing per dispatch would defeat the plan's
#: purpose.  Arguments and TrackedArray captures have no such bound.
CONST_GUARD_MAX_BYTES = 1 << 20

class PlanBakeError(RuntimeError):
    """Baking failed (untraceable harness body, drifted marshal clauses).
    The pass manager catches it and stays on the interpreter path."""


class PlanDonationError(ValueError):
    """``donate_args`` misuse (out-of-range position, or donating a leaf
    that feeds a marshaled operand).  Unlike other bake failures this is a
    user error, so the pass manager re-raises it."""


def default_plan_cache_path() -> Path:
    env = os.environ.get(_ENV_PATH)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "lilac" / "plans.json"


def plan_cache_disabled() -> bool:
    return os.environ.get(_ENV_DISABLE, "") == "1"


_SHARED_CACHES: Dict[Tuple[str, str], "PlanCache"] = {}


def shared_plan_cache(path, registry_fingerprint: str) -> "PlanCache":
    """Process-wide PlanCache per (file, registry-fingerprint): N compiled
    functions share one in-memory view instead of each re-reading and
    re-parsing the JSON file on their first call.  ``path=None`` resolves
    the env/default location.  Injected instances (tests) bypass this."""
    key = (str(Path(path) if path is not None else default_plan_cache_path()),
           registry_fingerprint)
    pc = _SHARED_CACHES.get(key)
    if pc is None:
        pc = _SHARED_CACHES[key] = PlanCache(
            key[0], registry_fingerprint=registry_fingerprint)
    return pc


def reset_shared_plan_caches():
    """Drop the process-wide PlanCache views (tests; a deleted or
    externally rewritten cache file is otherwise invisible to functions
    compiled afterwards in the same process)."""
    _SHARED_CACHES.clear()


# ---------------------------------------------------------------------------
# Match serialization: jaxpr atoms <-> stable positional references
# ---------------------------------------------------------------------------
#
# A detected Match points into a specific ClosedJaxpr: its anchor equation,
# claimed equations and binding atoms are *objects* of that jaxpr.  Both the
# normalized jaxpr and its pretty-printed form are deterministic for a given
# program, so every atom has a stable positional address:
#
#   ["cv", i]          i-th constvar
#   ["iv", i]          i-th invar
#   ["ev", ei, oi]     oi-th outvar of the ei-th equation
#   ["lit", v, dt, shape, weak]   a Literal (value + aval)
#   ["pyint"/"pybool"/"pyfloat", v]  a python scalar in the binding
#
# Rehydration resolves the addresses against a freshly traced jaxpr and
# validates the anchor primitive names, so a stale or colliding record
# degrades to a cache miss (full detection), never to a wrong rewrite.

def _atom_refs(jaxpr) -> Dict[Any, Tuple]:
    ref: Dict[Any, Tuple] = {}
    for i, v in enumerate(jaxpr.constvars):
        ref[v] = ("cv", i)
    for i, v in enumerate(jaxpr.invars):
        ref[v] = ("iv", i)
    for ei, eqn in enumerate(jaxpr.eqns):
        for oi, ov in enumerate(eqn.outvars):
            ref[ov] = ("ev", ei, oi)
    return ref


def _ser_atom(v, ref: Dict[Any, Tuple]) -> List:
    from jax.extend import core as jex_core

    if isinstance(v, bool):
        return ["pybool", v]
    if isinstance(v, (int, np.integer)):
        return ["pyint", int(v)]
    if isinstance(v, (float, np.floating)):
        return ["pyfloat", float(v)]
    if isinstance(v, jex_core.Literal):
        arr = np.asarray(v.val)
        return ["lit", arr.tolist(), str(arr.dtype), list(arr.shape),
                bool(getattr(v.aval, "weak_type", False))]
    r = ref.get(v)
    if r is None:
        raise PlanBakeError(f"binding atom {v!r} has no stable address")
    return list(r)


def _de_atom(spec: Sequence, jaxpr):
    from jax.extend import core as jex_core

    tag = spec[0]
    if tag == "pybool":
        return bool(spec[1])
    if tag == "pyint":
        return int(spec[1])
    if tag == "pyfloat":
        return float(spec[1])
    if tag == "lit":
        dt = np.dtype(spec[2])
        arr = np.asarray(spec[1], dtype=dt).reshape(spec[3])
        aval = jax.core.ShapedArray(tuple(spec[3]), dt, weak_type=spec[4])
        return jex_core.Literal(arr if arr.ndim else arr[()], aval)
    if tag == "cv":
        return jaxpr.constvars[spec[1]]
    if tag == "iv":
        return jaxpr.invars[spec[1]]
    if tag == "ev":
        return jaxpr.eqns[spec[1]].outvars[spec[2]]
    raise KeyError(f"unknown atom tag {tag!r}")


def serialize_matches(closed_jaxpr, matches) -> List[Dict[str, Any]]:
    """JSON-able form of a detection report against ``closed_jaxpr``.
    Raises :class:`PlanBakeError` when a match cannot be addressed."""
    jaxpr = closed_jaxpr.jaxpr
    ref = _atom_refs(jaxpr)
    eqn_idx = {id(e): i for i, e in enumerate(jaxpr.eqns)}
    out = []
    for m in matches:
        ei = eqn_idx.get(id(m.anchor_eqn))
        if ei is None:
            raise PlanBakeError("anchor equation not in jaxpr")
        try:
            anchor = _ser_atom(m.anchor, ref)
        except (PlanBakeError, TypeError):
            anchor = None
        out.append({
            "computation": m.computation,
            "variant": m.variant,
            "format": m.format,
            "epilogue": m.epilogue,
            "notes": m.notes,
            "anchor_eqn": ei,
            "anchor_prim": m.anchor_eqn.primitive.name,
            "anchor": anchor,
            "claimed_eqns": [eqn_idx[id(e)] for e in m.claimed_eqns
                             if id(e) in eqn_idx],
            "binding": {k: _ser_atom(v, ref) for k, v in m.binding.items()},
        })
    return out


def detect_digest(serialized: List[Dict[str, Any]]) -> str:
    """Content digest of a serialized detection report (integrity field of
    plan-cache records; also a cheap cross-process equality check)."""
    blob = json.dumps(serialized, sort_keys=True).encode()
    return hashlib.blake2b(blob, digest_size=8).hexdigest()


def rehydrate_matches(closed_jaxpr, serialized) -> Optional[List[Any]]:
    """Resolve serialized matches against a freshly traced ``closed_jaxpr``.
    Returns None (-> treat as a cache miss) when anything fails to line up
    with the live jaxpr."""
    from repro.core.detect import Match

    jaxpr = closed_jaxpr.jaxpr
    try:
        out = []
        for rec in serialized:
            ei = rec["anchor_eqn"]
            if not (0 <= ei < len(jaxpr.eqns)):
                return None
            eqn = jaxpr.eqns[ei]
            if eqn.primitive.name != rec["anchor_prim"]:
                return None
            binding = {k: _de_atom(v, jaxpr)
                       for k, v in rec["binding"].items()}
            anchor = (_de_atom(rec["anchor"], jaxpr)
                      if rec.get("anchor") else eqn.outvars[0])
            claimed = tuple(jaxpr.eqns[i] for i in rec.get("claimed_eqns", ())
                            if 0 <= i < len(jaxpr.eqns))
            out.append(Match(
                computation=rec["computation"], variant=rec["variant"],
                format=rec["format"], anchor=anchor, anchor_eqn=eqn,
                binding=binding, notes=rec.get("notes", ""),
                claimed_eqns=claimed, epilogue=rec.get("epilogue")))
        return out
    except (KeyError, IndexError, TypeError, ValueError):
        return None


def plan_key(closed_jaxpr, platform: str, mode: str, policy: str,
             reuse: float = 100.0) -> str:
    """Cache key for one compiled signature: a fingerprint of the
    normalized jaxpr (pretty-printed form + sampled const fingerprints)
    qualified by platform/mode/policy and the marshal policy's declared
    ``reuse`` frequency — the autotuner's repack-amortized argmin depends
    on reuse, so pins measured at one call frequency must never be served
    verbatim to a compile declaring another.  The registry fingerprint
    lives in the cache-file header, so a harness-set change drops every
    plan."""
    h = hashlib.blake2b(digest_size=16)
    h.update(str(closed_jaxpr.jaxpr).encode())
    for c in closed_jaxpr.consts:
        h.update(repr(fingerprint(c)).encode())
    return f"{h.hexdigest()}|{platform}|{mode}|{policy}|r{reuse:g}"


# ---------------------------------------------------------------------------
# Persistent plan cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanCacheStats:
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    stores: int = 0
    rejected: int = 0        # on-disk record failed rehydration
    invalidations: int = 0   # schema/registry-fingerprint drop
    save_errors: int = 0
    corrupt_recoveries: int = 0  # torn cache file quarantined, fresh start

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class PlanCache(JsonStore):
    """Versioned JSON store of resolved plans — the flat-keyed
    :class:`repro.core.jsonstore.JsonStore` disk protocol.

    Layout::

        {"schema": 1, "registry": "<fingerprint>",
         "entries": {"<jaxpr-fp>|<platform>|<mode>|<policy>|r<reuse>": {
             "matches": [...], "pins": {"0": ["pallas.ell", {...}]},
             "n_eqns": 12, "detect_digest": "..."}}}

    Writes are atomic (tempfile + ``os.replace``) and merge-on-save under
    an advisory lock; a registry-fingerprint or schema mismatch drops the
    whole file (detection reports are only as durable as the harness set
    that produced their pins).
    """

    schema_version = SCHEMA_VERSION

    def __init__(self, path: Optional[os.PathLike] = None,
                 registry_fingerprint: str = ""):
        self.stats = PlanCacheStats()   # before super(): _note_* hooks
        super().__init__(path, registry_fingerprint)

    def default_path(self) -> Path:
        return default_plan_cache_path()

    def _note_invalidation(self):
        self.stats.invalidations += 1

    def _note_corrupt_recovery(self):
        self.stats.corrupt_recoveries += 1

    def _note_save_error(self):
        self.stats.save_errors += 1

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        rec = self.entries.get(key)
        if rec is not None:
            self.stats.memory_hits += 1
            return rec
        if not self.loaded:
            self.load()
            rec = self.entries.get(key)
            if rec is not None:
                self.stats.disk_hits += 1
                return rec
        self.stats.misses += 1
        return None

    def put(self, key: str, record: Dict[str, Any], persist: bool = True):
        self.entries[key] = record
        self.stats.stores += 1
        if persist:
            self.save()


# ---------------------------------------------------------------------------
# Recording: capture one interpreted call's selections + marshaled buffers
# ---------------------------------------------------------------------------

class _Slot:
    """What one match contributed during the recorded call."""
    __slots__ = ("harness", "schedule", "fuse", "buffers")

    def __init__(self):
        self.harness = None
        self.schedule = None
        self.fuse = None
        self.buffers: List[Any] = []


class PlanRecorder:
    """Observes one interpreted call: per match, the finally selected
    harness, its schedule variant (schedule + epilogue-fusion decision),
    and the marshaled values its clauses produced (in clause order) —
    everything baking needs."""

    def __init__(self):
        self.slots: Dict[int, _Slot] = {}

    def slot(self, m) -> _Slot:
        return self.slots.setdefault(id(m.anchor_eqn), _Slot())

    def begin(self, m, harness, schedule, fuse=None):
        """Called by ``on_select`` AFTER selection: autotune measurement
        may have routed candidate repacks through the recording cache, so
        the buffer list restarts here — only the winner's final execution
        is recorded."""
        s = self.slot(m)
        s.harness = harness
        s.schedule = schedule
        s.fuse = fuse
        s.buffers.clear()

    def complete_for(self, matches) -> bool:
        return all(
            (s := self.slots.get(id(m.anchor_eqn))) is not None
            and s.harness is not None
            for m in matches)


class _RecordingNone:
    """Recording stand-in for ``cache=None`` (marshaling disabled): every
    repack recomputes, and the produced value is recorded."""
    __slots__ = ("_sink",)

    def __init__(self, sink: List[Any]):
        self._sink = sink

    def get(self, name, keys, compute):
        val = compute()
        self._sink.append(val)
        return val


class _RecordingCache:
    """Transparent recorder around a MarshalingCache (no ``ensure``)."""
    __slots__ = ("_inner", "_sink")

    def __init__(self, inner, sink: List[Any]):
        self._inner = inner
        self._sink = sink

    def get(self, name, keys, compute):
        val = self._inner.get(name, keys, compute)
        self._sink.append(val)
        return val

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _RecordingPlane(_RecordingCache):
    """Transparent recorder around a DataPlane (has ``ensure``)."""
    __slots__ = ()

    def ensure(self, src, dst, keys, binding, fallback=None):
        val = self._inner.ensure(src, dst, keys, binding, fallback=fallback)
        self._sink.append(val)
        return val


def recording_cache(inner, sink: List[Any]):
    """Wrap a call's marshaling cache so produced values are recorded.
    Mirrors the generated wrapper's dispatch exactly: the proxy exposes
    ``ensure`` only when the wrapped cache does."""
    if inner is None:
        return _RecordingNone(sink)
    if hasattr(inner, "ensure"):
        return _RecordingPlane(inner, sink)
    return _RecordingCache(inner, sink)


class _PlanBuffers:
    """The bake-time stand-in for the data plane: marshal clauses replay
    the recorded buffers (in clause order) as captured constants instead
    of fingerprinting traced operands."""
    __slots__ = ("_vals", "_i")

    def __init__(self, values: Sequence[Any]):
        self._vals = tuple(values)
        self._i = 0

    def _next(self):
        if self._i >= len(self._vals):
            raise PlanBakeError(
                "marshal clause count drifted between record and bake")
        v = self._vals[self._i]
        self._i += 1
        return v

    def get(self, name, keys, compute):
        return self._next()

    def ensure(self, src, dst, keys, binding, fallback=None):
        return self._next()


# ---------------------------------------------------------------------------
# Guards
# ---------------------------------------------------------------------------

class _Guard:
    """One marshal-source leaf, guarded by :func:`~repro.core.marshal.
    version_token`: object identity for immutable (jax) arrays, the O(1)
    (base-token, version) pair for TrackedArray operands.  A strong
    reference keeps the token's ``id`` unambiguous.

    Writable ``np.ndarray`` operands are the one case identity cannot
    cover — the same object can be mutated in place — so they carry a
    content fingerprint checked on every dispatch.  For marshal-source
    *leaves* the default (sampled-above-64KB) fingerprint keeps parity
    with the interpreter's marshaling-cache keying; const guards pass
    ``exact=True`` because the interpreter re-reads closure captures
    exactly on every call — a sampled hash would miss a single-element
    edit of a large capture that ``bake=False`` would honor."""
    __slots__ = ("pos", "exact", "ref", "token", "content_fp")

    def __init__(self, pos: int, leaf, exact: bool = False):
        self.pos = pos
        self.exact = exact
        self.rebind(leaf)

    def rebind(self, leaf):
        self.ref = leaf
        self.token = version_token(leaf)
        self.content_fp = (fingerprint(leaf, self.exact)
                           if isinstance(leaf, np.ndarray)
                           and leaf.flags.writeable else None)

    def ok(self, leaf) -> bool:
        if version_token(leaf) != self.token:
            return False
        if self.content_fp is not None and \
                fingerprint(leaf, self.exact) != self.content_fp:
            return False
        return True


def leaf_templates(flat) -> Tuple:
    """THE per-leaf keying semantics, shared by every dispatch layer:
    anything with shape+dtype — including numpy scalars like
    ``np.float64``, which ARE ``float`` instances but carry avals — keys
    as ``("a", shape, dtype)``; python ints/bools key on their value
    (they may steer control flow); any other python leaf keys on its
    type only (``("p", type, None)``).  ``pass_manager._signature`` (the
    compile-dict key), the last-entry fast path (:func:`leaves_match`)
    and the baked-plan guard specs are all derived from this one
    function, so they cannot drift."""
    out = []
    for a in flat:
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            out.append(("a", a.shape if type(a.shape) is tuple
                        else tuple(a.shape), a.dtype))
        else:
            out.append(("p", type(a),
                        a if isinstance(a, (int, bool)) else None))
    return tuple(out)


def leaves_match(templates: Tuple, flat) -> bool:
    """Loop-compare live leaves against stored templates (no tuple
    rebuild, no dict hash) — the last-entry fast path."""
    if len(templates) != len(flat):
        return False
    for t, a in zip(templates, flat):
        if t[0] == "a":
            if (not hasattr(a, "shape") or not hasattr(a, "dtype")
                    or a.shape != t[1] or a.dtype != t[2]):
                return False
        else:
            if type(a) is not t[1]:
                return False
            if t[2] is not None and a != t[2]:
                return False
    return True


def _aval_specs(raw_flat) -> Tuple:
    """Baked-plan guard templates: :func:`leaf_templates` over the
    TrackedArray-unwrapped leaves (plans guard the wrapped operand but
    dispatch the unwrapped array)."""
    return leaf_templates([x.arr if isinstance(x, TrackedArray) else x
                           for x in raw_flat])


def marshal_guard_positions(closed_jaxpr, match_harness_pairs) -> frozenset:
    """Flat-leaf positions whose content the hoisted marshal buffers were
    derived from: the binding atoms named by each selected harness's
    marshal-clause keys, closed transitively back to the jaxpr invars.
    (Closure-captured operands need no position: EVERY writable numpy
    const is fingerprint-guarded by ``bake_plan``, marshal source or
    not.)"""
    from jax.extend import core as jex_core

    jaxpr = closed_jaxpr.jaxpr
    targets = set()
    for m, h in match_harness_pairs:
        for cl in getattr(h, "marshal", ()) or ():
            for alts in cl.keys:
                for k in alts:
                    v = m.binding.get(k)
                    if v is not None and not isinstance(
                            v, (int, float, bool, jex_core.Literal)):
                        targets.add(v)
                        break
    if not targets:
        return frozenset()
    need = set(targets)
    for eqn in reversed(jaxpr.eqns):
        if any(ov in need for ov in eqn.outvars):
            for iv in eqn.invars:
                if not isinstance(iv, jex_core.Literal):
                    need.add(iv)
    invar_pos = {v: i for i, v in enumerate(jaxpr.invars)}
    return frozenset(invar_pos[v] for v in need if v in invar_pos)


# ---------------------------------------------------------------------------
# The plan itself
# ---------------------------------------------------------------------------

class ExecutablePlan:
    """A baked realization of one ``CompiledEntry``: the rewritten program
    as a single jitted callable plus the guards that keep it honest."""

    def __init__(self, jitted, in_tree, out_tree, avals, guards,
                 report, selections, schedules, hoisted, enabled: bool,
                 const_guards=(), registry_epoch: int = 0,
                 trace_servable: bool = False, fuses=None):
        # registry epoch at bake time: the pass manager refuses to serve
        # (or guard-refresh) this plan once any harness (re-)registration
        # has moved the registry on — a replaced kernel body must never
        # keep running from a stale jitted executable
        self.registry_epoch = registry_epoch
        self.jitted = jitted
        self.in_tree = in_tree
        self.out_tree = out_tree
        self.avals = avals                   # per-leaf templates
        self.guards = guards                 # marshal-source leaf guards
        # closure-captured writable-numpy marshal sources: re-checked by
        # content fingerprint each dispatch (no leaf carries them)
        self.const_guards = tuple(const_guards)
        self.report = report                 # the entry's DetectionReport
        self.selections = selections         # [(Match, harness name)]
        self.schedules = schedules           # aligned schedule variants
        # aligned epilogue-fusion decisions (None = declared default)
        self.fuses = list(fuses) if fuses is not None \
            else [None] * len(selections)
        self.hoisted = hoisted               # {anchor id: (buffers...)}
        self.enabled = enabled
        # True when every selected harness composes with transform traces
        # (jit_safe, or wrapped in a declared custom_vjp): the plan may
        # then serve abstract (tracer) leaves, EXCEPT at marshal-guarded
        # positions — hoisted buffers were derived from those leaves'
        # *contents*, which a tracer cannot attest to
        self.trace_servable = bool(trace_servable)
        self._guarded_pos = frozenset(g.pos for g in guards)
        self.hits = 0

    def match_and_unwrap(self, in_tree, leaves, enabled: bool):
        """The per-call guard: returns the (TrackedArray-unwrapped) leaf
        list when this plan can serve the call, else None.  One python
        loop over the arity — the whole point of baking."""
        if enabled is not self.enabled or in_tree != self.in_tree:
            return None
        specs = self.avals
        if len(leaves) != len(specs):
            return None
        out = list(leaves)
        for i, spec in enumerate(specs):
            x = out[i]
            if isinstance(x, TrackedArray):
                x = x.arr
                out[i] = x
            if spec[0] == "a":
                if isinstance(x, jax.core.Tracer) and (
                        not self.trace_servable or i in self._guarded_pos):
                    return None
                if (getattr(x, "shape", None) != spec[1]
                        or getattr(x, "dtype", None) != spec[2]):
                    return None
            else:
                if type(x) is not spec[1]:
                    return None
                if spec[2] is not None and x != spec[2]:
                    return None
        for g in self.guards:
            if not g.ok(leaves[g.pos]):
                return None
        for g in self.const_guards:
            if not g.ok(g.ref):
                return None
        return out

    def refresh_guards(self, raw_leaves):
        """Re-anchor the identity guards on new (content-identical) leaf
        objects: the data plane proved the hoisted buffers still apply, so
        only the expected identities move."""
        for g in self.guards:
            g.rebind(raw_leaves[g.pos])

    def consts_ok(self) -> bool:
        """True while no guarded closure capture has mutated.  Checked
        before the guard-refresh shortcut: a stale const means the jitted
        executable itself is stale, so the plan must re-bake rather than
        merely re-anchor its leaf guards."""
        return all(g.ok(g.ref) for g in self.const_guards)

    def same_hoisted(self, recorder: PlanRecorder) -> bool:
        """True when a recorded call produced exactly the buffers this
        plan captured (object identity: data-plane hits return the cached
        objects) — the plan survives, only its guards need re-anchoring."""
        for aid, bufs in self.hoisted.items():
            s = recorder.slots.get(aid)
            if s is None or len(s.buffers) != len(bufs):
                return False
            if any(a is not b for a, b in zip(s.buffers, bufs)):
                return False
        return True

    def hoisted_nbytes(self) -> int:
        """Total bytes of marshal products pinned by this plan (what the
        serving tier reports as per-plan resident overhead)."""
        total = 0
        for bufs in self.hoisted.values():
            for b in bufs:
                total += int(getattr(b, "nbytes", 0) or 0)
        return total

    def describe(self) -> Dict[str, Any]:
        """JSON-able summary for observability (serve/metrics, plan_info)."""
        arr = [s for s in self.avals if s[0] == "a"]
        return {
            "arity": len(self.avals),
            "array_leaves": [[list(s[1]), str(s[2])] for s in arr],
            "selections": [name for _, name in self.selections],
            "schedules": [s for s in self.schedules],
            "fuses": [f for f in self.fuses],
            "guards": len(self.guards),
            "const_guards": len(self.const_guards),
            "hoisted_nbytes": self.hoisted_nbytes(),
            "enabled": self.enabled,
            "hits": self.hits,
        }


def bake_plan(*, closed_jaxpr, matches, needed, recorder: PlanRecorder,
              raw_flat, flat, in_tree, out_tree, report,
              mode: str, platform: str, enabled: bool,
              donate: Tuple[int, ...] = (),
              registry_epoch: int = 0) -> ExecutablePlan:
    """Bake one resolved rewrite into an :class:`ExecutablePlan`.

    ``raw_flat`` are the call's leaves as passed (possibly TrackedArray),
    ``flat`` the unwrapped ones the trace runs on.  Raises
    :class:`PlanBakeError` (or whatever the trace raises) on failure; the
    caller decides whether to disable baking for the entry."""
    import jax.numpy as jnp

    from repro.core import faults
    from repro.core.harness import CallCtx
    from repro.core.rewrite import run_rewritten

    if faults.ACTIVE is not None:
        faults.fail("bake_raise", "bake")
    if not recorder.complete_for(matches):
        raise PlanBakeError("recorded call is missing selections")
    slots = {id(m.anchor_eqn): recorder.slots[id(m.anchor_eqn)]
             for m in matches}

    donate = tuple(sorted(set(int(i) for i in donate)))
    for i in donate:
        if not (0 <= i < len(flat)):
            raise PlanDonationError(f"donate_args position {i} out of range "
                                    f"(call has {len(flat)} leaves)")

    guard_positions = marshal_guard_positions(
        closed_jaxpr, [(m, slots[id(m.anchor_eqn)].harness)
                       for m in matches])
    bad = set(donate) & guard_positions
    if bad:
        raise PlanDonationError(
            f"donate_args positions {sorted(bad)} feed marshaled operands; "
            f"donating them would invalidate the hoisted buffers")

    def select(m, binding=None, ctx=None):
        s = slots[id(m.anchor_eqn)]
        if ctx is not None:
            ctx.schedule = s.schedule
            ctx.fuse = s.fuse
        return s.harness

    def ctx_factory(m):
        s = slots[id(m.anchor_eqn)]
        return CallCtx(mode=mode, cache=_PlanBuffers(s.buffers),
                       format=m.format, platform=platform,
                       schedule=s.schedule, epilogue=m.epilogue,
                       fuse=s.fuse)

    def baked(*leaves):
        return run_rewritten(closed_jaxpr, matches, select, list(leaves),
                             ctx_factory, needed=needed)

    jitted = jax.jit(baked, donate_argnums=donate)
    traced = any(isinstance(x, jax.core.Tracer) for x in flat)
    if traced:
        # Baking under a transform trace (the call that resolved the
        # rewrite ran inside jax.grad/vmap/jit): there are no concrete
        # leaves to warm up with, and a guard anchored on a tracer would
        # be meaningless.  The caller guaranteed no marshal-source
        # position holds a tracer, so guard construction below only ever
        # sees concrete leaves; warm-up is deferred to first dispatch.
        for pos in guard_positions:
            if isinstance(raw_flat[pos], jax.core.Tracer):
                raise PlanBakeError(
                    "marshal-source leaf is a tracer; cannot guard")
    else:
        # Warm-up compile now, so the first fast-path call is already
        # fast — and so an untraceable body fails HERE (the caller falls
        # back to the interpreter) rather than on a later dispatch.
        # Donated positions get copies: the caller's buffers must survive
        # the warm-up.
        warm = list(flat)
        for i in donate:
            warm[i] = jnp.array(warm[i])
        jax.block_until_ready(jitted(*warm))

    guards = [_Guard(pos, raw_flat[pos]) for pos in sorted(guard_positions)]
    # Closure captures: jax keeps them as live references in consts, so
    # the interpreter re-reads them every call while the jitted plan
    # froze their values at trace time.  Immutable (jax) consts cannot
    # diverge; EVERY writable numpy const — marshal source or plain
    # operand (e.g. a captured bias) — gets a per-dispatch EXACT content
    # fingerprint so in-place mutation busts the plan like it would have
    # changed the interpreter's output.  Exact hashing is O(bytes) per
    # dispatch, so captures past the bound refuse to bake instead of
    # silently making the "zero-overhead" path slower than the
    # interpreter — pass big matrices as arguments (identity-guarded,
    # free) or TrackedArray (O(1) version) to get a plan.
    writable = [c for c in closed_jaxpr.consts
                if isinstance(c, np.ndarray) and c.flags.writeable]
    big = [c for c in writable if c.nbytes > CONST_GUARD_MAX_BYTES]
    if big:
        raise PlanBakeError(
            f"writable numpy closure capture of {big[0].nbytes} bytes "
            f"exceeds the exact-guard bound ({CONST_GUARD_MAX_BYTES}); "
            f"pass it as an argument or TrackedArray to enable baking")
    const_guards = [_Guard(-1, c, exact=True) for c in writable]
    selections = [(m, slots[id(m.anchor_eqn)].harness.name) for m in matches]
    schedules = [slots[id(m.anchor_eqn)].schedule for m in matches]
    fuses = [slots[id(m.anchor_eqn)].fuse for m in matches]
    hoisted = {aid: tuple(s.buffers) for aid, s in slots.items()}
    trace_servable = all(
        s.harness.jit_safe or getattr(s.harness, "vjp", None) is not None
        for s in slots.values())
    return ExecutablePlan(jitted, in_tree, out_tree, _aval_specs(raw_flat),
                          guards, report, selections, schedules, hoisted,
                          enabled, const_guards=const_guards,
                          registry_epoch=registry_epoch,
                          trace_servable=trace_servable, fuses=fuses)
