"""The user-facing LiLAC pass (the paper's Fig. 1 compiler flow).

``compile(fn, mode=...)`` is the single entry point (exposed as
``repro.lilac.compile``); an optional :class:`CompileOptions` dataclass
carries the full configuration.

``mode="trace"`` — returns a function with the same signature whose jaxpr
    has detected computations replaced by jit-safe harnesses.  Wrap it in
    ``jax.jit`` exactly like the original; this is how the LM framework
    consumes LiLAC (MoE layers etc.).

``mode="host"`` — the paper's runtime model.  Each call executes the
    rewritten program eagerly; harnesses may be host-only and use the
    marshaling cache, so format repacks / derived invariants are amortized
    across calls exactly like the paper's mprotect machinery (Fig. 18).
    Use for solver-style apps that call the step repeatedly.

Both share: trace -> normalize -> detect (backtracking) -> rewrite.
Detection runs once per input-shape signature and is cached — and, when
the persistent plan cache (``repro.core.plan``) holds a record for the
jaxpr, it is skipped entirely: matches and autotune pins rehydrate from
disk.  Once every match has a definitive ``(harness, schedule)`` decision
and a concrete call has run, the rewrite is *baked* into an
:class:`~repro.core.plan.ExecutablePlan` — steady-state dispatch becomes a
guard check plus one ``jax.jit`` call instead of the eqn-by-eqn
interpreter (see ``docs/dispatch.md``).

``lilac_optimize`` / ``lilac_accelerate`` are deprecation shims over
``compile`` kept for out-of-repo callers; they warn with
:class:`LilacDeprecationWarning`, which the test suite escalates to an
error so in-repo code stays on the new surface.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Tuple

import jax

from repro.core import detect as D
from repro.core import faults
from repro.core import harness as H
from repro.core import plan as P
from repro.core import plan_search as PS
from repro.core import resilience as R
from repro.core.autotune import autotune_disabled, variant_key
from repro.core.marshal import (DataPlane, MarshalingCache, MarshalPolicy,
                                TrackedArray)
from repro.core.rewrite import needed_eqn_ids, run_rewritten

_ENV_SHADOW = "LILAC_SHADOW_RATE"


def shadow_rate() -> float:
    """``LILAC_SHADOW_RATE`` in [0, 1]: the *floor* fraction of served
    dispatches that also run the un-rewritten reference for comparison.
    Since the adaptive controller landed this is re-read per dispatch
    (via an identity check on the cached env string, so the steady-state
    cost stays one dict lookup); divergence or quarantine incidents spike
    the effective rate above this floor — see
    :class:`repro.core.resilience.AdaptiveShadowRate`."""
    try:
        r = float(os.environ.get(_ENV_SHADOW, "0") or 0.0)
    except ValueError:
        return 0.0
    return min(max(r, 0.0), 1.0)


@dataclasses.dataclass
class CompiledEntry:
    closed_jaxpr: Any
    report: D.DetectionReport
    out_tree: Any
    # autotune pins: match index -> (harness name, schedule variant, fuse
    # realization), filled at first lowering for this signature so later
    # calls (and re-traces under jit) reuse the measured winner — including
    # its swept kernel schedule and epilogue-fusion decision — without
    # consulting the tuner again.  After the joint plan search runs, these
    # hold the jointly-optimal assignment, not the per-match argmins.
    pins: Dict[int, Tuple[str, Optional[Dict[str, Any]], Optional[bool]]] = \
        dataclasses.field(default_factory=dict)
    # id(anchor eqn) -> match index, built once at entry construction (the
    # pinned-select path used to rebuild it per call)
    idx_of: Dict[int, int] = dataclasses.field(default_factory=dict)
    # persistent-plan-cache plumbing
    cache_key: Optional[str] = None
    persisted: bool = False
    # the baked executable plan (None until the rewrite is resolved and a
    # concrete call has run; see docs/dispatch.md for the lifecycle)
    plan: Optional[P.ExecutablePlan] = None
    no_bake: bool = False
    bake_error: Optional[str] = None
    rebakes: int = 0
    # joint whole-program plan search (repro.core.plan_search): the report
    # of the last search and whether the search has run (or been skipped)
    # for this entry.  Entries rehydrated from the plan cache with complete
    # pins start done: the persisted pins already ARE the joint assignment,
    # so warm processes serve it with zero re-search.
    joint: Optional[Dict[str, Any]] = None
    joint_done: bool = False
    # match indices (into the flattened report) whose every harness
    # candidate failed under containment: these anchors evaluate as plain
    # jaxpr equations — the reference floor — until the entry is rebuilt
    disabled: set = dataclasses.field(default_factory=set)
    # memoized liveness (rewrite.needed_eqn_ids), keyed by the anchor-id
    # set of the match list actually evaluated — containment can disable
    # individual matches, so "full" and "empty" are just two of the keys
    _needed: Dict[FrozenSet[int], frozenset] = \
        dataclasses.field(default_factory=dict)

    def needed_for(self, matches) -> frozenset:
        key = frozenset(id(m.anchor_eqn) for m in matches)
        got = self._needed.get(key)
        if got is None:
            got = self._needed[key] = needed_eqn_ids(self.closed_jaxpr,
                                                     matches)
        return got


def _flat_matches(matches) -> List[D.Match]:
    """Flatten a detection report for selection bookkeeping: scan-body
    wrapper matches never select a harness themselves — their *inner*
    matches do, once per trace of the rebuilt ``lax.scan`` body — so pins,
    resolution counting and the anchor->index map all operate on the
    recursively flattened list."""
    out: List[D.Match] = []
    for m in matches:
        if m.variant == "scan_body" and m.body is not None:
            out.extend(_flat_matches(m.body[1]))
        else:
            out.append(m)
    return out


def _signature(flat_args) -> Tuple:
    """Hashable compile-dict key, derived from the single leaf-keying
    source (``plan.leaf_templates`` — also the basis of the last-entry
    fast path and the baked-plan guard specs) so the layers cannot
    drift."""
    return tuple(
        (t[1], str(t[2])) if t[0] == "a" else ("py", t[1].__name__, t[2])
        for t in P.leaf_templates(flat_args))


class LilacFunction:
    """A function passed through the LiLAC pass."""

    def __init__(self, fn: Callable, *, mode: str = "trace",
                 policy: str = "default",
                 registry: Optional[H.HarnessRegistry] = None,
                 detector: Optional[D.Detector] = None,
                 platform: Optional[str] = None,
                 cache: Optional[MarshalingCache] = None,
                 marshal_policy=None,
                 enabled: bool = True,
                 bake: bool = True,
                 plan_cache: Any = None,
                 donate_args: Tuple[int, ...] = ()):
        assert mode in ("trace", "host")
        self.fn = fn
        self.mode = mode
        self.policy = policy
        self.registry = registry or H.REGISTRY
        self.detector = detector or D.default_detector()
        self.platform = platform or jax.default_backend()
        self.marshal_policy = MarshalPolicy.parse(marshal_policy)
        if cache is not None:
            # caller-supplied cache (possibly shared with other compiled
            # functions: the cross-function plan-level sharing path)
            self.cache = cache
        elif self.marshal_policy.enabled:
            self.cache = DataPlane(policy=self.marshal_policy)
        else:
            self.cache = None       # every call repacks (A/B baseline)
        self.enabled = bool(enabled)
        self.bake_enabled = bool(bake)
        self.donate_args = tuple(donate_args or ())
        self._plan_cache_injected = isinstance(plan_cache, P.PlanCache)
        self._plan_cache = self._make_plan_cache(plan_cache)
        self._compiled: Dict[Tuple, CompiledEntry] = {}
        self._last_compiled: Optional[Tuple] = None  # (entry, in_tree, tmpl)
        self._last_plan: Optional[P.ExecutablePlan] = None
        # recently-served baked plans across ALL signatures, move-to-front.
        # Bucketed callers (the serving tier) rotate between a small set of
        # shapes every few calls; checking each hot plan's O(arity) guard
        # beats falling back to flatten -> template compare -> dict lookup
        # on every bucket switch.
        self._hot_plans: List[P.ExecutablePlan] = []
        self.last_report: Optional[D.DetectionReport] = None
        # (match, harness-name) pairs from the most recent call, in anchor
        # order — what actually ran, for benchmarks and tests.
        self.last_selections: List[Tuple[D.Match, str]] = []
        # the schedule variant each selection ran with (None = default /
        # untuned), aligned with last_selections — benchmarks record which
        # swept schedule a plan actually used.
        self.last_schedules: List[Optional[Dict[str, Any]]] = []
        # failure containment (repro.core.resilience): per-function
        # counters, the adaptive shadow-verification controller (the env
        # rate is a floor; incidents spike it, clean checks decay it —
        # rate 0 with no incidents must stay one dict lookup + float
        # compare per dispatch), and the recursion guard that keeps a
        # shadow's own dispatch from shadowing
        self.resilience_stats = R.ContainmentStats()
        self._shadow = R.AdaptiveShadowRate(_ENV_SHADOW)
        self._shadow_ctr = 0
        self._in_shadow = False

    def _make_plan_cache(self, opt) -> Optional[P.PlanCache]:
        if opt is False or (isinstance(opt, str)
                            and opt in ("off", "none", "disabled")):
            return None
        if isinstance(opt, P.PlanCache):
            return opt
        if opt in (None, True, "default", "on"):
            # only the default resolution honors the env kill-switch: an
            # explicitly passed path (like an injected instance) is a
            # stronger statement of intent than LILAC_PLAN_CACHE_DISABLE
            if P.plan_cache_disabled():
                return None
            return P.shared_plan_cache(None, self.registry.fingerprint())
        return P.shared_plan_cache(opt, self.registry.fingerprint())

    # -- compilation ---------------------------------------------------------

    def _validated_pins(self, raw: Dict[str, Any], matches) -> Dict[int, Tuple]:
        """Pins rehydrated from the plan cache, checked against the live
        registry: a vanished harness or a schedule outside the harness's
        current tune space drops the pin (the autotune policy re-tunes it)
        rather than ever pinning something unservable."""
        pins: Dict[int, Tuple] = {}
        flat = _flat_matches(matches)
        q = R.shared_quarantine()
        for k, v in (raw or {}).items():
            try:
                i, name, schedule = int(k), v[0], v[1]
            except (TypeError, ValueError, IndexError):
                continue
            # pre-joint-search records persisted [name, schedule] pairs;
            # fuse=None keeps the harness's declared realization
            fuse = v[2] if len(v) > 2 else None
            if not (0 <= i < len(flat)):
                continue
            try:
                h = self.registry.get(flat[i].computation, name)
            except KeyError:
                continue
            if schedule is not None and schedule not in (h.schedules or ()):
                continue
            # a quarantined (harness, variant) must never rehydrate into a
            # pin: the record predates the incident that quarantined it
            if q.is_quarantined(flat[i].computation, name,
                                variant_key(schedule, fuse)) \
                    or q.is_quarantined(flat[i].computation, name):
                continue
            pins[i] = (name, schedule, fuse)
        return pins

    def _build_entry(self, args, kwargs) -> CompiledEntry:
        cj, out_shape = jax.make_jaxpr(self.fn, return_shape=True)(*args, **kwargs)
        ncj = D.normalize_closed_jaxpr(cj)
        out_tree = jax.tree_util.tree_structure(out_shape)
        cache_key = None
        report = None
        pins: Dict[int, Tuple] = {}
        served = False
        joint_rec = None
        pc = self._plan_cache
        if pc is not None and not self._plan_cache_injected \
                and pc.registry_fingerprint != self.registry.fingerprint():
            # specs registered since this LilacFunction was built: re-key
            # the cache view so stale plans invalidate, fresh ones persist
            pc = self._plan_cache = P.shared_plan_cache(
                pc.path, self.registry.fingerprint())
        if pc is not None:
            cache_key = P.plan_key(ncj, self.platform, self.mode,
                                   self.policy,
                                   reuse=self.marshal_policy.reuse)
            rec = pc.get(cache_key)
            if rec is not None:
                got = None
                # integrity first: every schema-1 record carries n_eqns +
                # detect_digest, so both must be present AND agree with
                # the record's own matches / the live jaxpr before any
                # atom reference is resolved — truncated or hand-edited
                # records reject here
                ser = rec.get("matches", ())
                intact = (rec.get("n_eqns") == len(ncj.jaxpr.eqns)
                          and rec.get("detect_digest")
                          == P.detect_digest(ser))
                if intact:
                    got = P.rehydrate_matches(ncj, ser)
                if got is not None:
                    report = D.DetectionReport(
                        got, n_eqns=len(ncj.jaxpr.eqns),
                        log=["rehydrated from plan cache "
                             "(detection + tuning skipped)"])
                    pins = self._validated_pins(rec.get("pins"), got)
                    joint_rec = rec.get("joint")
                    served = True
                else:
                    pc.stats.rejected += 1
        if report is None:
            report = self.detector.detect(ncj, normalize=False)
        entry = CompiledEntry(ncj, report, out_tree)
        entry.pins = pins
        entry.idx_of = {id(m.anchor_eqn): i
                        for i, m in enumerate(_flat_matches(report.matches))}
        entry.cache_key = cache_key
        # a served record with complete pins never re-persists; a served
        # record whose pins were dropped (or never tuned) re-persists once
        # this process resolves them
        entry.persisted = served and (
            self.policy != "autotune" or not report.matches
            or len(pins) == len(report.matches))
        # warm start: served pins with full coverage already carry the
        # joint assignment from the process that searched it — serve with
        # zero re-search (the acceptance property the benchmark gates)
        if served and pins and len(pins) == len(
                _flat_matches(report.matches)):
            entry.joint_done = True
            entry.joint = joint_rec
        return entry

    def _entry_for(self, args, kwargs, flat, in_tree) -> CompiledEntry:
        last = self._last_compiled
        if (last is not None and last[1] == in_tree
                and P.leaves_match(last[2], flat)):
            entry = last[0]
        else:
            key = (_signature(flat), in_tree)
            entry = self._compiled.get(key)
            if entry is None:
                entry = self._build_entry(args, kwargs)
                self._compiled[key] = entry
            self._last_compiled = (entry, in_tree, P.leaf_templates(flat))
        self.last_report = entry.report
        return entry

    def _prepare(self, args, kwargs, flat=None, in_tree=None):
        """Flatten, unwrap TrackedArray leaves, resolve the CompiledEntry.
        Returns (entry, raw leaves, unwrapped leaves, in_tree)."""
        if flat is None:
            flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        raw_flat = flat
        if any(isinstance(x, TrackedArray) for x in flat):
            flat = [x.arr if isinstance(x, TrackedArray) else x for x in flat]
            args, kwargs = jax.tree_util.tree_unflatten(in_tree, flat)
        entry = self._entry_for(args, kwargs, flat, in_tree)
        return entry, raw_flat, flat, in_tree

    def _compile(self, args, kwargs) -> Tuple[CompiledEntry, List[Any]]:
        entry, _, flat, _ = self._prepare(args, kwargs)
        return entry, flat

    def report_for(self, *args, **kwargs) -> D.DetectionReport:
        entry, _ = self._compile(args, kwargs)
        return entry.report

    # -- execution -----------------------------------------------------------

    def _select(self, m: D.Match, binding=None, ctx=None) -> H.Harness:
        return self.registry.select(
            m.computation, m.format, self.platform, self.mode,
            policy=self.policy, binding=binding, ctx=ctx)

    def _pinned_select(self, entry: CompiledEntry):
        """Autotune policy: delegate to the persistent tuner once per match
        per input-signature, then pin the (winner, schedule) pair into the
        rewrite.  Pinning only happens for definitive decisions (measured
        or cache-hit) so a can't-measure fallback — e.g. the very first
        call happening under a user's jit trace — stays re-tunable on later
        concrete calls."""
        idx_of = entry.idx_of

        def select(m: D.Match, binding=None, ctx=None) -> H.Harness:
            i = idx_of.get(id(m.anchor_eqn))
            if i is None:
                # defensive: a match outside the entry's flattened report
                # (shouldn't happen) still selects, just without pinning
                return self._select(m, binding, ctx)
            pin = entry.pins.get(i)
            if pin is not None:
                name, schedule, fuse = pin
                try:
                    h = self.registry.get(m.computation, name)
                    if ctx is not None:
                        ctx.schedule = schedule
                        ctx.fuse = fuse
                    return h
                except KeyError:
                    del entry.pins[i]   # harness set changed; re-tune
            h = self._select(m, binding, ctx)
            tuner = self.registry.autotuner
            dec = tuner.last_decision
            if dec is not None and dec.definitive:
                entry.pins[i] = dec.as_pin()
            return h

        return select

    def _ctx_factory(self, m: D.Match) -> H.CallCtx:
        return H.CallCtx(mode=self.mode, cache=self.cache, format=m.format,
                         platform=self.platform, epilogue=m.epilogue)

    def _dispatch_plan(self, plan: P.ExecutablePlan, leaves):
        plan.hits += 1
        self.last_report = plan.report
        self.last_selections = plan.selections
        self.last_schedules = plan.schedules
        outs = plan.jitted(*leaves)
        return jax.tree_util.tree_unflatten(plan.out_tree, outs)

    def _enabled_matches(self, entry: CompiledEntry) -> List[D.Match]:
        """The report's matches minus containment-disabled ones.  A
        scan-body wrapper drops wholesale when any inner match is disabled
        — there is no per-iteration mix of harness and reference."""
        matches = entry.report.matches if self.enabled else []
        if not entry.disabled:
            return matches
        idx_of = entry.idx_of
        return [m for m in matches
                if not any(idx_of.get(id(fm.anchor_eqn)) in entry.disabled
                           for fm in _flat_matches([m]))]

    def _serve_plan(self, plan: P.ExecutablePlan, leaves, in_tree):
        out = self._dispatch_plan(plan, leaves)
        if not self._in_shadow:
            r = self._shadow.effective()
            if r > 0.0:
                out = self._maybe_shadow(plan, leaves, in_tree, out, r)
        return out

    def _maybe_shadow(self, plan, leaves, in_tree, out, r):
        """Sampled shadow verification: deterministically stratified so a
        rate of r checks dispatch n iff the integer part of n*r advances —
        every window of 1/r dispatches contains exactly one check, with no
        RNG state to perturb.  ``r`` is the adaptive *effective* rate, so
        an incident densifies checking immediately and a clean streak
        relaxes it back to the floor."""
        self._shadow_ctr = n = self._shadow_ctr + 1
        if int(n * r) == int((n - 1) * r):
            return out
        if any(isinstance(x, jax.core.Tracer) for x in leaves):
            return out          # values don't exist yet; nothing to compare
        self.resilience_stats.shadow_checks += 1
        args, kwargs = jax.tree_util.tree_unflatten(in_tree, leaves)
        self._in_shadow = True
        try:
            ref = self.fn(*args, **kwargs)
        except Exception:
            return out          # the reference itself failed; keep ours
        finally:
            self._in_shadow = False
        if R.outputs_close(out, ref) \
                and not faults.check("shadow_diverge", "dispatch"):
            self._shadow.clean()
            return out
        # divergence: the accelerated answer is wrong.  Serve the reference
        # for THIS call, quarantine everything the plan selected, and tear
        # the plan down so the next dispatch re-tunes and re-bakes.
        self.resilience_stats.shadow_divergences += 1
        self._shadow_divergence(plan)
        return ref

    def _shadow_divergence(self, plan: P.ExecutablePlan):
        self._shadow.spike("shadow divergence")
        q = R.shared_quarantine()
        for (m, name), sched in zip(plan.selections, plan.schedules):
            q.add(m.computation, name, variant_key(sched, None),
                  reason="shadow divergence", site=name)
        if self._last_plan is plan:
            self._last_plan = None
        self._drop_hot(plan)
        for entry in self._compiled.values():
            if entry.plan is plan:
                entry.plan = None
                entry.pins.clear()
                entry.persisted = False
                entry.joint_done = False
                entry.joint = None

    def report_divergence(self, reason: str = "external divergence"):
        """An out-of-band verifier (the serving tier's request-level shadow,
        an application-level checksum) observed this function producing a
        wrong answer that per-dispatch shadowing did not catch.  Responds
        exactly like an in-band divergence: quarantine what the live plans
        selected, tear the plans down so the next dispatch re-tunes, spike
        the adaptive shadow rate, and count the incident."""
        self.resilience_stats.shadow_divergences += 1
        plans = []
        for entry in self._compiled.values():
            if entry.plan is not None and entry.plan not in plans:
                plans.append(entry.plan)
        q = R.shared_quarantine()
        for entry in self._compiled.values():
            if entry.plan is None and entry.pins:
                # tuned but unbaked signature: quarantine its pinned
                # selections directly and force a re-tune
                flat = _flat_matches(entry.report.matches)
                for i, (name, sched, fuse) in list(entry.pins.items()):
                    comp = flat[i].computation if i < len(flat) else name
                    q.add(comp, name, variant_key(sched, None),
                          reason=reason, site=name)
                entry.pins.clear()
                entry.persisted = False
                entry.joint_done = False
                entry.joint = None
        for plan in plans:
            self._shadow_divergence(plan)
        if not plans:
            self._shadow.spike(reason)

    def resilience_info(self) -> Dict[str, Any]:
        """Containment / quarantine / shadow counters for this function
        plus the shared quarantine store's view — benchmarks and the chaos
        gate read this instead of poking privates."""
        q = R.shared_quarantine()
        return {
            "containment": self.resilience_stats.as_dict(),
            "quarantine": q.stats.as_dict(),
            "quarantine_active": len(q.active()),
            "quarantine_path": str(q.path),
            "shadow_rate": self._shadow.effective(),
            "shadow": self._shadow.snapshot(),
            "disabled_matches": sum(len(e.disabled)
                                    for e in self._compiled.values()),
        }

    _HOT_PLAN_LIMIT = 32

    def _note_hot(self, plan: P.ExecutablePlan):
        """Move-to-front a plan in the hot list (bounded)."""
        hot = self._hot_plans
        if hot and hot[0] is plan:
            return
        try:
            hot.remove(plan)
        except ValueError:
            pass
        hot.insert(0, plan)
        del hot[self._HOT_PLAN_LIMIT:]

    def _drop_hot(self, plan: P.ExecutablePlan):
        try:
            self._hot_plans.remove(plan)
        except ValueError:
            pass

    def __call__(self, *args, **kwargs):
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        # steady-state fast path: guard check -> one jitted dispatch.
        # A registry epoch moved by any (re-)registration refuses the
        # plan: a replaced harness body must never be served from a
        # stale jitted executable.
        epoch = self.registry.epoch
        plan = self._last_plan
        if plan is not None and plan.registry_epoch == epoch:
            leaves = plan.match_and_unwrap(in_tree, flat, self.enabled)
            if leaves is not None:
                return self._serve_plan(plan, leaves, in_tree)
        # hot-plan scan: bucketed callers rotate between a handful of
        # signatures; any of them can serve without re-keying the entry
        for hp in self._hot_plans:
            if hp is plan or hp.registry_epoch != epoch:
                continue
            leaves = hp.match_and_unwrap(in_tree, flat, self.enabled)
            if leaves is not None:
                self._last_plan = hp
                self._note_hot(hp)
                return self._serve_plan(hp, leaves, in_tree)
        entry, raw_flat, uflat, in_tree = self._prepare(
            args, kwargs, flat, in_tree)
        # second chance: another signature's plan was hot; this entry may
        # still hold a valid one
        plan = entry.plan
        if (plan is not None and plan is not self._last_plan
                and plan.registry_epoch == epoch):
            leaves = plan.match_and_unwrap(in_tree, raw_flat, self.enabled)
            if leaves is not None:
                self._last_plan = plan
                self._note_hot(plan)
                return self._serve_plan(plan, leaves, in_tree)

        matches = self._enabled_matches(entry)
        select = (self._pinned_select(entry) if self.policy == "autotune"
                  else self._select)
        # Recording runs even when leaves are tracers (the call sits under
        # jax.grad / vmap / a user jit): once the rewrite is resolved, the
        # plan bakes *under the transform trace* — no concrete call is ever
        # required — with warm-up deferred and hoisting skipped for
        # anything tracer-derived (see _maybe_bake / plan.bake_plan).
        recorder = (P.PlanRecorder()
                    if self.bake_enabled and not entry.no_bake
                    else None)

        def ctx_factory(m):
            ctx = self._ctx_factory(m)
            if recorder is not None:
                ctx.cache = P.recording_cache(ctx.cache,
                                              recorder.slot(m).buffers)
            return ctx

        selections: List[Tuple[D.Match, str]] = []
        schedules: List[Optional[Dict[str, Any]]] = []

        def on_select(m, h, ctx):
            sched = getattr(ctx, "schedule", None)
            if selections and selections[-1][0] is m:
                # containment retry: the previous candidate for this same
                # anchor failed — replace its record, don't append
                selections[-1] = (m, h.name)
                schedules[-1] = sched
            else:
                selections.append((m, h.name))
                schedules.append(sched)
            if recorder is not None:
                recorder.begin(m, h, sched, getattr(ctx, "fuse", None))

        def on_quarantine(m, h, vkey, reason):
            # the quarantined harness may be pinned, persisted, baked and
            # jointly-assigned for this entry: unwind all four so the next
            # selection re-tunes and the next resolution re-bakes.  A
            # quarantine is also an incident: densify shadow checking
            # until a clean streak restores trust.
            self._shadow.spike(f"quarantine: {reason}")
            i = entry.idx_of.get(id(m.anchor_eqn))
            pin = entry.pins.get(i) if i is not None else None
            if pin is not None and pin[0] == h.name:
                del entry.pins[i]
            entry.persisted = False
            entry.joint_done = False
            entry.joint = None
            entry.no_bake = False
            entry.bake_error = None
            if entry.plan is not None:
                if self._last_plan is entry.plan:
                    self._last_plan = None
                self._drop_hot(entry.plan)
                entry.plan = None

        contain = R.Containment(self.registry, R.shared_quarantine(),
                                on_quarantine=on_quarantine,
                                stats=self.resilience_stats)
        # containment retry loop: a ReferenceFallback disables ONE match
        # (its anchor then evaluates as a plain equation), so the loop is
        # bounded by the match count + the final all-reference pass
        for _ in range(len(_flat_matches(matches)) + 1):
            try:
                outs = run_rewritten(
                    entry.closed_jaxpr, matches, select, uflat, ctx_factory,
                    on_select=on_select, needed=entry.needed_for(matches),
                    contain=contain)
                break
            except R.ReferenceFallback as rf:
                i = entry.idx_of.get(id(rf.match.anchor_eqn))
                if i is None:
                    raise   # not this entry's match; nothing we can disable
                entry.disabled.add(i)
                matches = self._enabled_matches(entry)
                selections.clear()
                schedules.clear()
        self.last_selections = selections
        self.last_schedules = schedules
        joint_moved = self._maybe_joint(entry)
        self._maybe_persist(entry)
        if recorder is not None and not joint_moved:
            # pins just changed under the joint search: this call recorded
            # the pre-joint assignment, so baking it would freeze the wrong
            # plan — the next call records and bakes the joint one
            self._maybe_bake(entry, matches, recorder, raw_flat, uflat,
                             in_tree)
        return jax.tree_util.tree_unflatten(entry.out_tree, outs)

    # -- plan lifecycle ------------------------------------------------------

    def _maybe_joint(self, entry: CompiledEntry) -> bool:
        """Run the joint whole-program plan search once per entry, after
        every match has a definitive per-match pin.  Returns True when the
        search moved any pin (the caller then skips baking this call — the
        recorded selections are the pre-joint ones).

        The search is pure bookkeeping over the autotune cache's measured
        components — zero re-timing — so it runs inline.  Entries served
        from the plan cache with complete pins arrive ``joint_done`` (the
        persisted pins are the previous process's joint assignment)."""
        if entry.joint_done or self.policy != "autotune":
            return False
        matches = entry.report.matches if self.enabled else []
        flat = _flat_matches(matches)
        if len(flat) < 2:
            # nothing to couple: the per-match winner (fuse dimension
            # included, swept by the schema-4 autotuner) is already joint
            entry.joint_done = True
            return False
        if len(entry.pins) != len(flat):
            return False        # not yet resolved; retry next call
        width = PS.beam_width()
        if width <= 0:
            entry.joint_done = True     # LILAC_SEARCH_BEAM=0: pure greedy
            return False
        tuner = getattr(self.registry, "autotuner", None)
        if tuner is None:
            entry.joint_done = True
            return False
        try:
            res = PS.optimize_entry(
                flat, entry.pins, registry=self.registry, tuner=tuner,
                platform=self.platform, mode=self.mode, cache=self.cache,
                reuse=self.marshal_policy.reuse, width=width)
        except Exception:
            entry.joint_done = True     # cost model unavailable: pins stand
            return False
        entry.joint_done = True
        if res is None:
            return False
        entry.joint = res.report()
        moved = False
        for i, cand in enumerate(res.assignment):
            pin = cand.pin()
            if entry.pins.get(i) != pin:
                entry.pins[i] = pin
                moved = True
        if moved:
            entry.persisted = False     # re-persist the joint pins
            if entry.plan is not None:  # baked on pre-joint pins: stale
                if self._last_plan is entry.plan:
                    self._last_plan = None
                self._drop_hot(entry.plan)
                entry.plan = None
        return moved

    def _resolved(self, entry: CompiledEntry, matches) -> bool:
        """A rewrite is resolved once every selection is definitive: always
        for explicit/default policies, for autotune once every match is
        pinned (or tuning is disabled, making defaults deterministic)."""
        if self.policy != "autotune" or not matches:
            return True
        return (len(entry.pins) == len(_flat_matches(matches))
                or autotune_disabled())

    def _maybe_persist(self, entry: CompiledEntry):
        pc = self._plan_cache
        if pc is None or entry.persisted or entry.cache_key is None:
            return
        matches = entry.report.matches
        if not self._resolved(entry, matches):
            return
        if any(m.variant == "scan_body" for m in matches):
            # a scan-body match carries the normalized body jaxpr + inner
            # matches as live objects; there is no stable positional
            # address for them, and a rehydrated wrapper without its body
            # would be unservable — keep scan entries in-memory only
            entry.persisted = True
            return
        try:
            ser = P.serialize_matches(entry.closed_jaxpr, matches)
        except Exception:
            entry.persisted = True      # unaddressable match: don't retry
            return
        entry.persisted = True
        rec = {
            "matches": ser,
            "n_eqns": len(entry.closed_jaxpr.jaxpr.eqns),
            "detect_digest": P.detect_digest(ser),
            "pins": {str(i): [n, s, f]
                     for i, (n, s, f) in entry.pins.items()},
        }
        if entry.joint is not None:
            rec["joint"] = entry.joint
        pc.put(entry.cache_key, rec)

    def _disable_bake(self, entry: CompiledEntry, reason: str):
        """Stop baking this entry AND drop any existing plan: a retired
        plan would otherwise keep its jitted executable, hoisted device
        buffers and strong operand references resident (a silent leak on
        exactly the churning workloads baking gets disabled for) while
        its guards are certain to keep failing."""
        entry.no_bake = True
        entry.bake_error = reason
        if entry.plan is not None:
            if self._last_plan is entry.plan:
                self._last_plan = None
            self._drop_hot(entry.plan)
            entry.plan = None

    def _maybe_bake(self, entry: CompiledEntry, matches,
                    recorder: P.PlanRecorder, raw_flat, flat, in_tree):
        if entry.no_bake or not self._resolved(entry, matches):
            return
        if any(m.variant == "scan_body" for m in matches):
            # the rebuilt lax.scan already compiles the body once per call
            # and reuses kernels across iterations; a baked plan on top
            # could not guard body-internal marshal sources (their binding
            # atoms live in the body jaxpr, not the outer one)
            self._disable_bake(
                entry, "scan-body rewrite: lax.scan reconstruction "
                       "compiles per call; plan guards cannot cover "
                       "body-internal marshal sources")
            return
        if not recorder.complete_for(matches):
            return
        traced = any(isinstance(x, jax.core.Tracer) for x in flat)
        if traced:
            if any(s.buffers for s in recorder.slots.values()):
                # marshal products recorded under a transform trace are
                # (or depend on) tracers — not hoistable.  Skip this call
                # without disabling: a later concrete call records real
                # buffers
                return
            gpos = P.marshal_guard_positions(
                entry.closed_jaxpr,
                [(m, recorder.slots[id(m.anchor_eqn)].harness)
                 for m in matches])
            if any(isinstance(flat[i], jax.core.Tracer) for i in gpos):
                return                  # can't guard a tracer's contents
        # marshal_policy='off' promises "every call repacks" (the A/B
        # always-fresh baseline): hoisting a recorded repack into a plan
        # would silently reinstate caching, so any marshal-bearing
        # selection blocks baking under it
        if self.cache is None and any(
                s.buffers for s in recorder.slots.values()):
            self._disable_bake(entry, "marshal_policy='off' forbids "
                               "hoisting repacks; interpreter repacks "
                               "every call")
            return
        # stateful / opted-out backends: a baked plan freezes per-call
        # host-side behavior at trace time, so only bake bodies whose
        # host part is entirely their declared marshal clauses
        for m in matches:
            h = recorder.slots[id(m.anchor_eqn)].harness
            if (not getattr(h, "bakeable", True) or h.setup is not None
                    or h.teardown is not None or h.persistent):
                self._disable_bake(
                    entry, f"harness {h.name!r} is stateful or opted out "
                           f"of baking (bakeable=False / lifecycle hooks "
                           f"/ persistent)")
                return
        plan = entry.plan
        if plan is not None:
            if (plan.enabled == self.enabled and plan.consts_ok()
                    and plan.registry_epoch == self.registry.epoch
                    and plan.same_hoisted(recorder)):
                # content-identical operands under new identities (e.g. an
                # equal re-upload): the data plane served the same buffers,
                # so only the guards move — no re-trace, no re-compile
                plan.refresh_guards(raw_flat)
                self._last_plan = plan
                self._note_hot(plan)
                return
            if entry.rebakes >= 4 and plan.hits == 0:
                # operands churn faster than the plan pays off: stop
                # recompiling and stay on the interpreter
                self._disable_bake(
                    entry, "rebake thrash (operands change per call)")
                return
        try:
            baked = P.bake_plan(
                closed_jaxpr=entry.closed_jaxpr, matches=matches,
                needed=entry.needed_for(matches), recorder=recorder,
                raw_flat=raw_flat, flat=flat, in_tree=in_tree,
                out_tree=entry.out_tree, report=entry.report,
                mode=self.mode, platform=self.platform,
                enabled=self.enabled, donate=self.donate_args,
                registry_epoch=self.registry.epoch)
        except P.PlanDonationError:
            raise                       # user error: surface it
        except Exception as e:          # untraceable body etc: interpreter
            self._disable_bake(entry, repr(e))
            return
        if plan is not None:
            entry.rebakes += 1
            self._drop_hot(plan)
        entry.plan = baked
        self._last_plan = baked
        self._note_hot(baked)

    def invalidate_plans(self):
        """Drop every baked plan (not the persistent cache): the next call
        per signature re-records and re-bakes.  Use after mutating harness
        persistent state or releasing backends out-of-band."""
        for entry in self._compiled.values():
            entry.plan = None
            entry.no_bake = False
            entry.bake_error = None
            entry.rebakes = 0     # fresh thrash tolerance, as documented
        self._last_plan = None
        self._hot_plans.clear()

    def executable_plan(self, *args, **kwargs) -> Optional[P.ExecutablePlan]:
        """The baked plan serving this call signature, or None (not yet
        resolved / bake disabled / unbakeable).  For benchmarks and tests;
        does not execute anything."""
        entry, _, _, _ = self._prepare(args, kwargs)
        return entry.plan

    def prewarm(self, *signatures) -> Dict[str, Any]:
        """Bake a plan per call signature ahead of traffic.

        Each signature is a tuple of positional arguments;
        ``jax.ShapeDtypeStruct`` leaves are materialized as zeros, so
        callers can prewarm from shape specs without allocating inputs
        themselves.  Runs one concrete call per signature — the full
        detect -> tune -> bake lifecycle happens HERE (or is skipped via
        the persistent plan cache), never later on the request path.

        Returns a report: per-signature ``{baked, detect_calls,
        from_plan_cache}`` plus totals.  ``detect_calls`` is counted by
        instrumenting this function's detector for the duration of the
        call — on a plan-cache warm start it stays 0, which is exactly
        the "pay detection once per fleet, not once per replica" property
        the serving benchmark gates on.
        """
        import jax.numpy as jnp

        def materialize(leaf):
            if isinstance(leaf, jax.ShapeDtypeStruct):
                return jnp.zeros(leaf.shape, leaf.dtype)
            return leaf

        detector = self.detector
        orig_detect = detector.detect
        calls = {"n": 0}

        def spy(*a, **k):
            calls["n"] += 1
            return orig_detect(*a, **k)

        detector.detect = spy       # instance attribute shadows the method
        per_sig: List[Dict[str, Any]] = []
        try:
            for sig in signatures:
                args = tuple(jax.tree.map(materialize, a) for a in sig)
                before = calls["n"]
                self(*args)
                entry, _, _, _ = self._prepare(args, {})
                rehydrated = bool(entry and any(
                    "rehydrated from plan cache" in line
                    for line in entry.report.log))
                per_sig.append({
                    "baked": bool(entry and entry.plan is not None),
                    "detect_calls": calls["n"] - before,
                    "from_plan_cache": rehydrated,
                })
        finally:
            detector.__dict__.pop("detect", None)
        return {
            "signatures": per_sig,
            "n_signatures": len(per_sig),
            "baked": sum(1 for s in per_sig if s["baked"]),
            "detect_calls": sum(s["detect_calls"] for s in per_sig),
            "plan_cache_hits": sum(1 for s in per_sig
                                   if s["from_plan_cache"]),
        }

    def plan_info(self) -> Dict[str, Any]:
        """Introspection for benchmarks/tests: bake status per function."""
        entries = list(self._compiled.values())
        plans = [e.plan for e in entries if e.plan is not None]
        return {
            "entries": len(entries),
            "baked": len(plans),
            "plan_hits": sum(p.hits for p in plans),
            "rebakes": sum(e.rebakes for e in entries),
            "no_bake": sum(1 for e in entries if e.no_bake),
            "bake_errors": [e.bake_error for e in entries if e.bake_error],
            "joint_searched": sum(1 for e in entries
                                  if e.joint is not None),
            "joint": [e.joint for e in entries if e.joint is not None],
            "plan_cache": (str(self._plan_cache.path)
                           if self._plan_cache is not None else None),
            "plan_cache_stats": (self._plan_cache.stats.as_dict()
                                 if self._plan_cache is not None else None),
        }


class LilacDeprecationWarning(DeprecationWarning):
    """Emitted by the pre-``lilac.compile`` entry-point shims."""


@dataclasses.dataclass
class CompileOptions:
    """Configuration for :func:`compile` (the paper's Fig. 1 pass).

    ``mode``      'trace' (jit-compatible rewrite) or 'host' (eager with
                  marshaling cache — the paper's runtime model).
    ``policy``    'default' | 'autotune' | an explicit harness name.
    ``platform``  target platform; None = ``jax.default_backend()``.
    ``enabled``   False runs the original computation (A/B baseline).
    ``marshal_policy``  data-plane configuration: a
                  :class:`~repro.core.marshal.MarshalPolicy`, or one of
                  'shared' (default: plan-level DataPlane with the
                  conversion graph), 'exact' (exact fingerprints), 'off'
                  (no caching — every call repacks).  The policy's
                  ``reuse`` is the declared call frequency the autotuner
                  amortizes repack cost at.
    ``bake``      True (default) bakes resolved rewrites into jitted
                  :class:`~repro.core.plan.ExecutablePlan`s; False keeps
                  the eqn-interpreter on every call (the A/B baseline for
                  dispatch-overhead benchmarks).
    ``plan_cache``  persistent plan cache: None/'default' resolves
                  ``LILAC_PLAN_CACHE`` (default ~/.cache/lilac/plans.json),
                  'off'/False disables persistence, a path or
                  :class:`~repro.core.plan.PlanCache` injects one.
    ``donate_args``  flat argument positions donated to the baked plan's
                  XLA executable (output may alias their buffers).  Only
                  donate operands you never reuse after the call; positions
                  feeding marshaled operands are rejected.
    ``registry``/``detector``/``cache``  dependency injection for tests
                  and benchmarks; None picks the global instances.  Pass
                  the same DataPlane as ``cache`` to several compiled
                  functions to share marshaled buffers across them.
    """
    mode: str = "trace"
    policy: str = "default"
    platform: Optional[str] = None
    enabled: bool = True
    marshal_policy: Optional[Any] = None
    bake: bool = True
    plan_cache: Any = None
    donate_args: Tuple[int, ...] = ()
    registry: Optional[H.HarnessRegistry] = None
    detector: Optional[D.Detector] = None
    cache: Optional[MarshalingCache] = None


_OPTION_FIELDS = {f.name for f in dataclasses.fields(CompileOptions)}


def compile(fn: Optional[Callable] = None, *,
            options: Optional[CompileOptions] = None,
            **overrides) -> LilacFunction:
    """The single LiLAC entry point: pass a function through the pass.

    Usable directly (``lilac.compile(fn, mode="host")``), with an options
    dataclass (``lilac.compile(fn, options=CompileOptions(...))``; explicit
    keyword arguments override option fields), or as a decorator
    (``@lilac.compile(policy="autotune")``).
    """
    bad = set(overrides) - _OPTION_FIELDS
    if bad:
        raise TypeError(f"unknown compile option(s): {sorted(bad)}")
    opts = options if options is not None else CompileOptions()
    if overrides:
        opts = dataclasses.replace(opts, **overrides)
    if fn is None:
        return lambda f: compile(f, options=opts)
    if opts.mode not in ("trace", "host"):
        raise ValueError(f"mode must be 'trace' or 'host', got {opts.mode!r}")
    return LilacFunction(fn, mode=opts.mode, policy=opts.policy,
                         registry=opts.registry, detector=opts.detector,
                         platform=opts.platform, cache=opts.cache,
                         marshal_policy=opts.marshal_policy,
                         enabled=opts.enabled, bake=opts.bake,
                         plan_cache=opts.plan_cache,
                         donate_args=opts.donate_args)


def lilac_optimize(fn: Callable, **kw) -> LilacFunction:
    """Deprecated: use ``repro.lilac.compile(fn, mode='trace', ...)``."""
    warnings.warn(
        "lilac_optimize() is deprecated; use "
        "repro.lilac.compile(fn, mode='trace', ...)",
        LilacDeprecationWarning, stacklevel=2)
    return compile(fn, mode="trace", **kw)


def lilac_accelerate(fn: Callable, **kw) -> LilacFunction:
    """Deprecated: use ``repro.lilac.compile(fn, mode='host', ...)``."""
    warnings.warn(
        "lilac_accelerate() is deprecated; use "
        "repro.lilac.compile(fn, mode='host', ...)",
        LilacDeprecationWarning, stacklevel=2)
    return compile(fn, mode="host", **kw)
