"""The user-facing LiLAC pass (the paper's Fig. 1 compiler flow).

``compile(fn, mode=...)`` is the single entry point (exposed as
``repro.lilac.compile``); an optional :class:`CompileOptions` dataclass
carries the full configuration.

``mode="trace"`` — returns a function with the same signature whose jaxpr
    has detected computations replaced by jit-safe harnesses.  Wrap it in
    ``jax.jit`` exactly like the original; this is how the LM framework
    consumes LiLAC (MoE layers etc.).

``mode="host"`` — the paper's runtime model.  Each call executes the
    rewritten program eagerly; harnesses may be host-only and use the
    marshaling cache, so format repacks / derived invariants are amortized
    across calls exactly like the paper's mprotect machinery (Fig. 18).
    Use for solver-style apps that call the step repeatedly.

Both share: trace -> normalize -> detect (backtracking) -> rewrite.
Detection runs once per input-shape signature and is cached.

``lilac_optimize`` / ``lilac_accelerate`` are deprecation shims over
``compile`` kept for out-of-repo callers; they warn with
:class:`LilacDeprecationWarning`, which the test suite escalates to an
error so in-repo code stays on the new surface.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import detect as D
from repro.core import harness as H
from repro.core.marshal import DataPlane, MarshalingCache, MarshalPolicy
from repro.core.rewrite import run_rewritten


@dataclasses.dataclass
class CompiledEntry:
    closed_jaxpr: Any
    report: D.DetectionReport
    out_tree: Any
    # autotune pins: match index -> (harness name, schedule variant),
    # filled at first lowering for this signature so later calls (and
    # re-traces under jit) reuse the measured winner — including its swept
    # kernel schedule — without consulting the tuner again.
    pins: Dict[int, Tuple[str, Optional[Dict[str, Any]]]] = \
        dataclasses.field(default_factory=dict)


def _signature(flat_args) -> Tuple:
    sig = []
    for a in flat_args:
        if hasattr(a, "shape") and hasattr(a, "dtype"):
            sig.append((tuple(a.shape), str(a.dtype)))
        else:
            sig.append(("py", type(a).__name__, a if isinstance(a, (int, bool)) else None))
    return tuple(sig)


class LilacFunction:
    """A function passed through the LiLAC pass."""

    def __init__(self, fn: Callable, *, mode: str = "trace",
                 policy: str = "default",
                 registry: Optional[H.HarnessRegistry] = None,
                 detector: Optional[D.Detector] = None,
                 platform: Optional[str] = None,
                 cache: Optional[MarshalingCache] = None,
                 marshal_policy=None,
                 enabled: bool = True):
        assert mode in ("trace", "host")
        self.fn = fn
        self.mode = mode
        self.policy = policy
        self.registry = registry or H.REGISTRY
        self.detector = detector or D.default_detector()
        self.platform = platform or jax.default_backend()
        self.marshal_policy = MarshalPolicy.parse(marshal_policy)
        if cache is not None:
            # caller-supplied cache (possibly shared with other compiled
            # functions: the cross-function plan-level sharing path)
            self.cache = cache
        elif self.marshal_policy.enabled:
            self.cache = DataPlane(policy=self.marshal_policy)
        else:
            self.cache = None       # every call repacks (A/B baseline)
        self.enabled = enabled
        self._compiled: Dict[Tuple, CompiledEntry] = {}
        self.last_report: Optional[D.DetectionReport] = None
        # (match, harness-name) pairs from the most recent call, in anchor
        # order — what actually ran, for benchmarks and tests.
        self.last_selections: List[Tuple[D.Match, str]] = []
        # the schedule variant each selection ran with (None = default /
        # untuned), aligned with last_selections — benchmarks record which
        # swept schedule a plan actually used.
        self.last_schedules: List[Optional[Dict[str, Any]]] = []

    # -- compilation ---------------------------------------------------------

    def _compile(self, args, kwargs) -> Tuple[CompiledEntry, List[Any]]:
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        key = (_signature(flat), in_tree)
        entry = self._compiled.get(key)
        if entry is None:
            cj, out_shape = jax.make_jaxpr(self.fn, return_shape=True)(*args, **kwargs)
            ncj = D.normalize_closed_jaxpr(cj)
            report = self.detector.detect(ncj, normalize=False)
            out_tree = jax.tree_util.tree_structure(out_shape)
            entry = CompiledEntry(ncj, report, out_tree)
            self._compiled[key] = entry
        self.last_report = entry.report
        return entry, flat

    def report_for(self, *args, **kwargs) -> D.DetectionReport:
        entry, _ = self._compile(args, kwargs)
        return entry.report

    # -- execution -----------------------------------------------------------

    def _select(self, m: D.Match, binding=None, ctx=None) -> H.Harness:
        return self.registry.select(
            m.computation, m.format, self.platform, self.mode,
            policy=self.policy, binding=binding, ctx=ctx)

    def _pinned_select(self, entry: CompiledEntry):
        """Autotune policy: delegate to the persistent tuner once per match
        per input-signature, then pin the (winner, schedule) pair into the
        rewrite.  Pinning only happens for definitive decisions (measured
        or cache-hit) so a can't-measure fallback — e.g. the very first
        call happening under a user's jit trace — stays re-tunable on later
        concrete calls."""
        idx_of = {id(m.anchor_eqn): i for i, m in enumerate(entry.report.matches)}

        def select(m: D.Match, binding=None, ctx=None) -> H.Harness:
            i = idx_of[id(m.anchor_eqn)]
            pin = entry.pins.get(i)
            if pin is not None:
                name, schedule = pin
                try:
                    h = self.registry.get(m.computation, name)
                    if ctx is not None:
                        ctx.schedule = schedule
                    return h
                except KeyError:
                    del entry.pins[i]   # harness set changed; re-tune
            h = self._select(m, binding, ctx)
            tuner = self.registry.autotuner
            dec = tuner.last_decision
            if dec is not None and dec.source in ("memory", "disk", "measured"):
                entry.pins[i] = (h.name, dec.schedule)
            return h

        return select

    def _ctx_factory(self, m: D.Match) -> H.CallCtx:
        return H.CallCtx(mode=self.mode, cache=self.cache, format=m.format,
                         platform=self.platform, epilogue=m.epilogue)

    def __call__(self, *args, **kwargs):
        entry, flat = self._compile(args, kwargs)
        matches = entry.report.matches if self.enabled else []
        select = (self._pinned_select(entry) if self.policy == "autotune"
                  else self._select)
        selections: List[Tuple[D.Match, str]] = []
        schedules: List[Optional[Dict[str, Any]]] = []
        outs = run_rewritten(
            entry.closed_jaxpr, matches, select, flat, self._ctx_factory,
            on_select=lambda m, h, ctx: (
                selections.append((m, h.name)),
                schedules.append(getattr(ctx, "schedule", None))))
        self.last_selections = selections
        self.last_schedules = schedules
        return jax.tree_util.tree_unflatten(entry.out_tree, outs)


class LilacDeprecationWarning(DeprecationWarning):
    """Emitted by the pre-``lilac.compile`` entry-point shims."""


@dataclasses.dataclass
class CompileOptions:
    """Configuration for :func:`compile` (the paper's Fig. 1 pass).

    ``mode``      'trace' (jit-compatible rewrite) or 'host' (eager with
                  marshaling cache — the paper's runtime model).
    ``policy``    'default' | 'autotune' | an explicit harness name.
    ``platform``  target platform; None = ``jax.default_backend()``.
    ``enabled``   False runs the original computation (A/B baseline).
    ``marshal_policy``  data-plane configuration: a
                  :class:`~repro.core.marshal.MarshalPolicy`, or one of
                  'shared' (default: plan-level DataPlane with the
                  conversion graph), 'exact' (exact fingerprints), 'off'
                  (no caching — every call repacks).  The policy's
                  ``reuse`` is the declared call frequency the autotuner
                  amortizes repack cost at.
    ``registry``/``detector``/``cache``  dependency injection for tests
                  and benchmarks; None picks the global instances.  Pass
                  the same DataPlane as ``cache`` to several compiled
                  functions to share marshaled buffers across them.
    """
    mode: str = "trace"
    policy: str = "default"
    platform: Optional[str] = None
    enabled: bool = True
    marshal_policy: Optional[Any] = None
    registry: Optional[H.HarnessRegistry] = None
    detector: Optional[D.Detector] = None
    cache: Optional[MarshalingCache] = None


_OPTION_FIELDS = {f.name for f in dataclasses.fields(CompileOptions)}


def compile(fn: Optional[Callable] = None, *,
            options: Optional[CompileOptions] = None,
            **overrides) -> LilacFunction:
    """The single LiLAC entry point: pass a function through the pass.

    Usable directly (``lilac.compile(fn, mode="host")``), with an options
    dataclass (``lilac.compile(fn, options=CompileOptions(...))``; explicit
    keyword arguments override option fields), or as a decorator
    (``@lilac.compile(policy="autotune")``).
    """
    bad = set(overrides) - _OPTION_FIELDS
    if bad:
        raise TypeError(f"unknown compile option(s): {sorted(bad)}")
    opts = options if options is not None else CompileOptions()
    if overrides:
        opts = dataclasses.replace(opts, **overrides)
    if fn is None:
        return lambda f: compile(f, options=opts)
    if opts.mode not in ("trace", "host"):
        raise ValueError(f"mode must be 'trace' or 'host', got {opts.mode!r}")
    return LilacFunction(fn, mode=opts.mode, policy=opts.policy,
                         registry=opts.registry, detector=opts.detector,
                         platform=opts.platform, cache=opts.cache,
                         marshal_policy=opts.marshal_policy,
                         enabled=opts.enabled)


def lilac_optimize(fn: Callable, **kw) -> LilacFunction:
    """Deprecated: use ``repro.lilac.compile(fn, mode='trace', ...)``."""
    warnings.warn(
        "lilac_optimize() is deprecated; use "
        "repro.lilac.compile(fn, mode='trace', ...)",
        LilacDeprecationWarning, stacklevel=2)
    return compile(fn, mode="trace", **kw)


def lilac_accelerate(fn: Callable, **kw) -> LilacFunction:
    """Deprecated: use ``repro.lilac.compile(fn, mode='host', ...)``."""
    warnings.warn(
        "lilac_accelerate() is deprecated; use "
        "repro.lilac.compile(fn, mode='host', ...)",
        LilacDeprecationWarning, stacklevel=2)
    return compile(fn, mode="host", **kw)
