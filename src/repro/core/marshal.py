"""LiLAC-How data plane: formats, conversion planning, invariant caching
(paper §3.3.2, §4.2, Fig. 8/9/10/14/18).

The paper tracks writes to host arrays with memory protection so that
device transfers and data-dependent invariants (`cols`, SparseX tuning,
format conversions) are recomputed only when the underlying memory changed.
JAX arrays are immutable, so "did this memory change?" becomes "is this the
same value?", answered with content fingerprints at the harness call
boundary.

Beyond the fingerprint cache, this module makes storage formats first-class
(Rietveld & Wijshoff: data-structure selection belongs to the compiler) and
plans *conversion paths* over a cost-weighted graph (Linnea-style planning
over call sequences instead of greedy local choices):

* ``fingerprint(arr)`` — cheap content hash (full bytes below a threshold,
  strided sample + shape/dtype above it; ``exact=True`` forces full bytes).
* ``SparseFormat`` / ``FORMATS`` — the format registry (dense, COO, CSR,
  ELL and BCSR variants, JDS) that marshal clauses refer to by name.
* ``ConversionGraph`` / ``GRAPH`` — edges are value-level repack functions
  with measured (EWMA) costs; ``plan`` picks the cheapest path from any
  already-cached intermediate to the requested target format.
* ``MarshalingCache`` — memoizes INPUT-derived values keyed on the
  fingerprints of their source arrays, with cost-aware LRU eviction;
  counts hits/misses/bytes-avoided for the Fig. 18 experiment.
* ``DataPlane`` — the shared plan-level cache: harnesses declare
  ``marshal x = repack(keys) from SRC to DST`` and ``ensure`` walks the
  conversion graph, so two harnesses targeting the same format share one
  cached buffer and a CSR->BCSR repack can ride an already-cached DENSE
  intermediate.
* ``MarshalPolicy`` — per-compile knobs (``CompileOptions.marshal_policy``):
  declared call frequency for repack amortization (what the autotuner folds
  into winner selection), cache capacity, device residency, exactness.
* ``ReadObject`` — the paper's Fig. 14 template: construct / update /
  destruct driven by fingerprint changes instead of mprotect faults.
* ``TrackedArray`` — optional explicit-version wrapper for apps that mutate
  matrices functionally; version bumps replace hashing entirely (zero
  overhead, the closest analogue to a clean mprotect page table).
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

_SMALL = 1 << 16  # full-hash threshold in bytes

_MISSING = object()


def fingerprint(arr: Any, exact: bool = False) -> Tuple:
    """Content fingerprint of an array (or scalar / TrackedArray)."""
    if isinstance(arr, TrackedArray):
        return version_token(arr)   # THE O(1) token rule, defined once
    if isinstance(arr, (int, float, bool)):
        return ("scalar", arr)
    a = np.asarray(arr)
    meta = (a.shape, str(a.dtype))
    if exact or a.nbytes <= _SMALL:
        digest = hashlib.blake2b(a.tobytes(), digest_size=16).hexdigest()
        return ("full", meta, digest)
    # strided sample + edges: cheap, catches structural changes; apps that
    # need exactness use TrackedArray or exact=True.
    flat = a.reshape(-1)
    step = max(1, flat.shape[0] // 1024)
    sample = np.concatenate([flat[::step][:1024], flat[:64], flat[-64:]])
    digest = hashlib.blake2b(sample.tobytes(), digest_size=16).hexdigest()
    return ("sampled", meta, digest)


class TrackedArray:
    """Explicit-version wrapper: functional updates bump the version, so
    fingerprinting is O(1).  ``arr`` is the current value."""

    def __init__(self, arr, base_token: Optional[object] = None, version: int = 0):
        self.arr = arr
        self.base_token = base_token if base_token is not None else object()
        self.version = version

    def replace(self, new_arr) -> "TrackedArray":
        return TrackedArray(new_arr, self.base_token, self.version + 1)

    def __repr__(self):
        return f"TrackedArray(v{self.version}, {getattr(self.arr, 'shape', ())})"


def unwrap(x):
    return x.arr if isinstance(x, TrackedArray) else x


def version_token(x) -> Tuple:
    """O(1) change token for executable-plan guards (``repro.core.plan``):
    a TrackedArray yields its (base-token id, version) pair — a functional
    update bumps it — while plain (immutable) arrays yield their object
    identity, which proves content identity for jax arrays.  Unlike
    :func:`fingerprint`, no bytes are ever read."""
    if isinstance(x, TrackedArray):
        return ("tracked", id(x.base_token), x.version)
    return ("id", id(x))


def nbytes_of(x) -> int:
    """Size of an array-like WITHOUT materializing it: reads ``nbytes`` or
    shape/dtype metadata only, so a cache hit on a device array never
    forces a device->host transfer (the Fig. 18 stats used to)."""
    x = unwrap(x)
    if isinstance(x, (int, float, bool)) or x is None:
        return 0
    nb = getattr(x, "nbytes", None)
    if nb is not None:
        return int(nb)
    shape = getattr(x, "shape", None)
    if shape is None:
        aval = getattr(x, "aval", None)
        shape = getattr(aval, "shape", None)
        if shape is None:
            return 0
        x = aval
    try:
        itemsize = np.dtype(getattr(x, "dtype", np.float32)).itemsize
    except TypeError:
        itemsize = 4
    return int(np.prod(shape)) * itemsize if len(shape) else itemsize


def tree_nbytes(val) -> int:
    """``nbytes_of`` summed over a container of arrays (marshaled values
    are often tuples of buffers — ELL/BCSR packs)."""
    if isinstance(val, (tuple, list)):
        return sum(tree_nbytes(v) for v in val)
    if isinstance(val, dict):
        return sum(tree_nbytes(v) for v in val.values())
    return nbytes_of(val)


# ---------------------------------------------------------------------------
# Format registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseFormat:
    """A first-class storage format marshal clauses can name.

    ``device_resident`` formats keep their cached buffers as device arrays
    (persistent across calls — the paper's "maintain state between calls"),
    host formats stay as numpy/python values.
    """
    name: str
    description: str = ""
    device_resident: bool = True


FORMATS: Dict[str, SparseFormat] = {}


def register_format(fmt: SparseFormat, override: bool = False) -> SparseFormat:
    if fmt.name in FORMATS and FORMATS[fmt.name] != fmt and not override:
        raise ValueError(f"format {fmt.name!r} already registered")
    FORMATS[fmt.name] = fmt
    return fmt


# Built-in format vocabulary (repro.sparse.formats containers + variants).
for _f in (
    SparseFormat("CSR", "val/col_ind/row_ptr (paper Fig. 4)"),
    SparseFormat("COO", "val/row/col triplets"),
    SparseFormat("DENSE", "densified matrix"),
    SparseFormat("ELL8", "row-padded slabs, lane=8 (VPU sublane)"),
    SparseFormat("ELL128", "row-padded slabs, lane=128 (TPU lane)"),
    SparseFormat("BCSR8x128", "block CSR, (8,128) VPU tiles"),
    SparseFormat("BCSR128x128", "block CSR, (128,128) MXU tiles"),
    SparseFormat("JDS", "jagged diagonal storage (paper Fig. 5)"),
):
    register_format(_f)


# ---------------------------------------------------------------------------
# Conversion graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ConversionEdge:
    """One value-level repack ``src-format value -> dst-format value`` with
    a measured cost (EWMA of observed seconds; ``est_cost`` is the prior
    used before the first measurement)."""
    src: str
    dst: str
    fn: Callable[[Any], Any]
    name: str
    est_cost: float = 1.0
    measured: Optional[float] = None
    runs: int = 0

    def cost(self) -> float:
        return self.measured if self.measured is not None else self.est_cost

    def run(self, value) -> Tuple[Any, float]:
        t0 = time.perf_counter()
        out = self.fn(value)
        dt = time.perf_counter() - t0
        self.measured = dt if self.measured is None \
            else 0.7 * self.measured + 0.3 * dt
        self.runs += 1
        return out, dt


class ConversionGraph:
    """Cost-weighted directed graph over format names.  The planner picks
    the cheapest conversion *path* — possibly through an intermediate
    format that is already cached (Linnea-style: global plan over a space
    of conversion sequences, not a greedy single hop)."""

    def __init__(self):
        self._edges: Dict[str, List[ConversionEdge]] = {}

    def add(self, edge: ConversionEdge, override: bool = False) -> ConversionEdge:
        outs = self._edges.setdefault(edge.src, [])
        for i, e in enumerate(outs):
            if e.dst == edge.dst:
                if not override:
                    raise ValueError(
                        f"edge {edge.src}->{edge.dst} already registered")
                outs[i] = edge
                return edge
        outs.append(edge)
        return edge

    def edges(self) -> List[ConversionEdge]:
        return [e for outs in self._edges.values() for e in outs]

    def edges_from(self, src: str) -> List[ConversionEdge]:
        return list(self._edges.get(src, []))

    def plan(self, starts: Dict[str, float], dst: str
             ) -> Optional[Tuple[str, List[ConversionEdge], float]]:
        """Dijkstra from a set of start formats (each with an entry cost —
        0.0 for cached intermediates, the loader estimate for the source)
        to ``dst``.  Returns (chosen start, edge path, total cost)."""
        if dst in starts:
            return dst, [], starts[dst]
        best: Dict[str, float] = dict(starts)
        back: Dict[str, Tuple[Optional[str], Optional[ConversionEdge]]] = {
            s: (None, None) for s in starts}
        counter = itertools.count()
        heap = [(c, next(counter), s) for s, c in starts.items()]
        heapq.heapify(heap)
        seen = set()
        while heap:
            cost, _, node = heapq.heappop(heap)
            if node in seen:
                continue
            seen.add(node)
            if node == dst:
                break
            for e in self._edges.get(node, []):
                nc = cost + max(e.cost(), 0.0)
                if e.dst not in best or nc < best[e.dst]:
                    best[e.dst] = nc
                    back[e.dst] = (node, e)
                    heapq.heappush(heap, (nc, next(counter), e.dst))
        if dst not in back:
            return None
        path: List[ConversionEdge] = []
        node = dst
        while True:
            prev, edge = back[node]
            if edge is None:
                start = node
                break
            path.append(edge)
            node = prev
        path.reverse()
        return start, path, best[dst]

    def full_path_cost(self, src_fmt: str, dst: str,
                      entry_cost: float = 0.0) -> Optional[float]:
        """Cheapest-path cost src->dst from measured/estimated edge costs,
        ignoring cached intermediates (the deterministic, sharing-independent
        repack cost the autotuner amortizes)."""
        plan = self.plan({src_fmt: entry_cost}, dst)
        return None if plan is None else plan[2]

    def plan_cost(self, start_states: Dict[str, float], target: str
                  ) -> Optional[Tuple[float, Tuple[str, ...]]]:
        """Side-effect-free path costing for the joint plan optimizer
        (``repro.core.plan_search``): cheapest cost from any start format
        (each carrying its entry cost — 0.0 for an intermediate another
        assignment already builds) to ``target``, plus the formats the
        winning path would materialize along the way.  No edges run, no
        EWMAs update — this is the cost ORACLE, not the executor."""
        plan = self.plan(dict(start_states), target)
        if plan is None:
            return None
        start, path, cost = plan
        return cost, (start,) + tuple(e.dst for e in path)


GRAPH = ConversionGraph()


def edge(src: str, dst: str, *, name: Optional[str] = None,
         est_cost: float = 1.0, graph: Optional[ConversionGraph] = None,
         override: bool = False):
    """Decorator: register a value-level conversion as a graph edge."""
    def deco(fn):
        (graph or GRAPH).add(
            ConversionEdge(src, dst, fn, name or f"{src}->{dst}",
                           est_cost=est_cost), override=override)
        return fn
    return deco


# Binding loaders: how a marshal clause's *source* format is materialized
# from a harness binding.  Keyed by the clause's ``from`` name; the value
# is (produced format, fn, cost EWMA holder).
@dataclasses.dataclass
class SourceLoader:
    name: str
    fmt: str
    fn: Callable[[Dict[str, Any]], Any]
    measured: Optional[float] = None

    def cost(self) -> float:
        return self.measured if self.measured is not None else 0.1

    def run(self, binding) -> Tuple[Any, float]:
        t0 = time.perf_counter()
        out = self.fn(binding)
        dt = time.perf_counter() - t0
        self.measured = dt if self.measured is None \
            else 0.7 * self.measured + 0.3 * dt
        return out, dt


SOURCES: Dict[str, SourceLoader] = {}


def register_source(name: str, fmt: str, fn: Callable, override: bool = False
                    ) -> SourceLoader:
    if fmt not in FORMATS:
        raise ValueError(f"source {name!r} produces unknown format {fmt!r}")
    if name in SOURCES and not override:
        raise ValueError(f"source loader {name!r} already registered")
    loader = SourceLoader(name, fmt, fn)
    SOURCES[name] = loader
    return loader


# ---------------------------------------------------------------------------
# Policy + stats
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MarshalPolicy:
    """Knobs for the data plane (``CompileOptions.marshal_policy``).

    ``reuse``   declared call frequency: expected harness calls per matrix
                change.  The autotuner folds repack cost in at this rate
                (steady-state amortized cost = kernel + marshal/reuse).
    ``max_entries``      plan-cache capacity (cost-aware LRU beyond it).
    ``device_resident``  keep cached buffers as device arrays.
    ``exact``            exact fingerprints (no sampling) for cache keys.
    ``enabled``          False disables caching entirely (every call
                         repacks — the paper's "naive library call").
    """
    reuse: float = 100.0
    max_entries: int = 64
    device_resident: bool = True
    exact: bool = False
    enabled: bool = True

    @staticmethod
    def parse(val) -> "MarshalPolicy":
        if val is None:
            return MarshalPolicy()
        if isinstance(val, MarshalPolicy):
            return val
        if isinstance(val, str):
            if val in ("shared", "default", "on"):
                return MarshalPolicy()
            if val in ("off", "none", "disabled"):
                return MarshalPolicy(enabled=False)
            if val == "exact":
                return MarshalPolicy(exact=True)
            raise ValueError(f"unknown marshal_policy {val!r} "
                             "(use 'shared' | 'off' | 'exact' or a "
                             "MarshalPolicy instance)")
        raise TypeError(f"marshal_policy must be str or MarshalPolicy, "
                        f"got {type(val).__name__}")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    bytes_avoided: int = 0
    recompute_seconds_avoided: float = 0.0
    edge_runs: int = 0          # conversion-graph edges executed
    loader_runs: int = 0        # binding->format source loads executed
    shared_edge_hits: int = 0   # planned paths that started from a cached
                                # intermediate instead of the binding
    evictions: int = 0

    def reset(self):
        self.hits = self.misses = self.bytes_avoided = 0
        self.recompute_seconds_avoided = 0.0
        self.edge_runs = self.loader_runs = self.shared_edge_hits = 0
        self.evictions = 0


@dataclasses.dataclass
class PlanStats:
    """Per-(source, target-format) cache accounting, surfaced by Fig. 18."""
    src: str
    dst: str
    hits: int = 0
    misses: int = 0
    bytes_avoided: int = 0
    seconds_avoided: float = 0.0
    build_seconds: float = 0.0
    last_path: Tuple[str, ...] = ()
    shared_prefix_hits: int = 0
    # joint-search observability: how often (and how many bytes' worth) a
    # planned path entered at an intermediate another plan already built —
    # the cost-0 sharing assumption plan_search's model relies on
    rides: int = 0
    shared_prefix_bytes: int = 0

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["last_path"] = list(self.last_path)
        return d


# ---------------------------------------------------------------------------
# The caches
# ---------------------------------------------------------------------------

class MarshalingCache:
    """Memoizes marshaled INPUTs (paper Fig. 8/9/10): format conversions,
    derived invariants, device-resident buffers.

    Eviction is cost-aware LRU: entries are kept in recency order (a hit
    refreshes), and when capacity is exceeded the *cheapest-to-recompute*
    entry among the least-recently-used window is dropped — a hot or
    expensive repack survives churn that a FIFO would evict it under.
    """

    #: how many LRU-tail entries compete on recompute cost at eviction
    EVICT_WINDOW = 8

    def __init__(self, exact: bool = False, max_entries: int = 64):
        self.exact = exact
        self.max_entries = max_entries
        self._store: "OrderedDict[Tuple, Any]" = OrderedDict()
        self._cost: Dict[Tuple, float] = {}
        self._spec_cost: Dict[str, float] = {}   # repack name -> last seconds
        self.stats = CacheStats()

    def _key(self, spec_name: str, key_arrays: Sequence) -> Tuple:
        return (spec_name,) + tuple(
            fingerprint(a, self.exact) for a in key_arrays)

    def _hit(self, key: Tuple, key_arrays: Sequence):
        self._store.move_to_end(key)
        self.stats.hits += 1
        self.stats.bytes_avoided += sum(nbytes_of(a) for a in key_arrays)
        self.stats.recompute_seconds_avoided += self._cost.get(key, 0.0)

    def _evict(self):
        while len(self._store) > self.max_entries:
            # candidates come from the LRU head; the most-recently-used
            # entry is never eligible, so a just-inserted value cannot be
            # evicted out from under its own insert
            window = min(self.EVICT_WINDOW, len(self._store) - 1)
            tail = list(itertools.islice(iter(self._store), window))
            victim = min(tail, key=lambda k: self._cost.get(k, 0.0))
            self._store.pop(victim)
            self._cost.pop(victim, None)
            self.stats.evictions += 1

    def _insert(self, key: Tuple, val: Any, cost: float):
        self._store[key] = val
        self._store.move_to_end(key)
        self._cost[key] = cost
        self._evict()

    def get(self, spec_name: str, key_arrays: Tuple, compute: Callable[[], Any]):
        """Return cached value for ``spec_name`` derived from ``key_arrays``;
        recompute only if any source array changed (the mprotect analogue)."""
        key = self._key(spec_name, key_arrays)
        val = self._store.get(key, _MISSING)
        if val is not _MISSING:
            self._hit(key, key_arrays)
            return val
        self.stats.misses += 1
        from repro.core import faults
        if faults.ACTIVE is not None:
            faults.fail("marshal_raise", spec_name)
        t0 = time.perf_counter()
        val = compute()
        cost = time.perf_counter() - t0
        self._spec_cost[spec_name] = cost
        self._insert(key, val, cost)
        return val

    def marshal_seconds(self, repack_names: Sequence[str]) -> float:
        """Last measured repack seconds for the named repacks (0.0 when a
        repack has not run through this cache) — what the autotuner folds
        into winner selection for legacy (format-less) marshal clauses."""
        return sum(self._spec_cost.get(n, 0.0) for n in repack_names)

    def estimate_marshal_seconds(self, clauses: Sequence[Any]) -> float:
        """Cold-repack cost estimate for a harness's marshal clauses."""
        return self.marshal_seconds(
            [getattr(cl, "repack", cl) for cl in clauses])

    def clear(self):
        self._store.clear()
        self._cost.clear()


class DataPlane(MarshalingCache):
    """The shared plan-level cache: format-aware marshaling over the
    conversion graph.

    ``ensure(src, dst, key_arrays, binding)`` materializes the ``dst``
    format for the matrix identified by ``key_arrays``' fingerprints:

    1. plan-cache hit -> return the persistent (device-resident) buffer;
    2. otherwise plan the cheapest conversion path over ``graph`` starting
       from any already-cached intermediate of the same matrix (cost 0) or
       from the binding loader, execute the remaining edges, and cache
       every intermediate produced — so a later harness targeting another
       format downstream of the same intermediates rides them for free.

    One ``ensure`` call counts as ONE hit or miss in ``stats`` (edge and
    loader executions are tracked separately), keeping hit/miss semantics
    identical to the legacy per-repack cache.
    """

    def __init__(self, policy: Optional[MarshalPolicy] = None,
                 graph: Optional[ConversionGraph] = None,
                 exact: Optional[bool] = None,
                 max_entries: Optional[int] = None):
        policy = policy or MarshalPolicy()
        super().__init__(
            exact=policy.exact if exact is None else exact,
            max_entries=policy.max_entries if max_entries is None
            else max_entries)
        self.policy = policy
        self.graph = graph or GRAPH
        self.plans: Dict[Tuple[str, str], PlanStats] = {}

    # -- plumbing ------------------------------------------------------------

    def _node_key(self, src: str, fmt: str, fps: Tuple) -> Tuple:
        return ("node", src, fmt) + fps

    def _plan_stats(self, src: str, dst: str) -> PlanStats:
        ps = self.plans.get((src, dst))
        if ps is None:
            ps = self.plans[(src, dst)] = PlanStats(src, dst)
        return ps

    def _maybe_device(self, fmt: str, val):
        if not self.policy.device_resident:
            return val
        f = FORMATS.get(fmt)
        if f is not None and not f.device_resident:
            return val
        try:
            import jax
            import jax.numpy as jnp
            return jax.tree_util.tree_map(jnp.asarray, val)
        except Exception:
            return val

    # -- the planner ---------------------------------------------------------

    def ensure(self, src: str, dst: str, key_arrays: Sequence,
               binding: Dict[str, Any],
               fallback: Optional[Callable[[], Any]] = None):
        """Materialize format ``dst`` for the matrix identified by the
        fingerprints of ``key_arrays``, via the cheapest conversion path.
        ``fallback`` (the clause's legacy repack) runs when no path exists."""
        loader = SOURCES.get(src)
        if loader is None or dst not in FORMATS:
            if fallback is None:
                raise KeyError(f"unknown marshal source {src!r} or "
                               f"format {dst!r} and no fallback repack")
            return self.get(f"{src}->{dst}", tuple(key_arrays), fallback)

        fps = tuple(fingerprint(a, self.exact) for a in key_arrays)
        key = self._node_key(src, dst, fps)
        ps = self._plan_stats(src, dst)
        val = self._store.get(key, _MISSING)
        if val is not _MISSING:
            self._hit(key, key_arrays)
            ps.hits += 1
            ps.bytes_avoided += sum(nbytes_of(a) for a in key_arrays)
            ps.seconds_avoided += self._cost.get(key, 0.0)
            return val

        self.stats.misses += 1
        ps.misses += 1
        from repro.core import faults
        if faults.ACTIVE is not None:
            faults.fail("marshal_raise", f"{src}->{dst}")

        # start set: cached intermediates of the SAME matrix (cost 0) plus
        # the binding loader at its measured cost
        starts: Dict[str, float] = {}
        cached_vals: Dict[str, Tuple] = {}
        for k in self._store:
            if (isinstance(k, tuple) and len(k) == 3 + len(fps)
                    and k[0] == "node" and k[1] == src and k[3:] == fps):
                starts[k[2]] = 0.0
                cached_vals[k[2]] = k
        loader_start = loader.fmt not in starts
        if loader_start:
            starts.setdefault(loader.fmt, loader.cost())

        plan = self.graph.plan(starts, dst)
        if plan is None:
            if fallback is None:
                raise KeyError(f"no conversion path {src}({loader.fmt})"
                               f"->{dst} and no fallback repack")
            t0 = time.perf_counter()
            val = fallback()
            cost = time.perf_counter() - t0
            self._spec_cost[f"{src}->{dst}"] = cost
            ps.build_seconds += cost
            ps.last_path = (f"{src}!fallback", dst)
            val = self._maybe_device(dst, val)
            self._insert(key, val, cost)
            return val

        start_fmt, path, _ = plan
        paid = 0.0
        path_names = [start_fmt] + [e.dst for e in path]
        if start_fmt in cached_vals:
            # ride an already-cached intermediate (possibly built for a
            # DIFFERENT harness) — the plan-level sharing win
            val = self._store[cached_vals[start_fmt]]
            self._store.move_to_end(cached_vals[start_fmt])
            self.stats.shared_edge_hits += 1
            ps.shared_prefix_hits += 1
            ps.rides += 1
            ps.shared_prefix_bytes += tree_nbytes(val)
        else:
            val, dt = loader.run(binding)
            paid += dt
            self.stats.loader_runs += 1
            val = self._maybe_device(start_fmt, val)
            self._insert(self._node_key(src, start_fmt, fps), val, paid)
        for e in path:
            val, dt = e.run(val)
            paid += dt
            self.stats.edge_runs += 1
            val = self._maybe_device(e.dst, val)
            # cache every intermediate: cost = cumulative seconds paid to
            # produce it in THIS ensure (what a hit on it will avoid)
            self._insert(self._node_key(src, e.dst, fps), val, paid)
        ps.build_seconds += paid
        ps.last_path = tuple(path_names)
        return val

    # -- autotuner interface -------------------------------------------------

    def estimate_marshal_seconds(self, clauses: Sequence[Any]) -> float:
        """Steady-state repack cost of a harness's marshal clauses: the
        cheapest full conversion path from the binding (measured EWMA edge
        costs; sharing-independent so tuning decisions are stable).  Legacy
        clauses without formats fall back to their last measured cost."""
        total = 0.0
        for cl in clauses:
            src = getattr(cl, "src", None)
            dst = getattr(cl, "dst", None)
            if src and dst and src in SOURCES and dst in FORMATS:
                loader = SOURCES[src]
                c = self.graph.full_path_cost(loader.fmt, dst,
                                             entry_cost=loader.cost())
                if c is not None:
                    total += c
                    continue
                # no graph path: ensure() served this clause via its
                # fallback repack and recorded the cost under "src->dst"
                fb = self._spec_cost.get(f"{src}->{dst}")
                if fb is not None:
                    total += fb
                    continue
            total += self._spec_cost.get(getattr(cl, "repack", str(cl)), 0.0)
        return total

    def plan_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-plan accounting for benchmarks: '{src}->{dst}' -> stats."""
        return {f"{src}->{dst}": ps.as_dict()
                for (src, dst), ps in sorted(self.plans.items())}


class ReadObject:
    """Paper Fig. 14: specializes (construct, update, destruct) with change
    tracking.  ``construct`` runs before first use and when shape changes;
    ``update`` when content changes; ``destruct`` on release."""

    def __init__(self, construct: Callable, update: Callable,
                 destruct: Optional[Callable] = None, exact: bool = False):
        self.construct = construct
        self.update = update
        self.destruct = destruct
        self.exact = exact
        self._state: Optional[Any] = None
        self._fp: Optional[Tuple] = None
        self._shape: Optional[Tuple] = None

    def read(self, arr):
        fp = fingerprint(arr, self.exact)
        shape = tuple(np.asarray(unwrap(arr)).shape)
        if self._state is None or shape != self._shape:
            if self._state is not None and self.destruct is not None:
                self.destruct(self._state)
            self._state = self.construct(unwrap(arr))
            self._fp, self._shape = fp, shape
        elif fp != self._fp:
            self._state = self.update(unwrap(arr), self._state)
            self._fp = fp
        return self._state

    def release(self):
        if self._state is not None and self.destruct is not None:
            self.destruct(self._state)
        self._state = self._fp = self._shape = None
