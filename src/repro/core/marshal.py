"""LiLAC-How data marshaling: the mprotect analogue (paper §3.3.2, §4.2).

The paper tracks writes to host arrays with memory protection so that
device transfers and data-dependent invariants (`cols`, SparseX tuning,
format conversions) are recomputed only when the underlying memory changed.

JAX arrays are immutable, so "did this memory change?" becomes "is this the
same value?".  We answer it with content fingerprints at the harness call
boundary:

* ``fingerprint(arr)`` — cheap content hash (full bytes below a threshold,
  strided sample + shape/dtype above it; ``exact=True`` forces full bytes).
* ``MarshalingCache`` — memoizes INPUT-derived values keyed on the
  fingerprints of their source arrays; counts hits/misses/bytes-avoided so
  the Fig. 18 experiment can report the marshaling win.
* ``ReadObject`` — the paper's Fig. 14 template: construct / update /
  destruct driven by fingerprint changes instead of mprotect faults.
* ``TrackedArray`` — optional explicit-version wrapper for apps that mutate
  matrices functionally; version bumps replace hashing entirely (zero
  overhead, the closest analogue to a clean mprotect page table).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

_SMALL = 1 << 16  # full-hash threshold in bytes


def fingerprint(arr: Any, exact: bool = False) -> Tuple:
    """Content fingerprint of an array (or scalar / TrackedArray)."""
    if isinstance(arr, TrackedArray):
        return ("tracked", id(arr.base_token), arr.version)
    if isinstance(arr, (int, float, bool)):
        return ("scalar", arr)
    a = np.asarray(arr)
    meta = (a.shape, str(a.dtype))
    if exact or a.nbytes <= _SMALL:
        digest = hashlib.blake2b(a.tobytes(), digest_size=16).hexdigest()
        return ("full", meta, digest)
    # strided sample + edges: cheap, catches structural changes; apps that
    # need exactness use TrackedArray or exact=True.
    flat = a.reshape(-1)
    step = max(1, flat.shape[0] // 1024)
    sample = np.concatenate([flat[::step][:1024], flat[:64], flat[-64:]])
    digest = hashlib.blake2b(sample.tobytes(), digest_size=16).hexdigest()
    return ("sampled", meta, digest)


class TrackedArray:
    """Explicit-version wrapper: functional updates bump the version, so
    fingerprinting is O(1).  ``arr`` is the current value."""

    def __init__(self, arr, base_token: Optional[object] = None, version: int = 0):
        self.arr = arr
        self.base_token = base_token if base_token is not None else object()
        self.version = version

    def replace(self, new_arr) -> "TrackedArray":
        return TrackedArray(new_arr, self.base_token, self.version + 1)

    def __repr__(self):
        return f"TrackedArray(v{self.version}, {getattr(self.arr, 'shape', ())})"


def unwrap(x):
    return x.arr if isinstance(x, TrackedArray) else x


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    bytes_avoided: int = 0
    recompute_seconds_avoided: float = 0.0

    def reset(self):
        self.hits = self.misses = self.bytes_avoided = 0
        self.recompute_seconds_avoided = 0.0


class MarshalingCache:
    """Memoizes marshaled INPUTs (paper Fig. 8/9/10): format conversions,
    derived invariants, device-resident buffers."""

    def __init__(self, exact: bool = False, max_entries: int = 64):
        self.exact = exact
        self.max_entries = max_entries
        self._store: Dict[Tuple, Any] = {}
        self._cost: Dict[Tuple, float] = {}
        self.stats = CacheStats()

    def get(self, spec_name: str, key_arrays: Tuple, compute: Callable[[], Any]):
        """Return cached value for ``spec_name`` derived from ``key_arrays``;
        recompute only if any source array changed (the mprotect analogue)."""
        import time

        key = (spec_name,) + tuple(fingerprint(a, self.exact) for a in key_arrays)
        if key in self._store:
            self.stats.hits += 1
            self.stats.bytes_avoided += sum(
                int(np.asarray(unwrap(a)).nbytes) for a in key_arrays
                if not isinstance(a, (int, float, bool)))
            self.stats.recompute_seconds_avoided += self._cost.get(key, 0.0)
            return self._store[key]
        self.stats.misses += 1
        t0 = time.perf_counter()
        val = compute()
        self._cost[key] = time.perf_counter() - t0
        if len(self._store) >= self.max_entries:
            oldest = next(iter(self._store))
            self._store.pop(oldest)
            self._cost.pop(oldest, None)
        self._store[key] = val
        return val

    def clear(self):
        self._store.clear()
        self._cost.clear()


class ReadObject:
    """Paper Fig. 14: specializes (construct, update, destruct) with change
    tracking.  ``construct`` runs before first use and when shape changes;
    ``update`` when content changes; ``destruct`` on release."""

    def __init__(self, construct: Callable, update: Callable,
                 destruct: Optional[Callable] = None, exact: bool = False):
        self.construct = construct
        self.update = update
        self.destruct = destruct
        self.exact = exact
        self._state: Optional[Any] = None
        self._fp: Optional[Tuple] = None
        self._shape: Optional[Tuple] = None

    def read(self, arr):
        fp = fingerprint(arr, self.exact)
        shape = tuple(np.asarray(unwrap(arr)).shape)
        if self._state is None or shape != self._shape:
            if self._state is not None and self.destruct is not None:
                self.destruct(self._state)
            self._state = self.construct(unwrap(arr))
            self._fp, self._shape = fp, shape
        elif fp != self._fp:
            self._state = self.update(unwrap(arr), self._state)
            self._fp = fp
        return self._state

    def release(self):
        if self._state is not None and self.destruct is not None:
            self.destruct(self._state)
        self._state = self._fp = self._shape = None
