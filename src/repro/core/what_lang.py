"""LiLAC: the paper's specification language (Fig. 3 grammar + §3.3).

    spec    ::= { <computation> | <harness> }
    computation ::= COMPUTATION <name> <body>
    body    ::= <forall> | <stmt>
    range   ::= ( <exp> <= <name> < <exp> )
    forall  ::= forall <range> { <body> }
    stmt    ::= <addr> = sum <range> <exp> ;
    addr    ::= <name> { [ <exp> ] }
    exp     ::= <name> | <cnst> | <addr> | <exp> + <exp> | <exp> * <exp>

    harness ::= HARNESS <name> implements <namelist> { <clause> }
    clause  ::= platforms <namelist> ;
              | formats <namelist> ;
              | default_for <namelist> ;
              | host_only ;
              | marshal <name> = <name> ( <keylist> )
                    [ from <name> ] [ to <name> ] ;
              | persistent <namelist> ;
              | BeforeFirstExecution <name> ;
              | AfterLastExecution <name> ;
              | tune <name> in { <valuelist> } ;
              | constraint <exp> ( <= | < ) <exp> ;
              | fuse epilogue ;
              | vjp <name> ( <namelist> ) ;
    namelist ::= <name> { , <name> }
    keylist ::= <key> { , <key> }
    key     ::= <name> { | <name> }          -- alternatives, first present wins
    valuelist ::= <value> { , <value> }
    value   ::= <num> | <name>               -- numbers or symbolic values

A *spec* is the paper's one-off LiLAC description: the What-clause (the
COMPUTATION programs — Fig. 2 spmv_csr, Fig. 5 spmv_jds, Fig. 11
dotproduct, plus the LM-framework computations) and the How-clause (the
HARNESS blocks of §3.3: which computation a backend implements, on which
platforms/formats, which inputs are *marshaled* through a repack clause —
the mprotect-amortized conversions of Fig. 8-10 — and what persistent
state is managed by BeforeFirstExecution / AfterLastExecution hooks).

This module provides a tokenizer with source positions, a recursive-descent
parser producing the ASTs below, and the builtin spec texts.  The detection
pass (`repro.core.detect`) *generates* jaxpr matchers from the What-ASTs;
`repro.core.spec` *compiles* the How-descriptors into executable `Harness`
objects — both analogues of the paper generating LLVM detection functions
and harness glue at LLVM build time.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple, Union


# ---------------------------------------------------------------------------
# AST — What (computation)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Const:
    value: float

    def __str__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True)
class Var:
    name: str

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class Load:
    """array[index] — possibly nested, e.g. a[rowstr[i]+j]."""
    array: str
    index: "Expr"

    def __str__(self):
        return f"{self.array}[{self.index}]"


@dataclasses.dataclass(frozen=True)
class Add:
    lhs: "Expr"
    rhs: "Expr"

    def __str__(self):
        return f"({self.lhs} + {self.rhs})"


@dataclasses.dataclass(frozen=True)
class Mul:
    lhs: "Expr"
    rhs: "Expr"

    def __str__(self):
        return f"({self.lhs} * {self.rhs})"


Expr = Union[Const, Var, Load, Add, Mul]


@dataclasses.dataclass(frozen=True)
class Range:
    lo: Expr
    var: str
    hi: Expr

    def __str__(self):
        return f"({self.lo} <= {self.var} < {self.hi})"


@dataclasses.dataclass(frozen=True)
class SumStore:
    """target = sum(range) expr;   target is Var (scalar) or Load (addr)."""
    target: Union[Var, Load]
    range: Range
    expr: Expr

    def __str__(self):
        return f"{self.target} = sum{self.range} {self.expr};"


@dataclasses.dataclass(frozen=True)
class ForAll:
    range: Range
    body: "Body"

    def __str__(self):
        return f"forall{self.range} {{ {self.body} }}"


Body = Union[ForAll, SumStore]


@dataclasses.dataclass(frozen=True)
class Computation:
    name: str
    body: Body

    def __str__(self):
        return f"COMPUTATION {self.name}\n{self.body}"

    # -- structural helpers used by the matcher generator ------------------

    def foralls(self) -> List[ForAll]:
        out, b = [], self.body
        while isinstance(b, ForAll):
            out.append(b)
            b = b.body
        return out

    def stmt(self) -> SumStore:
        b = self.body
        while isinstance(b, ForAll):
            b = b.body
        assert isinstance(b, SumStore)
        return b

    def free_arrays(self) -> List[str]:
        """Array names loaded/stored — the harness interface (paper §3.1:
        'it identifies the variables that are arguments to the library')."""
        seen: List[str] = []

        def walk_e(e: Expr):
            if isinstance(e, Load):
                if e.array not in seen:
                    seen.append(e.array)
                walk_e(e.index)
            elif isinstance(e, (Add, Mul)):
                walk_e(e.lhs)
                walk_e(e.rhs)

        def walk_b(b: Body):
            if isinstance(b, ForAll):
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_b(b.body)
            else:
                if isinstance(b.target, Load):
                    if b.target.array not in seen:
                        seen.append(b.target.array)
                    walk_e(b.target.index)
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_e(b.expr)

        walk_b(self.body)
        return seen

    def free_scalars(self) -> List[str]:
        """Loop-bound names that are not loop iterators and not arrays."""
        iters = {f.range.var for f in self.foralls()} | {self.stmt().range.var}
        arrays = set(self.free_arrays())
        seen: List[str] = []

        def walk_e(e: Expr):
            if isinstance(e, Var) and e.name not in iters \
                    and e.name not in arrays and e.name not in seen:
                seen.append(e.name)
            elif isinstance(e, Load):
                walk_e(e.index)
            elif isinstance(e, (Add, Mul)):
                walk_e(e.lhs)
                walk_e(e.rhs)

        def walk_b(b: Body):
            if isinstance(b, ForAll):
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_b(b.body)
            else:
                if isinstance(b.target, Load):
                    walk_e(b.target.index)
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_e(b.expr)

        walk_b(self.body)
        return seen


# ---------------------------------------------------------------------------
# AST — How (harness descriptors, paper §3.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MarshalClause:
    """``marshal <name> = <repack>(<keys>) [from <src>] [to <dst>]``: the
    named input is produced by the registered repack function, memoized in
    the marshaling cache on the fingerprints of the key arrays (the
    mprotect analogue).  Each key may list ``|``-separated alternatives;
    the first present in the binding is used (e.g. ``rowstr|rowidx``
    covers CSR and COO matches).

    ``from``/``to`` declare the repack's source loader and target format
    (names in the data plane's SOURCES / FORMATS registries).  With both
    present the conversion graph plans the repack as a *path* — sharing
    cached intermediates with other harnesses — and the repack function
    itself becomes the fallback when no path exists."""
    name: str
    repack: str
    keys: Tuple[Tuple[str, ...], ...]
    src: Optional[str] = None
    dst: Optional[str] = None

    def __str__(self):
        ks = ", ".join("|".join(alts) for alts in self.keys)
        tail = ""
        if self.src is not None:
            tail += f" from {self.src}"
        if self.dst is not None:
            tail += f" to {self.dst}"
        return f"marshal {self.name} = {self.repack}({ks}){tail};"


@dataclasses.dataclass(frozen=True)
class TuneClause:
    """``tune <param> in {v1, v2, ...}``: a declared schedule parameter.

    The first value is the *default schedule*'s value — HARNESS blocks list
    the previously hard-coded constant first so an untuned call is
    bit-identical to the pre-tuning kernel.  Values are ints, floats or
    bare names (symbolic values such as ``parallel``/``arbitrary`` for
    Pallas ``dimension_semantics``)."""
    name: str
    values: Tuple[Any, ...]

    def __str__(self):
        vals = ", ".join(str(v) for v in self.values)
        return f"tune {self.name} in {{{vals}}};"


@dataclasses.dataclass(frozen=True)
class Constraint:
    """``constraint <exp> (<=|<) <exp>``: prunes the schedule cross-product.

    Expressions use the What-language grammar over tune-parameter names and
    constants (e.g. ``block_m * block_k <= 16384`` bounds the VMEM working
    set); variants violating any constraint are never materialized."""
    lhs: Expr
    op: str          # '<=' | '<'
    rhs: Expr

    def __str__(self):
        return f"constraint {self.lhs} {self.op} {self.rhs};"

    def holds(self, env: Dict[str, Any]) -> bool:
        lhs = _eval_expr(self.lhs, env)
        rhs = _eval_expr(self.rhs, env)
        return lhs <= rhs if self.op == "<=" else lhs < rhs

    def params(self) -> Tuple[str, ...]:
        """Names referenced by either side (must all be tune params)."""
        out: List[str] = []

        def walk(e: Expr):
            if isinstance(e, Var):
                if e.name not in out:
                    out.append(e.name)
            elif isinstance(e, Load):
                walk(e.index)
            elif isinstance(e, (Add, Mul)):
                walk(e.lhs)
                walk(e.rhs)

        walk(self.lhs)
        walk(self.rhs)
        return tuple(out)


def _eval_expr(e: Expr, env: Dict[str, Any]):
    """Evaluate a constraint expression over concrete parameter values.
    Referencing a non-numeric (symbolic) tune value raises TypeError,
    which surfaces as a registration-time SpecError for the whole harness
    (constraints are arithmetic; symbolic params can't be bounded)."""
    if isinstance(e, Const):
        return e.value
    if isinstance(e, Var):
        v = env[e.name]
        if not isinstance(v, (int, float)):
            raise TypeError(f"constraint references non-numeric value "
                            f"{e.name}={v!r}")
        return v
    if isinstance(e, Add):
        return _eval_expr(e.lhs, env) + _eval_expr(e.rhs, env)
    if isinstance(e, Mul):
        return _eval_expr(e.lhs, env) * _eval_expr(e.rhs, env)
    raise TypeError(f"unsupported constraint expression {e!r}")


@dataclasses.dataclass(frozen=True)
class VjpClause:
    """``vjp <name>(<wrt>)``: the harness is differentiable — wrap its call
    in ``jax.custom_vjp`` with the registered backward body ``name`` (see
    ``spec.vjp``), differentiating with respect to the listed binding keys.

    The backward body receives ``(binding, ctx, primal_out, cotangent)``
    and returns a dict mapping each ``wrt`` key to its gradient.  Keys not
    listed are treated as non-differentiable constants (index structure,
    routing tables); the rewriter closes over them, which is what lets a
    host-marshaling kernel survive ``jax.grad``/``vmap`` — AD never looks
    inside the forward."""
    name: str
    wrt: Tuple[str, ...]

    def __str__(self):
        return f"vjp {self.name}({', '.join(self.wrt)});"


_DEFAULT_PLATFORMS = ("cpu", "tpu")


@dataclasses.dataclass(frozen=True)
class HarnessDecl:
    """One HARNESS block: how a named backend implements What-computations."""
    name: str
    implements: Tuple[str, ...]
    platforms: Tuple[str, ...] = _DEFAULT_PLATFORMS
    formats: Tuple[str, ...] = ()
    jit_safe: bool = True                    # host_only; sets this False
    default_for: Tuple[str, ...] = ()
    marshal: Tuple[MarshalClause, ...] = ()
    persistent: Tuple[str, ...] = ()
    before_first: Optional[str] = None       # BeforeFirstExecution hook name
    after_last: Optional[str] = None         # AfterLastExecution hook name
    tune: Tuple[TuneClause, ...] = ()        # declared schedule parameters
    constraints: Tuple[Constraint, ...] = ()  # schedule-space pruning
    fuse_epilogue: bool = False              # body applies detected epilogues
    vjp: Optional[VjpClause] = None          # declared custom backward body

    def __str__(self):
        lines = [f"HARNESS {self.name} implements {', '.join(self.implements)}"]
        if self.platforms != _DEFAULT_PLATFORMS:
            lines.append(f"  platforms {', '.join(self.platforms)};")
        if self.formats:
            lines.append(f"  formats {', '.join(self.formats)};")
        if not self.jit_safe:
            lines.append("  host_only;")
        if self.default_for:
            lines.append(f"  default_for {', '.join(self.default_for)};")
        lines.extend(f"  {m}" for m in self.marshal)
        if self.persistent:
            lines.append(f"  persistent {', '.join(self.persistent)};")
        if self.before_first is not None:
            lines.append(f"  BeforeFirstExecution {self.before_first};")
        if self.after_last is not None:
            lines.append(f"  AfterLastExecution {self.after_last};")
        lines.extend(f"  {t}" for t in self.tune)
        lines.extend(f"  {c}" for c in self.constraints)
        if self.fuse_epilogue:
            lines.append("  fuse epilogue;")
        if self.vjp is not None:
            lines.append(f"  {self.vjp}")
        return "\n".join(lines)

    def default_schedule(self) -> Dict[str, Any]:
        """First declared value of every tune param — the pre-tuning
        constants, so an unswept call reproduces the fixed-constant kernel."""
        return {t.name: t.values[0] for t in self.tune}

    def schedules(self) -> Tuple[Dict[str, Any], ...]:
        """The declared schedule-variant family (see
        :func:`enumerate_schedules`); empty for untuned harnesses."""
        return enumerate_schedules(self.tune, self.constraints)


def enumerate_schedules(tune: Tuple[TuneClause, ...],
                        constraints: Tuple[Constraint, ...] = (),
                        ) -> Tuple[Dict[str, Any], ...]:
    """Cross-product of the declared tune values, filtered by constraints.

    The first variant is the default schedule (every param at its first
    declared value) when it satisfies the constraints; declared order is
    otherwise preserved so budget truncation keeps near-default variants.
    """
    if not tune:
        return ()
    import itertools

    names = [t.name for t in tune]
    out: List[Dict[str, Any]] = []
    for combo in itertools.product(*(t.values for t in tune)):
        env = dict(zip(names, combo))
        try:
            if all(c.holds(env) for c in constraints):
                out.append(env)
        except TypeError as e:
            raise ParseError(f"constraint not evaluable: {e}")
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class Spec:
    """A parsed LiLAC description: What-programs + How-descriptors."""
    computations: Tuple[Computation, ...]
    harnesses: Tuple[HarnessDecl, ...]

    def __str__(self):
        return "\n\n".join([str(c) for c in self.computations]
                           + [str(h) for h in self.harnesses])

    def computation(self, name: str) -> Computation:
        for c in self.computations:
            if c.name == name:
                return c
        raise KeyError(name)


# ---------------------------------------------------------------------------
# Tokenizer + recursive-descent parser
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(
    r"[ \t\r\n]*(?:(?P<comment>--[^\n]*)"
    r"|(?P<num>\d+(?:\.\d+)?)"
    r"|(?P<name>[A-Za-z_]\w*(?:\.[A-Za-z_]\w*)*)"
    r"|(?P<op><=|[()\[\]{}=;+*<,|])|(?P<bad>\S))"
)

_KEYWORDS = {"COMPUTATION", "HARNESS", "forall", "sum"}

# HARNESS clause words are contextual (not reserved in expressions).
_CLAUSES = {"platforms", "formats", "default_for", "host_only", "marshal",
            "persistent", "BeforeFirstExecution", "AfterLastExecution",
            "tune", "constraint", "fuse", "vjp"}


class ParseError(ValueError):
    """Parse failure with 1-based source position (``line``, ``col``)."""

    def __init__(self, msg: str, line: Optional[int] = None,
                 col: Optional[int] = None):
        if line is not None:
            msg = f"{msg} (at line {line}, col {col})"
        super().__init__(msg)
        self.line = line
        self.col = col


def _line_col_fn(src: str):
    """O(1)-per-query offset -> (line, col) via precomputed line starts."""
    import bisect

    starts = [0] + [i + 1 for i, c in enumerate(src) if c == "\n"]

    def line_col(pos: int) -> Tuple[int, int]:
        li = bisect.bisect_right(starts, pos) - 1
        return li + 1, pos - starts[li] + 1

    return line_col


def _tokenize(src: str):
    line_col = _line_col_fn(src)
    toks: List[Tuple[str, str]] = []
    positions: List[Tuple[int, int]] = []
    pos = 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if not m:
            break
        start = m.end() - len(m.group(0).lstrip())
        pos = m.end()
        if m.group("comment") is not None:
            continue
        if m.group("num") is not None:
            toks.append(("num", m.group("num")))
        elif m.group("name") is not None:
            name = m.group("name")
            toks.append(("kw" if name in _KEYWORDS else "name", name))
        elif m.group("op") is not None:
            toks.append(("op", m.group("op")))
        elif m.group("bad") is not None:
            line, col = line_col(start)
            raise ParseError(f"bad token {m.group('bad')!r}", line, col)
        positions.append(line_col(start))
    return toks, positions, line_col(len(src))


class _Parser:
    def __init__(self, src: str):
        self.toks, self.positions, self.end_pos = _tokenize(src)
        self.i = 0

    def peek(self) -> Optional[Tuple[str, str]]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def pos(self) -> Tuple[int, int]:
        """Position of the current (next-to-consume) token."""
        if self.i < len(self.positions):
            return self.positions[self.i]
        return self.end_pos

    def error(self, msg: str) -> ParseError:
        line, col = self.pos()
        return ParseError(msg, line, col)

    def next(self) -> Tuple[str, str]:
        if self.i >= len(self.toks):
            raise self.error("unexpected end of input")
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind: str, value: Optional[str] = None) -> str:
        if self.i >= len(self.toks):
            raise self.error(f"expected {value or kind}, got end of input")
        k, v = self.toks[self.i]
        if k != kind or (value is not None and v != value):
            raise self.error(f"expected {value or kind}, got {v!r}")
        self.i += 1
        return v

    # spec ::= { computation | harness }
    def spec(self) -> Spec:
        comps: List[Computation] = []
        harnesses: List[HarnessDecl] = []
        while True:
            t = self.peek()
            if t is None:
                break
            if t == ("kw", "COMPUTATION"):
                comps.append(self.program())
            elif t == ("kw", "HARNESS"):
                harnesses.append(self.harness())
            else:
                raise self.error(
                    f"expected COMPUTATION or HARNESS, got {t[1]!r}")
        if not comps and not harnesses:
            raise self.error("empty spec")
        return Spec(tuple(comps), tuple(harnesses))

    # program ::= COMPUTATION <name> <body>
    def program(self) -> Computation:
        self.expect("kw", "COMPUTATION")
        name = self.expect("name")
        return Computation(name=name, body=self.body())

    def body(self) -> Body:
        t = self.peek()
        if t == ("kw", "forall"):
            return self.forall()
        return self.stmt()

    # forall ::= forall ( exp <= name < exp ) { body }
    def forall(self) -> ForAll:
        self.expect("kw", "forall")
        rng = self.range_()
        self.expect("op", "{")
        b = self.body()
        self.expect("op", "}")
        return ForAll(range=rng, body=b)

    def range_(self) -> Range:
        self.expect("op", "(")
        lo = self.expr()
        self.expect("op", "<=")
        var = self.expect("name")
        self.expect("op", "<")
        hi = self.expr()
        self.expect("op", ")")
        return Range(lo=lo, var=var, hi=hi)

    # stmt ::= addr = sum ( range ) expr ;
    def stmt(self) -> SumStore:
        target = self.addr_or_var()
        self.expect("op", "=")
        self.expect("kw", "sum")
        rng = self.range_()
        e = self.expr()
        self.expect("op", ";")
        return SumStore(target=target, range=rng, expr=e)

    def addr_or_var(self) -> Union[Var, Load]:
        name = self.expect("name")
        if self.peek() == ("op", "["):
            self.next()
            idx = self.expr()
            self.expect("op", "]")
            return Load(array=name, index=idx)
        return Var(name)

    # expr with + lowest, * higher
    def expr(self) -> Expr:
        e = self.term()
        while self.peek() == ("op", "+"):
            self.next()
            e = Add(e, self.term())
        return e

    def term(self) -> Expr:
        e = self.atom()
        while self.peek() == ("op", "*"):
            self.next()
            e = Mul(e, self.atom())
        return e

    def atom(self) -> Expr:
        t = self.peek()
        if t is None:
            raise self.error("unexpected end")
        if t[0] == "num":
            self.next()
            return Const(float(t[1]) if "." in t[1] else int(t[1]))
        if t == ("op", "("):
            self.next()
            e = self.expr()
            self.expect("op", ")")
            return e
        return self.addr_or_var()

    # -- harness blocks (§3.3) ----------------------------------------------

    def namelist(self) -> Tuple[str, ...]:
        names = [self.expect("name")]
        while self.peek() == ("op", ","):
            self.next()
            names.append(self.expect("name"))
        return tuple(names)

    def keylist(self) -> Tuple[Tuple[str, ...], ...]:
        keys = [self.key()]
        while self.peek() == ("op", ","):
            self.next()
            keys.append(self.key())
        return tuple(keys)

    def key(self) -> Tuple[str, ...]:
        alts = [self.expect("name")]
        while self.peek() == ("op", "|"):
            self.next()
            alts.append(self.expect("name"))
        return tuple(alts)

    def tune_value(self):
        t = self.peek()
        if t is None:
            raise self.error("expected a tune value, got end of input")
        if t[0] == "num":
            self.next()
            return float(t[1]) if "." in t[1] else int(t[1])
        if t[0] == "name":
            self.next()
            return t[1]
        raise self.error(f"expected a tune value (number or name), "
                         f"got {t[1]!r}")

    def harness(self) -> HarnessDecl:
        self.expect("kw", "HARNESS")
        name = self.expect("name")
        self.expect("name", "implements")
        implements = self.namelist()
        platforms = _DEFAULT_PLATFORMS
        formats: Tuple[str, ...] = ()
        jit_safe = True
        default_for: Tuple[str, ...] = ()
        marshal: List[MarshalClause] = []
        persistent: Tuple[str, ...] = ()
        before_first: Optional[str] = None
        after_last: Optional[str] = None
        tune: List[TuneClause] = []
        constraints: List[Constraint] = []
        fuse_epilogue = False
        vjp_clause: Optional[VjpClause] = None
        while True:
            t = self.peek()
            if t is None or t[0] == "kw":
                break
            if t[0] != "name":
                raise self.error(f"expected a HARNESS clause, got {t[1]!r}")
            word = t[1]
            if word not in _CLAUSES:
                raise self.error(f"unknown HARNESS clause {word!r}")
            self.next()
            if word == "platforms":
                platforms = self.namelist()
            elif word == "formats":
                formats = self.namelist()
            elif word == "default_for":
                default_for = self.namelist()
            elif word == "host_only":
                jit_safe = False
            elif word == "marshal":
                mname = self.expect("name")
                self.expect("op", "=")
                repack = self.expect("name")
                self.expect("op", "(")
                keys = self.keylist()
                self.expect("op", ")")
                src = dst = None
                if self.peek() == ("name", "from"):
                    self.next()
                    src = self.expect("name")
                if self.peek() == ("name", "to"):
                    self.next()
                    dst = self.expect("name")
                marshal.append(MarshalClause(mname, repack, keys,
                                             src=src, dst=dst))
            elif word == "persistent":
                persistent = persistent + self.namelist()
            elif word == "BeforeFirstExecution":
                before_first = self.expect("name")
            elif word == "AfterLastExecution":
                after_last = self.expect("name")
            elif word == "tune":
                pname = self.expect("name")
                if any(t.name == pname for t in tune):
                    raise self.error(f"duplicate tune parameter {pname!r}")
                self.expect("name", "in")
                self.expect("op", "{")
                values = [self.tune_value()]
                while self.peek() == ("op", ","):
                    self.next()
                    values.append(self.tune_value())
                if len(values) != len(set(values)):
                    raise self.error(
                        f"duplicate values in tune {pname!r}")
                self.expect("op", "}")
                tune.append(TuneClause(pname, tuple(values)))
            elif word == "constraint":
                lhs = self.expr()
                t = self.peek()
                if t not in (("op", "<="), ("op", "<")):
                    raise self.error(
                        f"expected <= or < in constraint, got "
                        f"{t[1] if t else 'end of input'!r}")
                self.next()
                rhs = self.expr()
                constraints.append(Constraint(lhs, t[1], rhs))
            elif word == "fuse":
                self.expect("name", "epilogue")
                fuse_epilogue = True
            elif word == "vjp":
                if vjp_clause is not None:
                    raise self.error("duplicate vjp clause")
                vname = self.expect("name")
                self.expect("op", "(")
                wrt = self.namelist()
                self.expect("op", ")")
                vjp_clause = VjpClause(vname, wrt)
            self.expect("op", ";")
        tune_names = {t.name for t in tune}
        for c in constraints:
            for p in c.params():
                if p not in tune_names:
                    raise self.error(
                        f"constraint references unknown tune parameter "
                        f"{p!r} (declared: {sorted(tune_names)})")
        return HarnessDecl(name=name, implements=implements,
                           platforms=platforms, formats=formats,
                           jit_safe=jit_safe, default_for=default_for,
                           marshal=tuple(marshal), persistent=persistent,
                           before_first=before_first, after_last=after_last,
                           tune=tuple(tune), constraints=tuple(constraints),
                           fuse_epilogue=fuse_epilogue, vjp=vjp_clause)


def parse_spec(src: str) -> Spec:
    """Parse a full LiLAC spec: computations and/or harness blocks."""
    p = _Parser(src)
    spec = p.spec()
    if p.peek() is not None:
        raise p.error(f"trailing tokens: {p.peek()}")
    return spec


def parse(src: str) -> Computation:
    """Parse a LiLAC-What program (exactly one COMPUTATION; any HARNESS
    blocks in the text are parsed, validated and discarded)."""
    spec = parse_spec(src)
    if len(spec.computations) != 1:
        raise ParseError(
            f"expected exactly one COMPUTATION, got {len(spec.computations)}")
    return spec.computations[0]


def parse_harness(src: str) -> HarnessDecl:
    """Parse a single HARNESS block (no COMPUTATION)."""
    spec = parse_spec(src)
    if spec.computations or len(spec.harnesses) != 1:
        raise ParseError("expected exactly one HARNESS block")
    return spec.harnesses[0]


# ---------------------------------------------------------------------------
# Builtin specs (paper Figs. 2, 5, 11 + framework computations, with the
# §3.3 harness descriptors for the jnp.* backends; the pallas.* backends
# declare their HARNESS blocks next to their kernels under repro/kernels/).
# ---------------------------------------------------------------------------

BUILTIN_SPECS: Dict[str, str] = {}

BUILTIN_SPECS["spmv"] = """
COMPUTATION spmv_csr
forall(0 <= i < rows) {
  output[i] = sum(rowstr[i] <= j < rowstr[i+1]) a[j] * iv[colidx[j]];
}

COMPUTATION spmv_coo
forall(0 <= i < rows) {
  output[i] = sum(0 <= j < nnz) delta[rowidx[j]] * a[j] * iv[colidx[j]];
}

HARNESS jnp.segment implements spmv_csr, spmv_coo
  formats CSR, COO;
  default_for cpu, tpu;

HARNESS jnp.ell implements spmv_csr, spmv_coo
  formats CSR, COO;
  host_only;
  marshal ell = ell_pack(a, colidx, rowstr|rowidx) from csr_binding to ELL8;

HARNESS jnp.bcsr implements spmv_csr, spmv_coo
  formats CSR, COO;
  host_only;
  marshal bcsr = bcsr_pack(a, colidx, rowstr|rowidx)
      from csr_binding to BCSR8x128;

HARNESS jnp.dense implements spmv_csr, spmv_coo
  formats CSR, COO;
  host_only;
  marshal dense = densify(a, colidx, rowstr|rowidx)
      from csr_binding to DENSE;
"""
# delta[rowidx[j]] denotes the i==rowidx[j] indicator; the generated matcher
# realizes it as the scatter-add-by-row skeleton (see detect.py).

BUILTIN_SPECS["spmv_padded"] = """
COMPUTATION spmv_ell
forall(0 <= i < rows) {
  output[i] = sum(0 <= j < width) val[i*width+j] * iv[colidx[i*width+j]];
}

COMPUTATION spmv_jds
forall(0 <= i < rows) {
  output[perm[i]] = sum(0 <= j < nzcnt[i])
      val[jd_ptr[j]+i] * vector[col_ind[jd_ptr[j]+i]];
}

HARNESS jnp.ell implements spmv_ell, spmv_jds
  formats ELL, JDS;
  default_for cpu;
"""

BUILTIN_SPECS["spmm"] = """
COMPUTATION spmm_csr
forall(0 <= i < rows) {
  forall(0 <= n < ncols) {
    output[i*ncols+n] = sum(rowstr[i] <= j < rowstr[i+1])
        a[j] * dense[colidx[j]*ncols+n];
  }
}

HARNESS jnp.segment implements spmm_csr
  formats CSR, COO;
  default_for cpu;

HARNESS jnp.bcsr implements spmm_csr
  formats CSR, COO;
  host_only;
  marshal bcsr = bcsr_pack_mm(a, colidx, rowstr|rowidx)
      from csr_binding_mm to BCSR8x128;
"""

BUILTIN_SPECS["dotproduct"] = """
COMPUTATION dotproduct
result = sum(0 <= i < length) a[i] * b[i];

HARNESS jnp.dot implements dotproduct
  default_for cpu, tpu;
"""

BUILTIN_SPECS["gemv"] = """
COMPUTATION gemv
forall(0 <= i < rows) {
  output[i] = sum(0 <= j < cols) mat[i*cols+j] * vec[j];
}

HARNESS jnp.dot implements gemv
  default_for cpu, tpu;
"""

# The MoE expert FFN with one-hot dispatch: the sparse computation inside
# modern LMs.  dispatch[t*E+e] is top-k sparse; computing h for all (e, t)
# is the naive dense realization the LiLAC pass detects and replaces.
BUILTIN_SPECS["moe_ffn"] = """
COMPUTATION moe_ffn
forall(0 <= t < tokens) {
  out[t*dm+d] = sum(0 <= e < experts)
      dispatch[t*experts+e] * y[e*tokens*dm+t*dm+d];
}

HARNESS jnp.capacity implements moe_ffn
  default_for cpu;
"""

# The dense baseline registers AFTER the Pallas kernels' own HARNESS
# blocks: candidate order is registration order, and the autotuner's
# exploration budget truncates in that order, so the baseline must stay
# last exactly as in the pre-spec hand-wired registry.
BUILTIN_SPECS["moe_ffn_baseline"] = """
HARNESS dense implements moe_ffn
"""

# Families whose harnesses must register after the kernel packages'.
POST_KERNEL_FAMILIES = ("moe_ffn_baseline",)

_BUILTIN_PARSED: Dict[str, Spec] = {k: parse_spec(v)
                                    for k, v in BUILTIN_SPECS.items()}

BUILTINS: Dict[str, Computation] = {
    c.name: c for s in _BUILTIN_PARSED.values() for c in s.computations
}

# Back-compat constants (paper Figs. 2, 5, 11).
SPMV_CSR = BUILTINS["spmv_csr"]
SPMV_COO = BUILTINS["spmv_coo"]
SPMV_ELL = BUILTINS["spmv_ell"]
SPMV_JDS = BUILTINS["spmv_jds"]
SPMM_CSR = BUILTINS["spmm_csr"]
DOTPRODUCT = BUILTINS["dotproduct"]
GEMV = BUILTINS["gemv"]
MOE_FFN = BUILTINS["moe_ffn"]
