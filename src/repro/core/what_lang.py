"""LiLAC-What: the paper's specification language (Fig. 3 grammar).

    program ::= COMPUTATION <name> <body>
    body    ::= <forall> | <stmt>
    range   ::= ( <exp> <= <name> < <exp> )
    forall  ::= forall <range> { <body> }
    stmt    ::= <addr> = sum <range> <exp> ;
    addr    ::= <name> { [ <exp> ] }
    exp     ::= <name> | <cnst> | <addr> | <exp> + <exp> | <exp> * <exp>

This module provides a tokenizer, a recursive-descent parser producing the
AST below, and the builtin What-programs used throughout the system (the
paper's Fig. 2 spmv_csr, Fig. 5 spmv_jds, Fig. 11 dotproduct, plus the
LM-framework computations).  The detection pass (`repro.core.detect`)
*generates* jaxpr matchers from these ASTs, the analogue of the paper
generating LLVM detection functions at LLVM build time.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Tuple, Union


# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Const:
    value: float

    def __str__(self):
        return repr(self.value)


@dataclasses.dataclass(frozen=True)
class Var:
    name: str

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class Load:
    """array[index] — possibly nested, e.g. a[rowstr[i]+j]."""
    array: str
    index: "Expr"

    def __str__(self):
        return f"{self.array}[{self.index}]"


@dataclasses.dataclass(frozen=True)
class Add:
    lhs: "Expr"
    rhs: "Expr"

    def __str__(self):
        return f"({self.lhs} + {self.rhs})"


@dataclasses.dataclass(frozen=True)
class Mul:
    lhs: "Expr"
    rhs: "Expr"

    def __str__(self):
        return f"({self.lhs} * {self.rhs})"


Expr = Union[Const, Var, Load, Add, Mul]


@dataclasses.dataclass(frozen=True)
class Range:
    lo: Expr
    var: str
    hi: Expr

    def __str__(self):
        return f"({self.lo} <= {self.var} < {self.hi})"


@dataclasses.dataclass(frozen=True)
class SumStore:
    """target = sum(range) expr;   target is Var (scalar) or Load (addr)."""
    target: Union[Var, Load]
    range: Range
    expr: Expr

    def __str__(self):
        return f"{self.target} = sum{self.range} {self.expr};"


@dataclasses.dataclass(frozen=True)
class ForAll:
    range: Range
    body: "Body"

    def __str__(self):
        return f"forall{self.range} {{ {self.body} }}"


Body = Union[ForAll, SumStore]


@dataclasses.dataclass(frozen=True)
class Computation:
    name: str
    body: Body

    def __str__(self):
        return f"COMPUTATION {self.name}\n{self.body}"

    # -- structural helpers used by the matcher generator ------------------

    def foralls(self) -> List[ForAll]:
        out, b = [], self.body
        while isinstance(b, ForAll):
            out.append(b)
            b = b.body
        return out

    def stmt(self) -> SumStore:
        b = self.body
        while isinstance(b, ForAll):
            b = b.body
        assert isinstance(b, SumStore)
        return b

    def free_arrays(self) -> List[str]:
        """Array names loaded/stored — the harness interface (paper §3.1:
        'it identifies the variables that are arguments to the library')."""
        seen: List[str] = []

        def walk_e(e: Expr):
            if isinstance(e, Load):
                if e.array not in seen:
                    seen.append(e.array)
                walk_e(e.index)
            elif isinstance(e, (Add, Mul)):
                walk_e(e.lhs)
                walk_e(e.rhs)

        def walk_b(b: Body):
            if isinstance(b, ForAll):
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_b(b.body)
            else:
                if isinstance(b.target, Load):
                    if b.target.array not in seen:
                        seen.append(b.target.array)
                    walk_e(b.target.index)
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_e(b.expr)

        walk_b(self.body)
        return seen

    def free_scalars(self) -> List[str]:
        """Loop-bound names that are not loop iterators and not arrays."""
        iters = {f.range.var for f in self.foralls()} | {self.stmt().range.var}
        arrays = set(self.free_arrays())
        seen: List[str] = []

        def walk_e(e: Expr):
            if isinstance(e, Var) and e.name not in iters \
                    and e.name not in arrays and e.name not in seen:
                seen.append(e.name)
            elif isinstance(e, Load):
                walk_e(e.index)
            elif isinstance(e, (Add, Mul)):
                walk_e(e.lhs)
                walk_e(e.rhs)

        def walk_b(b: Body):
            if isinstance(b, ForAll):
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_b(b.body)
            else:
                if isinstance(b.target, Load):
                    walk_e(b.target.index)
                walk_e(b.range.lo)
                walk_e(b.range.hi)
                walk_e(b.expr)

        walk_b(self.body)
        return seen


# ---------------------------------------------------------------------------
# Tokenizer + recursive-descent parser
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<num>\d+(?:\.\d+)?)|(?P<name>[A-Za-z_]\w*)"
    r"|(?P<op><=|[()\[\]{}=;+*<])|(?P<bad>\S))"
)

_KEYWORDS = {"COMPUTATION", "forall", "sum"}


class ParseError(ValueError):
    pass


def _tokenize(src: str) -> List[Tuple[str, str]]:
    toks = []
    pos = 0
    while pos < len(src):
        m = _TOKEN_RE.match(src, pos)
        if not m:
            break
        pos = m.end()
        if m.group("num") is not None:
            toks.append(("num", m.group("num")))
        elif m.group("name") is not None:
            name = m.group("name")
            toks.append(("kw" if name in _KEYWORDS else "name", name))
        elif m.group("op") is not None:
            toks.append(("op", m.group("op")))
        elif m.group("bad") is not None:
            raise ParseError(f"bad token {m.group('bad')!r} at {pos}")
    return toks


class _Parser:
    def __init__(self, toks: List[Tuple[str, str]]):
        self.toks = toks
        self.i = 0

    def peek(self) -> Optional[Tuple[str, str]]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> Tuple[str, str]:
        if self.i >= len(self.toks):
            raise ParseError("unexpected end of input")
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind: str, value: Optional[str] = None) -> str:
        k, v = self.next()
        if k != kind or (value is not None and v != value):
            raise ParseError(f"expected {value or kind}, got {v!r}")
        return v

    # program ::= COMPUTATION <name> <body>
    def program(self) -> Computation:
        self.expect("kw", "COMPUTATION")
        name = self.expect("name")
        return Computation(name=name, body=self.body())

    def body(self) -> Body:
        t = self.peek()
        if t == ("kw", "forall"):
            return self.forall()
        return self.stmt()

    # forall ::= forall ( exp <= name < exp ) { body }
    def forall(self) -> ForAll:
        self.expect("kw", "forall")
        rng = self.range_()
        self.expect("op", "{")
        b = self.body()
        self.expect("op", "}")
        return ForAll(range=rng, body=b)

    def range_(self) -> Range:
        self.expect("op", "(")
        lo = self.expr()
        self.expect("op", "<=")
        var = self.expect("name")
        self.expect("op", "<")
        hi = self.expr()
        self.expect("op", ")")
        return Range(lo=lo, var=var, hi=hi)

    # stmt ::= addr = sum ( range ) expr ;
    def stmt(self) -> SumStore:
        target = self.addr_or_var()
        self.expect("op", "=")
        self.expect("kw", "sum")
        rng = self.range_()
        e = self.expr()
        self.expect("op", ";")
        return SumStore(target=target, range=rng, expr=e)

    def addr_or_var(self) -> Union[Var, Load]:
        name = self.expect("name")
        if self.peek() == ("op", "["):
            self.next()
            idx = self.expr()
            self.expect("op", "]")
            return Load(array=name, index=idx)
        return Var(name)

    # expr with + lowest, * higher
    def expr(self) -> Expr:
        e = self.term()
        while self.peek() == ("op", "+"):
            self.next()
            e = Add(e, self.term())
        return e

    def term(self) -> Expr:
        e = self.atom()
        while self.peek() == ("op", "*"):
            self.next()
            e = Mul(e, self.atom())
        return e

    def atom(self) -> Expr:
        t = self.peek()
        if t is None:
            raise ParseError("unexpected end")
        if t[0] == "num":
            self.next()
            return Const(float(t[1]) if "." in t[1] else int(t[1]))
        if t == ("op", "("):
            self.next()
            e = self.expr()
            self.expect("op", ")")
            return e
        return self.addr_or_var()


def parse(src: str) -> Computation:
    """Parse a LiLAC-What program."""
    p = _Parser(_tokenize(src))
    prog = p.program()
    if p.peek() is not None:
        raise ParseError(f"trailing tokens: {p.peek()}")
    return prog


# ---------------------------------------------------------------------------
# Builtin What-programs (paper Figs. 2, 5, 11 + framework computations)
# ---------------------------------------------------------------------------

SPMV_CSR = parse("""
COMPUTATION spmv_csr
forall(0 <= i < rows) {
  output[i] = sum(rowstr[i] <= j < rowstr[i+1]) a[j] * iv[colidx[j]];
}
""")

SPMV_COO = parse("""
COMPUTATION spmv_coo
forall(0 <= i < rows) {
  output[i] = sum(0 <= j < nnz) delta[rowidx[j]] * a[j] * iv[colidx[j]];
}
""")
# delta[rowidx[j]] denotes the i==rowidx[j] indicator; the generated matcher
# realizes it as the scatter-add-by-row skeleton (see detect.py).

SPMV_ELL = parse("""
COMPUTATION spmv_ell
forall(0 <= i < rows) {
  output[i] = sum(0 <= j < width) val[i*width+j] * iv[colidx[i*width+j]];
}
""")

SPMV_JDS = parse("""
COMPUTATION spmv_jds
forall(0 <= i < rows) {
  output[perm[i]] = sum(0 <= j < nzcnt[i])
      val[jd_ptr[j]+i] * vector[col_ind[jd_ptr[j]+i]];
}
""")

DOTPRODUCT = parse("""
COMPUTATION dotproduct
result = sum(0 <= i < length) a[i] * b[i];
""")

GEMV = parse("""
COMPUTATION gemv
forall(0 <= i < rows) {
  output[i] = sum(0 <= j < cols) mat[i*cols+j] * vec[j];
}
""")

SPMM_CSR = parse("""
COMPUTATION spmm_csr
forall(0 <= i < rows) {
  forall(0 <= n < ncols) {
    output[i*ncols+n] = sum(rowstr[i] <= j < rowstr[i+1])
        a[j] * dense[colidx[j]*ncols+n];
  }
}
""")

# The MoE expert FFN with one-hot dispatch: the sparse computation inside
# modern LMs.  dispatch[t*E+e] is top-k sparse; computing h for all (e, t)
# is the naive dense realization the LiLAC pass detects and replaces.
MOE_FFN = parse("""
COMPUTATION moe_ffn
forall(0 <= t < tokens) {
  out[t*dm+d] = sum(0 <= e < experts)
      dispatch[t*experts+e] * y[e*tokens*dm+t*dm+d];
}
""")

BUILTINS = {
    c.name: c
    for c in [SPMV_CSR, SPMV_COO, SPMV_ELL, SPMV_JDS, SPMM_CSR,
              DOTPRODUCT, GEMV, MOE_FFN]
}
