"""Persistent, signature-keyed backend autotuning (paper §3.3 / Table 2).

The paper's central empirical claim is that no sparse backend wins
everywhere: the right harness depends on platform, format and input
structure.  SparseX answers this by tuning once per matrix and reusing the
decision; LiLAC inherits the idea at the harness-selection boundary.  This
module is the persistent half of that story:

* ``signature_of`` — buckets a harness-call binding into a stable key
  ``(computation, format, platform, shape-bucket, sparsity-bucket)``.
  Shapes are bucketed to powers of two and sparsity to decades so that
  "the same kind of problem" re-uses one tuning decision across runs,
  processes and slightly-different inputs.
* ``AutotuneCache`` — versioned on-disk JSON store
  (``~/.cache/lilac/autotune.json``, overridable via ``LILAC_AUTOTUNE_CACHE``)
  with warm-start load, atomic writes (tempfile + ``os.replace`` under an
  advisory ``flock``) and invalidation whenever the registered harness set
  or registry version changes.
* ``Autotuner`` — the selection policy.  On a cache miss it measures the
  top-``budget`` candidates (host mode: steady-state eager calls through
  the marshaling cache; trace mode: timed ``jax.jit`` compiles of each
  jit-safe candidate on operands synthesized from the traced avals), pins
  the winner, and persists it.  Under budget — or when measurement is
  impossible — it falls back to the per-platform default.

Winner selection is **repack-amortized** (schema 2): for host-mode
candidates with declared marshal clauses, the measured steady-state kernel
time is combined with the data plane's measured conversion-path cost at
the declared call frequency (``MarshalPolicy.reuse`` — expected calls per
matrix change), so a backend with a blazing kernel but a ruinous repack
only wins when the repack actually amortizes.  Schema-1 cache files are
migrated on load: their kernel-only records stay valid for marshal-free
candidate sets and are re-measured (not silently trusted) whenever a
marshaling harness is in play — no stale winners.

Environment knobs:

  LILAC_AUTOTUNE_CACHE    cache file path (default ~/.cache/lilac/autotune.json)
  LILAC_AUTOTUNE_BUDGET   max candidates measured per signature (default 8)
  LILAC_AUTOTUNE_DISABLE  "1" -> never measure or persist; defaults only
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

try:  # POSIX advisory locking for concurrent tuners; harmless to lose.
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

SCHEMA_VERSION = 2
_ENV_PATH = "LILAC_AUTOTUNE_CACHE"
_ENV_BUDGET = "LILAC_AUTOTUNE_BUDGET"
_ENV_DISABLE = "LILAC_AUTOTUNE_DISABLE"
_DEFAULT_BUDGET = 8


def default_cache_path() -> Path:
    env = os.environ.get(_ENV_PATH)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "lilac" / "autotune.json"


def autotune_disabled() -> bool:
    return os.environ.get(_ENV_DISABLE, "") == "1"


def exploration_budget() -> int:
    try:
        return int(os.environ.get(_ENV_BUDGET, _DEFAULT_BUDGET))
    except ValueError:
        return _DEFAULT_BUDGET


# ---------------------------------------------------------------------------
# Signatures
# ---------------------------------------------------------------------------

def pow2_bucket(n: int) -> int:
    """Round a positive extent up to the next power of two (0 stays 0)."""
    n = int(n)
    if n <= 0:
        return 0
    return 1 << (n - 1).bit_length()


def sparsity_bucket(frac: float) -> str:
    """Decade bucket of a density fraction: 1e-4 -> 'd-4'; unknown -> 'd?'."""
    if not (frac > 0.0):
        return "d?"
    return f"d{int(np.floor(np.log10(min(frac, 1.0))))}"


def _shape_of(v: Any) -> Optional[Tuple[int, ...]]:
    shape = getattr(v, "shape", None)
    if shape is None:
        aval = getattr(v, "aval", None)
        shape = getattr(aval, "shape", None)
    if shape is None:
        return None
    return tuple(int(s) for s in shape)


def signature_of(comp: str, fmt: str, platform: str,
                 binding: Dict[str, Any]) -> str:
    """Stable string key for one harness call site.

    Works on concrete arrays and on tracers (shape/dtype only — no data is
    read), so trace-mode lowering and host-mode execution agree on the key.
    """
    dims: List[str] = []
    rows = nnz = cols = None
    for k in sorted(binding):
        v = binding[k]
        if isinstance(v, bool):
            dims.append(f"{k}={v}")
        elif isinstance(v, int):
            dims.append(f"{k}={pow2_bucket(v)}")
            if k == "rows":
                rows = v
            elif k == "nnz":
                nnz = v
        elif isinstance(v, float):
            continue
        else:
            shape = _shape_of(v)
            if shape is not None:
                dims.append(f"{k}={'x'.join(str(pow2_bucket(s)) for s in shape)}")
                if k in ("iv", "vector", "vec", "dense") and shape:
                    cols = shape[0]
    if rows and nnz and cols:
        sb = sparsity_bucket(nnz / float(rows * cols))
    else:
        sb = "d?"
    return "|".join([comp, fmt, platform, ",".join(dims), sb])


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TuneStats:
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    timing_calls: int = 0      # candidate measurements performed
    stores: int = 0
    fallbacks: int = 0         # budget/measurability forced a default
    invalidations: int = 0     # on-disk entries dropped (version/fingerprint)
    migrations: int = 0        # schema-1 entries migrated to schema 2
    remeasures: int = 0        # kernel-only records re-tuned (marshal-aware)
    save_errors: int = 0       # persistence failed (unwritable path)

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def reset(self):
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class AutotuneCache:
    """Versioned JSON store of tuning decisions.

    Layout (schema 2)::

        {"schema": 2, "registry": "<fingerprint>",
         "entries": {"<sig>": {"<mode>": {
             "harness": ..., "best_s": ..., "timings": {...},
             "marshal_s": {...}, "reuse": 100.0, "amortized_s": {...},
             "cost_model": "amortized" | "kernel_only"}}}}

    ``timings`` are steady-state kernel seconds; ``marshal_s`` the measured
    conversion-path seconds per candidate; ``amortized_s`` their
    combination at the declared call frequency (``reuse``), which is what
    the winner minimizes.  Schema-1 files are migrated in place on load:
    records become ``cost_model: "kernel_only"`` (their winner predates
    marshal-aware selection) and are re-measured instead of served when a
    marshaling candidate is present.

    Writes are atomic (tempfile in the same directory + ``os.replace``) and
    merge-on-save under an advisory lock, so concurrent tuners never
    corrupt the file and rarely lose each other's entries.
    """

    def __init__(self, path: Optional[os.PathLike] = None,
                 registry_fingerprint: str = ""):
        self.path = Path(path) if path is not None else default_cache_path()
        self.registry_fingerprint = registry_fingerprint
        self.entries: Dict[str, Dict[str, Any]] = {}
        self.stats = TuneStats()
        self.loaded = False

    # -- disk ----------------------------------------------------------------

    def _migrate_v1(self, entries: Dict[str, Dict[str, Any]]
                    ) -> Dict[str, Dict[str, Any]]:
        """Schema 1 -> 2: keep the measured kernel timings (they are still
        valid measurements) but mark records ``kernel_only`` so the tuner
        re-measures — instead of serving a potentially stale winner —
        whenever marshal-aware selection would change the answer."""
        out: Dict[str, Dict[str, Any]] = {}
        for sig, modes in entries.items():
            if not isinstance(modes, dict):
                continue
            new_modes = {}
            for mode, rec in modes.items():
                if not isinstance(rec, dict) or "harness" not in rec:
                    continue
                rec = dict(rec)
                rec.setdefault("cost_model", "kernel_only")
                rec.setdefault("marshal_s", {})
                rec.setdefault("amortized_s", dict(rec.get("timings", {})))
                new_modes[mode] = rec
                self.stats.migrations += 1
            if new_modes:
                out[sig] = new_modes
        return out

    def _read_disk(self) -> Dict[str, Dict[str, Any]]:
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            return {}
        schema = doc.get("schema") if isinstance(doc, dict) else None
        if not isinstance(doc, dict) or schema not in (1, SCHEMA_VERSION):
            self.stats.invalidations += 1
            return {}
        if doc.get("registry") != self.registry_fingerprint:
            self.stats.invalidations += 1
            return {}
        entries = doc.get("entries", {})
        if not isinstance(entries, dict):
            return {}
        if schema == 1:
            entries = self._migrate_v1(entries)
        return entries

    def load(self) -> "AutotuneCache":
        """Warm-start: merge on-disk entries under the in-memory ones."""
        disk = self._read_disk()
        for sig, modes in disk.items():
            self.entries.setdefault(sig, {}).update(
                {m: r for m, r in modes.items() if m not in self.entries.get(sig, {})})
        self.loaded = True
        return self

    def save(self):
        """Best-effort persistence: an unwritable cache location degrades to
        in-memory tuning (counted in ``stats``) instead of failing the
        computation the tuner is serving."""
        try:
            self._save()
        except OSError:
            self.stats.save_errors += 1

    def _save(self):
        self.path.parent.mkdir(parents=True, exist_ok=True)
        lock_path = self.path.with_suffix(self.path.suffix + ".lock")
        lock_f = None
        try:
            if fcntl is not None:
                lock_f = open(lock_path, "a+")
                fcntl.flock(lock_f.fileno(), fcntl.LOCK_EX)
            merged = self._read_disk()
            for sig, modes in self.entries.items():
                merged.setdefault(sig, {}).update(modes)
            doc = {"schema": SCHEMA_VERSION,
                   "registry": self.registry_fingerprint,
                   "entries": merged}
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w", encoding="utf-8") as f:
                    json.dump(doc, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        finally:
            if lock_f is not None:
                fcntl.flock(lock_f.fileno(), fcntl.LOCK_UN)
                lock_f.close()

    # -- lookup --------------------------------------------------------------

    def get(self, sig: str, mode: str) -> Optional[Dict[str, Any]]:
        rec = self.entries.get(sig, {}).get(mode)
        if rec is not None:
            self.stats.memory_hits += 1
            return rec
        if not self.loaded:
            self.load()
            rec = self.entries.get(sig, {}).get(mode)
            if rec is not None:
                self.stats.disk_hits += 1
                return rec
        self.stats.misses += 1
        return None

    def put(self, sig: str, mode: str, record: Dict[str, Any],
            persist: bool = True):
        self.entries.setdefault(sig, {})[mode] = record
        self.stats.stores += 1
        if persist:
            self.save()


# ---------------------------------------------------------------------------
# Operand synthesis (trace-mode measurement)
# ---------------------------------------------------------------------------

def _infer_cols(binding: Dict[str, Any], shapes: Dict[str, Tuple[int, ...]]) -> int:
    for k in ("iv", "vector", "vec", "dense"):
        if k in shapes and shapes[k]:
            return shapes[k][0]
    return 0


def synthesize_operands(binding: Dict[str, Any], rng_seed: int = 0
                        ) -> Optional[Dict[str, Any]]:
    """Concrete, *semantically valid* stand-ins for traced binding atoms.

    Trace-mode tuning happens at lowering time, when the real operands are
    tracers.  We only know shapes/dtypes, so representative operands are
    synthesized; index-carrying What-names (``colidx``/``rowstr``/``idx``…)
    get valid index structure so candidate kernels exercise realistic
    gather/scatter paths.  Returns None if any atom's shape is unknown.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(rng_seed)
    shapes: Dict[str, Tuple[int, ...]] = {}
    dtypes: Dict[str, Any] = {}
    scalars: Dict[str, Any] = {}
    for k, v in binding.items():
        if isinstance(v, (int, float, bool)):
            scalars[k] = v
            continue
        shape = _shape_of(v)
        if shape is None:
            return None
        shapes[k] = shape
        aval = getattr(v, "aval", v)
        dtypes[k] = np.dtype(getattr(aval, "dtype", np.float32))

    rows = int(scalars.get("rows", 0))
    nnz = int(scalars.get("nnz", 0))
    experts = int(scalars.get("experts", 0))
    cols = _infer_cols(binding, shapes)

    out: Dict[str, Any] = dict(scalars)
    for k, shape in shapes.items():
        dt = dtypes[k]
        if k in ("colidx", "col_ind", "col"):
            hi = max(1, cols or (shape[-1] if shape else 1))
            arr = rng.integers(0, hi, shape)
        elif k in ("rowstr", "row_ptr"):
            # uniform monotone pointer: rows+1 entries from 0..nnz
            n = shape[0]
            arr = np.round(np.linspace(0, nnz, n)).astype(np.int64)
        elif k == "rowidx":
            arr = np.sort(rng.integers(0, max(1, rows), shape))
        elif k == "idx":
            arr = rng.integers(0, max(1, experts), shape)
        elif k == "perm":
            n = shape[0]
            arr = rng.permutation(n)
        elif np.issubdtype(dt, np.integer):
            arr = np.zeros(shape)
        else:
            arr = rng.standard_normal(shape)
        out[k] = jnp.asarray(arr.astype(dt))
    return out


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Decision:
    harness: str
    source: str     # 'memory' | 'disk' | 'measured' | 'fallback'
    sig: str


class Autotuner:
    """Signature-keyed backend selection with an exploration budget.

    ``select`` is the single entry point; it is deterministic once the
    cache holds a winner for the signature (zero re-timing), which is what
    lets trace-mode pin the winner into the rewrite and lets a fresh
    process warm-start from disk.
    """

    def __init__(self, registry_fingerprint: str = "",
                 cache: Optional[AutotuneCache] = None,
                 budget: Optional[int] = None,
                 reps: int = 2):
        self.registry_fingerprint = registry_fingerprint
        self._cache = cache
        self._cache_injected = cache is not None
        self.budget = budget
        self.reps = reps
        self.stats = TuneStats()
        self.last_decision: Optional[Decision] = None

    # -- cache plumbing ------------------------------------------------------

    @property
    def cache(self) -> AutotuneCache:
        """The persistent cache.  An explicitly injected cache is pinned;
        an auto-created one re-resolves if LILAC_AUTOTUNE_CACHE moved."""
        if self._cache_injected:
            return self._cache
        want = default_cache_path()
        if self._cache is None or (self._cache.path != want
                                   and _ENV_PATH in os.environ):
            self._cache = AutotuneCache(
                want, registry_fingerprint=self.registry_fingerprint)
        return self._cache

    def _budget(self) -> int:
        return self.budget if self.budget is not None else exploration_budget()

    # -- measurement ---------------------------------------------------------

    def _time_host(self, h, binding, ctx) -> float:
        """Steady-state eager timing: first call pays compile+marshal, the
        repetitions after it are what a solver loop would see."""
        import jax

        out = h(binding, ctx)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(1, self.reps)):
            t0 = time.perf_counter()
            out = h(binding, ctx)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    def _time_trace(self, h, ctx, operands) -> float:
        """Timed jax.jit candidate compile + steady-state run."""
        import jax

        static = {k: v for k, v in operands.items()
                  if isinstance(v, (int, float, bool))}
        arrays = {k: v for k, v in operands.items() if k not in static}

        def call(arrs):
            # through Harness.__call__ so BeforeFirstExecution setup runs,
            # same as the host-mode timing path
            return h({**static, **arrs}, ctx)

        f = jax.jit(call)
        out = f(arrays)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(1, self.reps)):
            t0 = time.perf_counter()
            out = f(arrays)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    @staticmethod
    def _marshal_cost(h, ctx) -> float:
        """Measured conversion-path seconds for a harness's declared
        marshal clauses (0.0 for marshal-free harnesses or caches that
        don't track costs).  Queried AFTER timing, when the warmup call has
        populated the data plane's edge-cost EWMAs."""
        clauses = getattr(h, "marshal", ()) or ()
        cache = getattr(ctx, "cache", None)
        if not clauses or cache is None:
            return 0.0
        est = getattr(cache, "estimate_marshal_seconds", None)
        if est is None:
            return 0.0
        try:
            return float(est(clauses))
        except Exception:
            return 0.0

    @staticmethod
    def _reuse(ctx) -> float:
        """Declared call frequency (calls per matrix change) from the data
        plane's MarshalPolicy; the amortization rate for repack cost."""
        policy = getattr(getattr(ctx, "cache", None), "policy", None)
        reuse = getattr(policy, "reuse", None)
        return float(reuse) if reuse else 100.0

    @staticmethod
    def amortized(timings: Dict[str, float], marshal_s: Dict[str, float],
                  reuse: float) -> Dict[str, float]:
        """Steady-state repack-amortized cost per candidate: kernel seconds
        plus the conversion cost spread over ``reuse`` calls."""
        return {n: t + marshal_s.get(n, 0.0) / max(reuse, 1.0)
                for n, t in timings.items()}

    def measure(self, cands: Sequence[Any], binding: Dict[str, Any],
                ctx, mode: str,
                default_name: Optional[str] = None
                ) -> Tuple[Optional[str], Dict[str, float], Dict[str, float]]:
        """Time up to budget candidates; returns (winner_name, kernel
        timings, marshal-path seconds).  The winner minimizes the
        repack-amortized cost, not raw kernel time."""
        import jax

        ranked = sorted(
            cands, key=lambda h: (h.name != default_name,))  # default first
        ranked = ranked[: max(0, self._budget())]
        operands = None
        if mode == "trace":
            concrete = all(
                not isinstance(v, jax.core.Tracer) and _shape_of(v) is not None
                for v in binding.values()
                if not isinstance(v, (int, float, bool)))
            operands = (dict(binding) if concrete
                        else synthesize_operands(binding))
            if operands is None:
                return None, {}, {}
        timings: Dict[str, float] = {}
        marshal_s: Dict[str, float] = {}
        for h in ranked:
            try:
                self.stats.timing_calls += 1
                if mode == "trace":
                    timings[h.name] = self._time_trace(h, ctx, operands)
                else:
                    timings[h.name] = self._time_host(h, binding, ctx)
                    marshal_s[h.name] = self._marshal_cost(h, ctx)
            except Exception:
                continue
        if not timings:
            return None, {}, {}
        amort = self.amortized(timings, marshal_s, self._reuse(ctx))
        return min(amort, key=amort.get), timings, marshal_s

    # -- selection -----------------------------------------------------------

    def select(self, comp: str, fmt: str, platform: str, mode: str,
               cands: Sequence[Any], binding: Dict[str, Any], ctx,
               default_name: Optional[str] = None):
        """Pick a harness from ``cands`` for this call signature.

        Returns the chosen Harness, or None to tell the registry to fall
        back to its per-platform default path.
        """
        if not cands:
            return None
        by_name = {h.name: h for h in cands}
        sig = signature_of(comp, fmt, platform, binding)
        any_marshal = any(getattr(h, "marshal", ()) for h in cands)

        if not autotune_disabled():
            disk_before = self.cache.stats.disk_hits
            rec = self.cache.get(sig, mode)
            if rec is not None and rec.get("harness") in by_name:
                # a migrated (schema-1, kernel-only) winner predates
                # marshal-aware selection: when a marshaling candidate is
                # in play the amortized argmin can differ, so re-measure
                # instead of serving a potentially stale winner
                if (rec.get("cost_model") == "kernel_only" and any_marshal
                        and not autotune_disabled() and self._budget() > 0):
                    self.stats.remeasures += 1
                else:
                    # the record stores the raw kernel + marshal
                    # measurements, so a DIFFERENT declared call frequency
                    # re-derives its winner arithmetically — zero re-timing
                    name = rec["harness"]
                    reuse = self._reuse(ctx)
                    timings = rec.get("timings") or {}
                    if (rec.get("cost_model") == "amortized" and timings
                            and rec.get("reuse") not in (None, reuse)):
                        amort = self.amortized(
                            {n: t for n, t in timings.items()
                             if n in by_name},
                            rec.get("marshal_s") or {}, reuse)
                        if amort:
                            name = min(amort, key=amort.get)
                    # the cache's own stats know whether this get had to
                    # read the file; mirror that classification here
                    src = ("disk" if self.cache.stats.disk_hits > disk_before
                           else "memory")
                    if src == "memory":
                        self.stats.memory_hits += 1
                    else:
                        self.stats.disk_hits += 1
                    self.last_decision = Decision(name, src, sig)
                    return by_name[name]

        if autotune_disabled() or self._budget() <= 0:
            self.stats.fallbacks += 1
            self.last_decision = Decision(default_name or cands[0].name,
                                          "fallback", sig)
            return None

        self.stats.misses += 1
        winner, timings, marshal_s = self.measure(
            cands, binding, ctx, mode, default_name=default_name)
        if winner is None:
            self.stats.fallbacks += 1
            self.last_decision = Decision(default_name or cands[0].name,
                                          "fallback", sig)
            return None
        reuse = self._reuse(ctx)
        amort = self.amortized(timings, marshal_s, reuse)
        record = {"harness": winner,
                  "best_s": timings[winner],
                  "timings": timings,
                  "marshal_s": marshal_s,
                  "reuse": reuse,
                  "amortized_s": amort,
                  "cost_model": "amortized",
                  "platform": platform,
                  "format": fmt}
        self.cache.put(sig, mode, record, persist=True)
        self.stats.stores += 1
        self.last_decision = Decision(winner, "measured", sig)
        return by_name[winner]

    def record_external(self, comp: str, fmt: str, platform: str, mode: str,
                        binding: Dict[str, Any],
                        timings: Dict[str, float],
                        marshal_s: Optional[Dict[str, float]] = None,
                        reuse: float = 100.0) -> str:
        """Seed the persistent cache from externally measured timings
        (e.g. a benchmark sweep acting as the tuner).  ``marshal_s`` (per
        candidate conversion-path seconds) makes the recorded winner the
        repack-amortized argmin at the declared ``reuse`` frequency; without
        it the record is kernel-only.  Returns the winner."""
        if not timings:
            raise ValueError("record_external needs at least one timing")
        sig = signature_of(comp, fmt, platform, binding)
        marshal_s = dict(marshal_s or {})
        amort = self.amortized(timings, marshal_s, reuse)
        winner = min(amort, key=amort.get)
        self.cache.put(sig, mode, {"harness": winner,
                                   "best_s": timings[winner],
                                   "timings": dict(timings),
                                   "marshal_s": marshal_s,
                                   "reuse": reuse,
                                   "amortized_s": amort,
                                   "cost_model": ("amortized" if marshal_s
                                                  else "kernel_only"),
                                   "platform": platform,
                                   "format": fmt}, persist=True)
        self.stats.stores += 1
        return winner

    # -- introspection -------------------------------------------------------

    def pinned(self) -> Dict[Tuple[str, str], str]:
        """(signature, mode) -> winning harness name, in-memory view."""
        out = {}
        for sig, modes in self.cache.entries.items():
            for mode, rec in modes.items():
                out[(sig, mode)] = rec.get("harness")
        return out
