"""Persistent, signature-keyed backend autotuning (paper §3.3 / Table 2).

The paper's central empirical claim is that no sparse backend wins
everywhere: the right harness depends on platform, format and input
structure.  SparseX answers this by tuning once per matrix and reusing the
decision; LiLAC inherits the idea at the harness-selection boundary.  This
module is the persistent half of that story:

* ``signature_of`` — buckets a harness-call binding into a stable key
  ``(computation, format, platform, shape-bucket, sparsity-bucket)``.
  Shapes are bucketed to powers of two and sparsity to decades so that
  "the same kind of problem" re-uses one tuning decision across runs,
  processes and slightly-different inputs.
* ``AutotuneCache`` — versioned on-disk JSON store
  (``~/.cache/lilac/autotune.json``, overridable via ``LILAC_AUTOTUNE_CACHE``)
  with warm-start load, atomic writes (tempfile + ``os.replace`` under an
  advisory ``flock``) and invalidation whenever the registered harness set
  or registry version changes.
* ``Autotuner`` — the selection policy.  On a cache miss it measures the
  top-``budget`` candidates (host mode: steady-state eager calls through
  the marshaling cache; trace mode: timed ``jax.jit`` compiles of each
  jit-safe candidate on operands synthesized from the traced avals), pins
  the winner, and persists it.  Under budget — or when measurement is
  impossible — it falls back to the per-platform default.

Winner selection is **repack-amortized** (since schema 2): for host-mode
candidates with declared marshal clauses, the measured steady-state kernel
time is combined with the data plane's measured conversion-path cost at
the declared call frequency (``MarshalPolicy.reuse`` — expected calls per
matrix change), so a backend with a blazing kernel but a ruinous repack
only wins when the repack actually amortizes.  Schema-1 cache files are
migrated on load: their kernel-only records stay valid for marshal-free
candidate sets and are re-measured (not silently trusted) whenever a
marshaling harness is in play — no stale winners.

Winner selection is also **schedule-swept** (schema 3): candidates whose
HARNESS blocks declare ``tune`` clauses contribute their whole
constraint-filtered variant family to the search, not just the default
schedule.  The cross-product is swept by *successive halving* — cheap
single-iteration elimination rounds shrink the pool until it fits the
existing exploration budget, and only the survivors get steady-state
timing — so a 40-variant space costs a handful of full measurements.  The
pinned decision is a ``(harness, schedule)`` pair; variants of one harness
share its marshaled format, so repack cost is measured once per harness.
Schema-2 records migrate as *priors*: their kernel-level winner ranks
first in the sweep, but the record is never served as-is when any live
candidate declares schedule variants — no stale winners, again.

Since schema 4 the sweep has a third dimension: at call sites with a
detected epilogue, every ``fuse epilogue`` candidate contributes BOTH its
fused (in-kernel) and unfused (``rewrite.apply_epilogue`` after the call)
realizations as variants, so fusion is pinned only where it measured
faster (``fused_epilogue_always_faster`` is false in practice).  Records
additionally expose per-candidate measured components (``variants``: every
surviving (schedule, fuse, seconds) triple per harness) — the inputs the
joint whole-program plan search (``repro.core.plan_search``) re-costs
without re-timing.  Schema-3 records migrate in place: served verbatim at
sites where the fuse dimension cannot change the answer (no epilogue, or
no fuse-capable candidate — cross-process zero re-timing preserved) and
demoted to sweep priors only where it can.

Environment knobs:

  LILAC_AUTOTUNE_CACHE         cache file path
                               (default ~/.cache/lilac/autotune.json)
  LILAC_AUTOTUNE_BUDGET        max candidates given steady-state timing
                               per signature (default 8)
  LILAC_AUTOTUNE_MAX_VARIANTS  cap on the swept variant pool per signature
                               (default 64; defaults survive the cap)
  LILAC_AUTOTUNE_DISABLE       "1" -> never measure or persist; defaults
                               only
"""
from __future__ import annotations

import dataclasses
import os
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jsonstore import JsonStore

SCHEMA_VERSION = 4
_ENV_PATH = "LILAC_AUTOTUNE_CACHE"
_ENV_BUDGET = "LILAC_AUTOTUNE_BUDGET"
_ENV_MAX_VARIANTS = "LILAC_AUTOTUNE_MAX_VARIANTS"
_ENV_DISABLE = "LILAC_AUTOTUNE_DISABLE"
_DEFAULT_BUDGET = 8
_DEFAULT_MAX_VARIANTS = 64


def default_cache_path() -> Path:
    env = os.environ.get(_ENV_PATH)
    if env:
        return Path(env)
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "lilac" / "autotune.json"


def autotune_disabled() -> bool:
    return os.environ.get(_ENV_DISABLE, "") == "1"


def exploration_budget() -> int:
    try:
        return int(os.environ.get(_ENV_BUDGET, _DEFAULT_BUDGET))
    except ValueError:
        return _DEFAULT_BUDGET


def variant_cap() -> int:
    """Cap on the swept (harness, schedule) pool per signature."""
    try:
        return int(os.environ.get(_ENV_MAX_VARIANTS, _DEFAULT_MAX_VARIANTS))
    except ValueError:
        return _DEFAULT_MAX_VARIANTS


def schedule_key(schedule: Optional[Dict[str, Any]]) -> str:
    """Canonical string form of a schedule variant ('default' for None/{})
    — JSON-record and report key for per-variant timings."""
    if not schedule:
        return "default"
    return ",".join(f"{k}={schedule[k]}" for k in sorted(schedule))


def variant_key(schedule: Optional[Dict[str, Any]],
                fuse: Optional[bool] = None) -> str:
    """Record key for a full (schedule, fuse) variant.  ``fuse=None``
    (no epilogue at the site, or a harness that can't fuse) keeps the
    historical ``schedule_key`` form, so schema-3 ``variant_s`` keys stay
    valid everywhere the fuse dimension doesn't exist."""
    k = schedule_key(schedule)
    if fuse is True:
        return k + "|fused"
    if fuse is False:
        return k + "|unfused"
    return k


# ---------------------------------------------------------------------------
# Signatures
# ---------------------------------------------------------------------------

def pow2_bucket(n: int) -> int:
    """Round a positive extent up to the next power of two (0 stays 0)."""
    n = int(n)
    if n <= 0:
        return 0
    return 1 << (n - 1).bit_length()


def sparsity_bucket(frac: float) -> str:
    """Decade bucket of a density fraction: 1e-4 -> 'd-4'; unknown -> 'd?'."""
    if not (frac > 0.0):
        return "d?"
    return f"d{int(np.floor(np.log10(min(frac, 1.0))))}"


def _shape_of(v: Any) -> Optional[Tuple[int, ...]]:
    shape = getattr(v, "shape", None)
    if shape is None:
        aval = getattr(v, "aval", None)
        shape = getattr(aval, "shape", None)
    if shape is None:
        return None
    return tuple(int(s) for s in shape)


def signature_of(comp: str, fmt: str, platform: str,
                 binding: Dict[str, Any],
                 epilogue: Optional[str] = None) -> str:
    """Stable string key for one harness call site.

    Works on concrete arrays and on tracers (shape/dtype only — no data is
    read), so trace-mode lowering and host-mode execution agree on the key.

    ``epilogue`` distinguishes fused-epilogue call sites (spmv+bias+relu)
    from the plain computation: the candidate cost structure differs (a
    fusing harness saves an output round-trip), so they tune separately.
    Plain call sites keep the historical key format.
    """
    dims: List[str] = []
    rows = nnz = cols = None
    for k in sorted(binding):
        v = binding[k]
        if isinstance(v, bool):
            dims.append(f"{k}={v}")
        elif isinstance(v, int):
            dims.append(f"{k}={pow2_bucket(v)}")
            if k == "rows":
                rows = v
            elif k == "nnz":
                nnz = v
        elif isinstance(v, float):
            continue
        else:
            shape = _shape_of(v)
            if shape is not None:
                dims.append(f"{k}={'x'.join(str(pow2_bucket(s)) for s in shape)}")
                if k in ("iv", "vector", "vec", "dense") and shape:
                    cols = shape[0]
    if rows and nnz and cols:
        sb = sparsity_bucket(nnz / float(rows * cols))
    else:
        sb = "d?"
    sig = "|".join([comp, fmt, platform, ",".join(dims), sb])
    if epilogue:
        sig += f"|ep:{epilogue}"
    return sig


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TuneStats:
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    timing_calls: int = 0      # candidate measurements performed
    stores: int = 0
    fallbacks: int = 0         # budget/measurability forced a default
    invalidations: int = 0     # on-disk entries dropped (version/fingerprint)
    migrations: int = 0        # schema-1/2 entries migrated to schema 3
    remeasures: int = 0        # stale records re-tuned (marshal/schedule)
    elimination_calls: int = 0  # cheap single-iteration sweep measurements
    save_errors: int = 0       # persistence failed (unwritable path)
    corrupt_recoveries: int = 0  # torn cache file quarantined, fresh start
    quarantine_skips: int = 0  # candidates/variants excluded by quarantine

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def reset(self):
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class AutotuneCache(JsonStore):
    """Versioned JSON store of tuning decisions (the
    :class:`repro.core.jsonstore.JsonStore` disk protocol with nested
    per-``(signature, mode)`` entries and schema-1/2 migration).

    Layout (schema 4)::

        {"schema": 4, "registry": "<fingerprint>",
         "entries": {"<sig>": {"<mode>": {
             "harness": ..., "best_s": ..., "timings": {...},
             "marshal_s": {...}, "reuse": 100.0, "amortized_s": {...},
             "cost_model": "amortized" | "kernel_only",
             "schedule": {...} | null, "schedules": {...},
             "fuse": true | false | null, "fuses": {...},
             "variant_s": {...}, "variants": {...},
             "schedule_swept": true, "fuse_swept": true}}}}

    ``timings`` are steady-state kernel seconds per harness (its best
    variant); ``marshal_s`` the measured conversion-path seconds per
    candidate; ``amortized_s`` their combination at the declared call
    frequency (``reuse``), which is what the winner minimizes.
    ``schedule`` is the winning harness's swept tune-parameter assignment
    (null for untuned winners), ``schedules`` each harness's best variant,
    ``fuse``/``fuses`` the analogous fused-epilogue decisions (null where
    the dimension doesn't exist), ``variant_s`` per-variant steady-state
    seconds (``{harness: {variant_key: s}}``) for the survivors of the
    successive-halving sweep, and ``variants`` the same survivors as
    structured ``{harness: [[schedule, fuse, seconds], ...]}`` triples —
    the per-candidate component table the joint plan search
    (``repro.core.plan_search``) consumes.

    Schema-1 files are migrated in place on load: records become
    ``cost_model: "kernel_only"`` (their winner predates marshal-aware
    selection) and are re-measured instead of served when a marshaling
    candidate is present.  Schema-2 records gain
    ``schedule_swept: false``: their kernel-level winner is kept as a
    *prior* (it ranks first in the next sweep) but the record is
    re-measured instead of served whenever a live candidate declares
    schedule variants.  Schema-3 records gain ``fuse_swept: false``: they
    are served verbatim wherever the fused-epilogue dimension can't change
    the answer and demote to sweep priors at epilogue sites with a
    fuse-capable candidate.

    Writes are atomic (tempfile in the same directory + ``os.replace``) and
    merge-on-save under an advisory lock, so concurrent tuners never
    corrupt the file and rarely lose each other's entries.
    """

    schema_version = SCHEMA_VERSION
    readable_schemas = (1, 2, 3)

    def __init__(self, path: Optional[os.PathLike] = None,
                 registry_fingerprint: str = ""):
        self.stats = TuneStats()   # before super(): _note_* hooks need it
        super().__init__(path, registry_fingerprint)

    # -- disk (JsonStore hooks) ----------------------------------------------

    def default_path(self) -> Path:
        return default_cache_path()

    def _note_invalidation(self):
        self.stats.invalidations += 1

    def _note_save_error(self):
        self.stats.save_errors += 1

    def _note_corrupt_recovery(self):
        self.stats.corrupt_recoveries += 1

    def _migrate(self, entries, schema):
        if schema == 1:
            entries = self._migrate_v1(entries)
        if schema <= 2:
            entries = self._migrate_v2(entries)
        return self._migrate_v3(entries)

    def _merge(self, base, incoming, overwrite):
        """Entries nest per signature then mode: merge at the mode level so
        concurrent tuners working different modes of one signature don't
        clobber each other."""
        for sig, modes in incoming.items():
            if not isinstance(modes, dict):
                continue
            slot = base.setdefault(sig, {})
            for m, rec in modes.items():
                if overwrite or m not in slot:
                    slot[m] = rec

    def _migrate_v1(self, entries: Dict[str, Dict[str, Any]]
                    ) -> Dict[str, Dict[str, Any]]:
        """Schema 1 -> 2: keep the measured kernel timings (they are still
        valid measurements) but mark records ``kernel_only`` so the tuner
        re-measures — instead of serving a potentially stale winner —
        whenever marshal-aware selection would change the answer."""
        out: Dict[str, Dict[str, Any]] = {}
        for sig, modes in entries.items():
            if not isinstance(modes, dict):
                continue
            new_modes = {}
            for mode, rec in modes.items():
                if not isinstance(rec, dict) or "harness" not in rec:
                    continue
                rec = dict(rec)
                rec.setdefault("cost_model", "kernel_only")
                rec.setdefault("marshal_s", {})
                rec.setdefault("amortized_s", dict(rec.get("timings", {})))
                # counted once per record, in _migrate_v3 (every legacy
                # record passes through it)
                new_modes[mode] = rec
            if new_modes:
                out[sig] = new_modes
        return out

    def _migrate_v2(self, entries: Dict[str, Dict[str, Any]]
                    ) -> Dict[str, Dict[str, Any]]:
        """Schema 2 -> 3: the measured (possibly marshal-amortized) winner
        is still a valid *kernel-level* decision, but it predates schedule
        sweeping — mark it unswept so the tuner uses it as a sweep prior
        and never serves it against a variant-declaring candidate set."""
        for modes in entries.values():
            if not isinstance(modes, dict):
                continue
            for rec in modes.values():
                if not isinstance(rec, dict) or "harness" not in rec:
                    continue
                if "schedule_swept" not in rec:
                    rec.setdefault("schedule", None)
                    rec.setdefault("schedules", {})
                    rec.setdefault("variant_s", {})
                    rec["schedule_swept"] = False
                    # counted once per record, in _migrate_v3
        return entries

    def _migrate_v3(self, entries: Dict[str, Dict[str, Any]]
                    ) -> Dict[str, Dict[str, Any]]:
        """Schema 3 -> 4: records predate the fused-epilogue variant
        dimension and the structured per-candidate ``variants`` table.
        Their winner stays fully valid wherever fusion isn't a choice (no
        epilogue at the site, or no fuse-capable candidate) — those are
        served with zero re-timing; at epilogue sites with a fuse-capable
        candidate the winner demotes to a sweep *prior* (ranked first)."""
        for modes in entries.values():
            if not isinstance(modes, dict):
                continue
            for rec in modes.values():
                if not isinstance(rec, dict) or "harness" not in rec:
                    continue
                if "fuse_swept" not in rec:
                    rec.setdefault("fuse", None)
                    rec.setdefault("fuses", {})
                    rec.setdefault("variants", {})
                    rec["fuse_swept"] = False
                    self.stats.migrations += 1
        return entries

    # -- lookup --------------------------------------------------------------

    def get(self, sig: str, mode: str) -> Optional[Dict[str, Any]]:
        rec = self.entries.get(sig, {}).get(mode)
        if rec is not None:
            self.stats.memory_hits += 1
            return rec
        if not self.loaded:
            self.load()
            rec = self.entries.get(sig, {}).get(mode)
            if rec is not None:
                self.stats.disk_hits += 1
                return rec
        self.stats.misses += 1
        return None

    def put(self, sig: str, mode: str, record: Dict[str, Any],
            persist: bool = True):
        self.entries.setdefault(sig, {})[mode] = record
        self.stats.stores += 1
        if persist:
            self.save()


# ---------------------------------------------------------------------------
# Operand synthesis (trace-mode measurement)
# ---------------------------------------------------------------------------

def _infer_cols(binding: Dict[str, Any], shapes: Dict[str, Tuple[int, ...]]) -> int:
    for k in ("iv", "vector", "vec", "dense"):
        if k in shapes and shapes[k]:
            return shapes[k][0]
    return 0


def synthesize_operands(binding: Dict[str, Any], rng_seed: int = 0
                        ) -> Optional[Dict[str, Any]]:
    """Concrete, *semantically valid* stand-ins for traced binding atoms.

    Trace-mode tuning happens at lowering time, when the real operands are
    tracers.  We only know shapes/dtypes, so representative operands are
    synthesized; index-carrying What-names (``colidx``/``rowstr``/``idx``…)
    get valid index structure so candidate kernels exercise realistic
    gather/scatter paths.  Returns None if any atom's shape is unknown.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(rng_seed)
    shapes: Dict[str, Tuple[int, ...]] = {}
    dtypes: Dict[str, Any] = {}
    scalars: Dict[str, Any] = {}
    for k, v in binding.items():
        if isinstance(v, (int, float, bool)):
            scalars[k] = v
            continue
        shape = _shape_of(v)
        if shape is None:
            return None
        shapes[k] = shape
        aval = getattr(v, "aval", v)
        dtypes[k] = np.dtype(getattr(aval, "dtype", np.float32))

    rows = int(scalars.get("rows", 0))
    nnz = int(scalars.get("nnz", 0))
    experts = int(scalars.get("experts", 0))
    cols = _infer_cols(binding, shapes)

    out: Dict[str, Any] = dict(scalars)
    for k, shape in shapes.items():
        dt = dtypes[k]
        if k in ("colidx", "col_ind", "col"):
            hi = max(1, cols or (shape[-1] if shape else 1))
            arr = rng.integers(0, hi, shape)
        elif k in ("rowstr", "row_ptr"):
            # uniform monotone pointer: rows+1 entries from 0..nnz
            n = shape[0]
            arr = np.round(np.linspace(0, nnz, n)).astype(np.int64)
        elif k == "rowidx":
            arr = np.sort(rng.integers(0, max(1, rows), shape))
        elif k == "idx":
            arr = rng.integers(0, max(1, experts), shape)
        elif k == "perm":
            n = shape[0]
            arr = rng.permutation(n)
        elif np.issubdtype(dt, np.integer):
            arr = np.zeros(shape)
        else:
            arr = rng.standard_normal(shape)
        out[k] = jnp.asarray(arr.astype(dt))
    return out


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------

#: decision sources that are real tuning outcomes (measured now or served
#: from the cache) — the only ones the pass manager pins into a rewrite or
#: serializes into the persistent plan cache.
DEFINITIVE_SOURCES = ("memory", "disk", "measured")


@dataclasses.dataclass
class Decision:
    harness: str
    source: str     # 'memory' | 'disk' | 'measured' | 'fallback'
    sig: str
    # winning schedule variant (tune-param assignment); None when the
    # winner has no declared tune space
    schedule: Optional[Dict[str, Any]] = None
    # winning fused-epilogue realization: True/False where the dimension
    # was swept, None where it doesn't exist (no epilogue / can't fuse)
    fuse: Optional[bool] = None

    @property
    def definitive(self) -> bool:
        """True when this decision may be pinned/persisted: a fallback
        (can't-measure, budget 0, tracer-only first call) must stay
        re-tunable on later concrete calls."""
        return self.source in DEFINITIVE_SOURCES

    def as_pin(self) -> Tuple[str, Optional[Dict[str, Any]], Optional[bool]]:
        """The JSON-serializable ``(harness, schedule, fuse)`` triple the
        pass manager stores in ``CompiledEntry.pins`` and the plan cache."""
        return (self.harness, self.schedule, self.fuse)


class Autotuner:
    """Signature-keyed backend selection with an exploration budget.

    ``select`` is the single entry point; it is deterministic once the
    cache holds a winner for the signature (zero re-timing), which is what
    lets trace-mode pin the winner into the rewrite and lets a fresh
    process warm-start from disk.
    """

    def __init__(self, registry_fingerprint: str = "",
                 cache: Optional[AutotuneCache] = None,
                 budget: Optional[int] = None,
                 reps: int = 2,
                 max_variants: Optional[int] = None):
        self.registry_fingerprint = registry_fingerprint
        self._cache = cache
        self._cache_injected = cache is not None
        self.budget = budget
        self.reps = reps
        self.max_variants = max_variants
        self.stats = TuneStats()
        self.last_decision: Optional[Decision] = None
        #: injectable QuarantineStore; None -> the process-shared one
        self.quarantine = None

    # -- cache plumbing ------------------------------------------------------

    @property
    def cache(self) -> AutotuneCache:
        """The persistent cache.  An explicitly injected cache is pinned;
        an auto-created one re-resolves if LILAC_AUTOTUNE_CACHE moved."""
        if self._cache_injected:
            return self._cache
        want = default_cache_path()
        if self._cache is None or (self._cache.path != want
                                   and _ENV_PATH in os.environ):
            self._cache = AutotuneCache(
                want, registry_fingerprint=self.registry_fingerprint)
        return self._cache

    def _quarantine_store(self):
        if self.quarantine is not None:
            return self.quarantine
        from repro.core.resilience import shared_quarantine
        return shared_quarantine()

    def _budget(self) -> int:
        return self.budget if self.budget is not None else exploration_budget()

    def _max_variants(self) -> int:
        return (self.max_variants if self.max_variants is not None
                else variant_cap())

    # -- measurement ---------------------------------------------------------

    @staticmethod
    def _as_runtime(h, binding, ctx):
        """One candidate call exactly as the rewrite will run it: for a
        match with a detected epilogue, unfused realizations pay the
        bias+activation after the call (rewrite.apply_epilogue) while
        fused ones pay it in-kernel — timing both the same way would bias
        selection.  ``ctx.fuse`` selects the realization for fuse-capable
        harnesses (None = the declared default, i.e. fused), mirroring
        ``rewrite._eval_anchor``."""
        from repro.core.rewrite import apply_epilogue, effective_fuse

        ep = getattr(ctx, "epilogue", None)
        fused = effective_fuse(h, ctx)
        if ep is not None and not fused and getattr(h, "fuse_epilogue", False):
            # unfused realization of a fuse-capable harness: hide the
            # epilogue from the body, pay it at the jnp level below
            ctx.epilogue = None
            try:
                out = h(binding, ctx)
            finally:
                ctx.epilogue = ep
        else:
            out = h(binding, ctx)
        if ep is not None and not fused:
            out = apply_epilogue(out, binding.get("bias"), ep)
        return out

    def _time_host(self, h, binding, ctx, reps: Optional[int] = None) -> float:
        """Steady-state eager timing: first call pays compile+marshal, the
        repetitions after it are what a solver loop would see."""
        import jax

        out = self._as_runtime(h, binding, ctx)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(1, reps if reps is not None else self.reps)):
            t0 = time.perf_counter()
            out = self._as_runtime(h, binding, ctx)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    def _time_trace(self, h, ctx, operands,
                    reps: Optional[int] = None) -> float:
        """Timed jax.jit candidate compile + steady-state run."""
        import jax

        static = {k: v for k, v in operands.items()
                  if isinstance(v, (int, float, bool))}
        arrays = {k: v for k, v in operands.items() if k not in static}

        def call(arrs):
            # through Harness.__call__ so BeforeFirstExecution setup runs,
            # same as the host-mode timing path (incl. the runtime epilogue
            # for non-fusing candidates)
            return self._as_runtime(h, {**static, **arrs}, ctx)

        f = jax.jit(call)
        out = f(arrays)
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(max(1, reps if reps is not None else self.reps)):
            t0 = time.perf_counter()
            out = f(arrays)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    def _time_variant(self, h, binding, ctx, mode, operands,
                      schedule: Optional[Dict[str, Any]],
                      reps: int) -> Optional[float]:
        """Time one (harness, schedule) variant; None on failure (a variant
        whose parameters are invalid for this problem — tile not dividing a
        dimension, VMEM overflow — is eliminated, not fatal)."""
        prev = getattr(ctx, "schedule", None)
        if hasattr(ctx, "schedule"):
            ctx.schedule = schedule
        try:
            from repro.core import faults
            if faults.ACTIVE is not None:
                faults.fail("tune_raise", h.name)
            if mode == "trace":
                return self._time_trace(h, ctx, operands, reps=reps)
            return self._time_host(h, binding, ctx, reps=reps)
        except Exception:
            return None
        finally:
            if hasattr(ctx, "schedule"):
                ctx.schedule = prev

    @staticmethod
    def _marshal_cost(h, ctx) -> float:
        """Measured conversion-path seconds for a harness's declared
        marshal clauses (0.0 for marshal-free harnesses or caches that
        don't track costs).  Queried AFTER timing, when the warmup call has
        populated the data plane's edge-cost EWMAs."""
        clauses = getattr(h, "marshal", ()) or ()
        cache = getattr(ctx, "cache", None)
        if not clauses or cache is None:
            return 0.0
        est = getattr(cache, "estimate_marshal_seconds", None)
        if est is None:
            return 0.0
        try:
            return float(est(clauses))
        except Exception:
            return 0.0

    @staticmethod
    def _reuse(ctx) -> float:
        """Declared call frequency (calls per matrix change) from the data
        plane's MarshalPolicy; the amortization rate for repack cost."""
        policy = getattr(getattr(ctx, "cache", None), "policy", None)
        reuse = getattr(policy, "reuse", None)
        return float(reuse) if reuse else 100.0

    @staticmethod
    def amortized(timings: Dict[str, float], marshal_s: Dict[str, float],
                  reuse: float) -> Dict[str, float]:
        """Steady-state repack-amortized cost per candidate: kernel seconds
        plus the conversion cost spread over ``reuse`` calls."""
        return {n: t + marshal_s.get(n, 0.0) / max(reuse, 1.0)
                for n, t in timings.items()}

    def _variant_pool(self, ranked: Sequence[Any],
                      epilogue: Optional[str] = None
                      ) -> List[Tuple[Any, Optional[Dict[str, Any]],
                                      Optional[bool]]]:
        """The sweep pool: every candidate contributes its schedule family
        (or a single ``None`` entry when untuned) crossed with its fusion
        realizations — at an epilogue site a ``fuse epilogue`` harness
        enters both fused and unfused (``fuse=None`` elsewhere) — capped at
        ``max_variants``.  Default variants (default schedule, fused)
        always survive the cap; the remainder fills round-robin so no
        harness monopolizes the budget."""
        q = self._quarantine_store()
        families = []
        for h in ranked:
            scheds = list(getattr(h, "schedules", ()) or ()) or [None]
            fuses = ([True, False]
                     if epilogue is not None
                     and getattr(h, "fuse_epilogue", False) else [None])
            fam = [(s, f) for s in scheds for f in fuses]
            if q is not None:
                comp = getattr(h, "implements", "")
                kept = [(s, f) for s, f in fam
                        if not q.is_quarantined(comp, h.name,
                                                variant_key(s, f))]
                self.stats.quarantine_skips += len(fam) - len(kept)
                if not kept:
                    continue
                fam = kept
            families.append((h, fam))
        cap = max(len(families), self._max_variants())
        total = sum(len(f) for _, f in families)
        if total <= cap:
            return [(h, s, f) for h, fam in families for s, f in fam]
        pool = [(h,) + fam[0] for h, fam in families]
        depth = 1
        while len(pool) < cap:
            added = False
            for h, fam in families:
                if depth < len(fam) and len(pool) < cap:
                    pool.append((h,) + fam[depth])
                    added = True
            if not added:
                break
            depth += 1
        return pool

    def _time_pool(self, h, binding, ctx, mode, operands,
                   schedule: Optional[Dict[str, Any]],
                   fuse: Optional[bool], reps: int) -> Optional[float]:
        """Time one (harness, schedule, fuse) pool entry.  The fusion
        choice travels on ``ctx.fuse`` (set/restored here) so
        ``_time_variant``'s signature — which external riggings patch —
        stays (harness, binding, ctx, mode, operands, schedule, reps)."""
        prev = getattr(ctx, "fuse", None)
        if hasattr(ctx, "fuse"):
            ctx.fuse = fuse
        try:
            return self._time_variant(h, binding, ctx, mode, operands,
                                      schedule, reps)
        finally:
            if hasattr(ctx, "fuse"):
                ctx.fuse = prev

    def _sweep(self, pool, binding, ctx, mode, operands
               ) -> Dict[Tuple[str, str],
                         Tuple[Any, Optional[Dict], Optional[bool], float]]:
        """Successive halving over the variant pool: cheap single-iteration
        elimination rounds shrink the pool to the steady-state budget, then
        the survivors are timed properly.  Returns
        ``(harness_name, variant_key) -> (harness, schedule, fuse,
        seconds)`` for the survivors."""
        budget = max(1, self._budget())
        survivors = list(pool)
        while len(survivors) > budget:
            scored = []
            for h, sched, fuse in survivors:
                self.stats.elimination_calls += 1
                t = self._time_pool(h, binding, ctx, mode, operands,
                                    sched, fuse, reps=1)
                if t is not None:
                    scored.append((t, h, sched, fuse))
            if not scored:
                return {}
            scored.sort(key=lambda x: x[0])
            keep = max(budget, (len(scored) + 1) // 2)
            if keep >= len(scored):
                survivors = [(h, s, f) for _, h, s, f in scored]
                break
            survivors = [(h, s, f) for _, h, s, f in scored[:keep]]
        out: Dict[Tuple[str, str],
                  Tuple[Any, Optional[Dict], Optional[bool], float]] = {}
        for h, sched, fuse in survivors:
            self.stats.timing_calls += 1
            t = self._time_pool(h, binding, ctx, mode, operands,
                                sched, fuse, reps=self.reps)
            if t is not None:
                out[(h.name, variant_key(sched, fuse))] = (h, sched, fuse, t)
        return out

    def measure(self, cands: Sequence[Any], binding: Dict[str, Any],
                ctx, mode: str,
                default_name: Optional[str] = None,
                prior_name: Optional[str] = None,
                ) -> Tuple[Optional[str], Dict[str, float],
                           Dict[str, float], Dict[str, Optional[Dict]],
                           Dict[str, Dict[str, float]],
                           Dict[str, Optional[bool]],
                           Dict[str, List[Tuple[Optional[Dict],
                                                Optional[bool], float]]]]:
        """Sweep the (harness, schedule, fuse) cross-product under the
        budget; returns (winner_name, per-harness best kernel timings,
        marshal-path seconds, per-harness best schedule, per-variant
        seconds, per-harness best fuse, per-harness surviving
        (schedule, fuse, seconds) triples).  The winner minimizes the
        repack-amortized cost of its best variant, not raw kernel time.
        ``prior_name`` (a migrated kernel-level winner) outranks even the
        platform default in sweep order, so budget truncation keeps the
        prior in play."""
        import jax

        ranked = sorted(
            cands, key=lambda h: (h.name != prior_name,
                                  h.name != default_name))
        ranked = ranked[: max(0, self._budget())]
        operands = None
        if mode == "trace":
            concrete = all(
                not isinstance(v, jax.core.Tracer) and _shape_of(v) is not None
                for v in binding.values()
                if not isinstance(v, (int, float, bool)))
            operands = (dict(binding) if concrete
                        else synthesize_operands(binding))
            if operands is None:
                return None, {}, {}, {}, {}, {}, {}
        pool = self._variant_pool(ranked, getattr(ctx, "epilogue", None))
        if len(pool) <= max(1, self._budget()):
            # no sweep needed: steady-state time everything directly
            measured = {}
            for h, sched, fuse in pool:
                self.stats.timing_calls += 1
                t = self._time_pool(h, binding, ctx, mode, operands,
                                    sched, fuse, reps=self.reps)
                if t is not None:
                    measured[(h.name, variant_key(sched, fuse))] = (
                        h, sched, fuse, t)
        else:
            measured = self._sweep(pool, binding, ctx, mode, operands)
        if not measured:
            return None, {}, {}, {}, {}, {}, {}
        timings: Dict[str, float] = {}
        schedules: Dict[str, Optional[Dict]] = {}
        fuses: Dict[str, Optional[bool]] = {}
        variant_s: Dict[str, Dict[str, float]] = {}
        variants: Dict[str, List[Tuple[Optional[Dict],
                                       Optional[bool], float]]] = {}
        marshal_s: Dict[str, float] = {}
        for (name, vkey), (h, sched, fuse, t) in measured.items():
            variant_s.setdefault(name, {})[vkey] = t
            variants.setdefault(name, []).append((sched, fuse, t))
            if name not in timings or t < timings[name]:
                timings[name] = t
                schedules[name] = sched
                fuses[name] = fuse
        if mode != "trace":
            by_name = {h.name: h for h, _, _ in pool}
            for name in timings:
                marshal_s[name] = self._marshal_cost(by_name[name], ctx)
        amort = self.amortized(timings, marshal_s, self._reuse(ctx))
        winner = min(amort, key=amort.get)
        return winner, timings, marshal_s, schedules, variant_s, fuses, variants

    # -- selection -----------------------------------------------------------

    def select(self, comp: str, fmt: str, platform: str, mode: str,
               cands: Sequence[Any], binding: Dict[str, Any], ctx,
               default_name: Optional[str] = None):
        """Pick a harness from ``cands`` for this call signature.

        Returns the chosen Harness, or None to tell the registry to fall
        back to its per-platform default path.
        """
        if not cands:
            return None
        q = self._quarantine_store()
        if q is not None:
            live = [h for h in cands if not q.is_quarantined(comp, h.name)]
            # all-quarantined keeps the full set: an answer is still owed,
            # and call-time containment is the real enforcement boundary
            if live and len(live) < len(cands):
                self.stats.quarantine_skips += len(cands) - len(live)
                cands = live
        by_name = {h.name: h for h in cands}
        sig = signature_of(comp, fmt, platform, binding,
                           epilogue=getattr(ctx, "epilogue", None))
        any_marshal = any(getattr(h, "marshal", ()) for h in cands)
        any_schedules = any(getattr(h, "schedules", ()) for h in cands)
        # the fused-epilogue dimension exists only at epilogue call sites
        # with a fuse-capable candidate — elsewhere pre-schema-4 records
        # stay servable verbatim (zero re-timing)
        fuse_dim = (getattr(ctx, "epilogue", None) is not None
                    and any(getattr(h, "fuse_epilogue", False)
                            for h in cands))
        prior_name = None

        if not autotune_disabled():
            disk_before = self.cache.stats.disk_hits
            rec = self.cache.get(sig, mode)
            if rec is not None and rec.get("harness") in by_name:
                # a migrated (schema-1, kernel-only) winner predates
                # marshal-aware selection: when a marshaling candidate is
                # in play the amortized argmin can differ, so re-measure
                # instead of serving a potentially stale winner
                stale = (rec.get("cost_model") == "kernel_only"
                         and any_marshal)
                # likewise a schema-2 (unswept) record against a candidate
                # set with declared schedule variants: the per-variant
                # argmin can differ, so the kernel-level winner demotes to
                # a sweep *prior* rather than being served
                stale = stale or (any_schedules
                                  and not rec.get("schedule_swept"))
                # a schema-3 (fuse-unswept) record at a site where the
                # fused-vs-unfused choice exists: the per-variant argmin
                # can differ, so demote to a sweep prior
                stale = stale or (fuse_dim and not rec.get("fuse_swept"))
                # a pinned schedule that no longer exists in the winner's
                # declared variant family (tune space changed) is stale too
                if not stale and rec.get("schedule") is not None:
                    fam = getattr(by_name[rec["harness"]], "schedules", ())
                    stale = rec["schedule"] not in fam
                # a quarantined (winner, variant): the record predates the
                # incident, so its measurement no longer speaks for the
                # candidate — demote to prior and re-measure (the sweep
                # pool filters the quarantined variant out)
                if not stale and q is not None and q.is_quarantined(
                        comp, rec["harness"],
                        variant_key(rec.get("schedule"), rec.get("fuse"))):
                    stale = True
                name = schedule = fuse = None
                if not stale:
                    # the record stores the raw kernel + marshal
                    # measurements, so a DIFFERENT declared call frequency
                    # re-derives its winner arithmetically — zero re-timing
                    name = rec["harness"]
                    reuse = self._reuse(ctx)
                    timings = rec.get("timings") or {}
                    if (rec.get("cost_model") == "amortized" and timings
                            and rec.get("reuse") not in (None, reuse)):
                        amort = self.amortized(
                            {n: t for n, t in timings.items()
                             if n in by_name},
                            rec.get("marshal_s") or {}, reuse)
                        if amort:
                            name = min(amort, key=amort.get)
                    schedule = (rec.get("schedule") if name == rec["harness"]
                                else (rec.get("schedules") or {}).get(name))
                    fuse = (rec.get("fuse") if name == rec["harness"]
                            else (rec.get("fuses") or {}).get(name))
                    # the same family check as above, but for the
                    # re-derived winner: a stored schedule from a since-
                    # changed tune space must never be pinned
                    if schedule is not None and schedule not in getattr(
                            by_name[name], "schedules", ()):
                        stale = True
                if stale and self._budget() > 0:
                    self.stats.remeasures += 1
                    prior_name = rec["harness"]
                elif not stale:
                    # the cache's own stats know whether this get had to
                    # read the file; mirror that classification here
                    src = ("disk" if self.cache.stats.disk_hits > disk_before
                           else "memory")
                    if src == "memory":
                        self.stats.memory_hits += 1
                    else:
                        self.stats.disk_hits += 1
                    if hasattr(ctx, "schedule"):
                        ctx.schedule = schedule
                    if hasattr(ctx, "fuse"):
                        ctx.fuse = fuse
                    self.last_decision = Decision(name, src, sig,
                                                  schedule=schedule,
                                                  fuse=fuse)
                    return by_name[name]

        if autotune_disabled() or self._budget() <= 0:
            self.stats.fallbacks += 1
            self.last_decision = Decision(default_name or cands[0].name,
                                          "fallback", sig)
            return None

        self.stats.misses += 1
        (winner, timings, marshal_s, schedules, variant_s, fuses,
         variants) = self.measure(
            cands, binding, ctx, mode, default_name=default_name,
            prior_name=prior_name)
        if winner is None:
            self.stats.fallbacks += 1
            self.last_decision = Decision(default_name or cands[0].name,
                                          "fallback", sig)
            return None
        reuse = self._reuse(ctx)
        amort = self.amortized(timings, marshal_s, reuse)
        win_schedule = schedules.get(winner)
        win_fuse = fuses.get(winner)
        record = {"harness": winner,
                  "best_s": timings[winner],
                  "timings": timings,
                  "marshal_s": marshal_s,
                  "reuse": reuse,
                  "amortized_s": amort,
                  "cost_model": "amortized",
                  "schedule": win_schedule,
                  "schedules": {n: s for n, s in schedules.items()
                                if s is not None},
                  "fuse": win_fuse,
                  "fuses": {n: f for n, f in fuses.items()
                            if f is not None},
                  "variant_s": variant_s,
                  "variants": {n: [[s, f, t] for s, f, t in vs]
                               for n, vs in variants.items()},
                  "schedule_swept": True,
                  "fuse_swept": True,
                  "platform": platform,
                  "format": fmt}
        self.cache.put(sig, mode, record, persist=True)
        self.stats.stores += 1
        if hasattr(ctx, "schedule"):
            ctx.schedule = win_schedule
        if hasattr(ctx, "fuse"):
            ctx.fuse = win_fuse
        self.last_decision = Decision(winner, "measured", sig,
                                      schedule=win_schedule, fuse=win_fuse)
        return by_name[winner]

    def record_external(self, comp: str, fmt: str, platform: str, mode: str,
                        binding: Dict[str, Any],
                        timings: Dict[str, float],
                        marshal_s: Optional[Dict[str, float]] = None,
                        reuse: float = 100.0,
                        schedules: Optional[Dict[str, Dict]] = None,
                        variant_s: Optional[Dict[str, Dict[str, float]]] = None,
                        epilogue: Optional[str] = None,
                        fuses: Optional[Dict[str, Optional[bool]]] = None,
                        ) -> str:
        """Seed the persistent cache from externally measured timings
        (e.g. a benchmark sweep acting as the tuner).  ``marshal_s`` (per
        candidate conversion-path seconds) makes the recorded winner the
        repack-amortized argmin at the declared ``reuse`` frequency; without
        it the record is kernel-only.  ``schedules`` (per-harness best
        variant) and ``variant_s`` (per-variant seconds) mark the record
        schedule-swept; without them it is a kernel-level prior that gets
        re-swept when a variant-declaring candidate appears.  ``fuses``
        (per-harness best fused-epilogue realization) likewise marks the
        record fuse-swept.  Returns the winner."""
        if not timings:
            raise ValueError("record_external needs at least one timing")
        sig = signature_of(comp, fmt, platform, binding, epilogue=epilogue)
        marshal_s = dict(marshal_s or {})
        amort = self.amortized(timings, marshal_s, reuse)
        winner = min(amort, key=amort.get)
        swept = schedules is not None or variant_s is not None
        schedules = dict(schedules or {})
        fuse_swept = fuses is not None or epilogue is None
        fuses = dict(fuses or {})
        self.cache.put(sig, mode, {"harness": winner,
                                   "best_s": timings[winner],
                                   "timings": dict(timings),
                                   "marshal_s": marshal_s,
                                   "reuse": reuse,
                                   "amortized_s": amort,
                                   "cost_model": ("amortized" if marshal_s
                                                  else "kernel_only"),
                                   "schedule": schedules.get(winner),
                                   "schedules": schedules,
                                   "fuse": fuses.get(winner),
                                   "fuses": {n: f for n, f in fuses.items()
                                             if f is not None},
                                   "variant_s": dict(variant_s or {}),
                                   "variants": {},
                                   "schedule_swept": swept,
                                   "fuse_swept": fuse_swept,
                                   "platform": platform,
                                   "format": fmt}, persist=True)
        self.stats.stores += 1
        return winner

    # -- introspection -------------------------------------------------------

    def pinned(self) -> Dict[Tuple[str, str], str]:
        """(signature, mode) -> winning harness name, in-memory view."""
        out = {}
        for sig, modes in self.cache.entries.items():
            for mode, rec in modes.items():
                out[(sig, mode)] = rec.get("harness")
        return out
