"""Deterministic, site-addressable fault injection (the chaos harness).

Every containment path in LiLAC — harness quarantine, reference fallback,
torn-cache recovery, poisoned-request eviction — exists to keep an
accelerated program *never worse* than the un-rewritten one.  This module
is how those paths get exercised on demand: injection points threaded
through kernel calls, marshal repacks, tune probes, bake, JsonStore I/O
and serve decode steps fire according to a seed-driven plan, so a chaos
run is exactly reproducible and a CI gate can rotate seeds.

Fault classes (the ``kind`` namespace)::

    kernel_raise      Harness.__call__ raises before the body runs
    nan_output        a concrete harness output is poisoned with NaNs
    marshal_raise     a data-plane repack / conversion raises
    tune_raise        an autotune candidate measurement raises
    bake_raise        plan baking raises (falls back to the interpreter)
    cache_torn_write  a JsonStore save leaves a truncated file on disk
    decode_raise      a serving decode step raises (poisons one slot)
    decode_nan        one row of the decode logits becomes NaN
    replica_crash     a serving replica dies mid-step (front-door failover)
    shadow_diverge    a shadow comparison is forced to report divergence

Spec grammar (``LILAC_FAULTS``): comma-separated rules, each
``kind[:site[:prob]]``.  ``site`` is an ``fnmatch`` pattern matched
against the injection point's name (a harness name like ``pallas.ell``,
a repack name, a cache file stem like ``autotune``, or ``decode``);
omitted or ``*`` matches every site.  ``prob`` (default 1.0) is the
per-attempt firing probability, decided by a stable hash of
``(seed, kind, site, attempt#)`` — no RNG state, so two processes with
the same plan and call sequence inject identically.

    LILAC_FAULTS="kernel_raise:pallas.ell:0.5,nan_output:*,cache_torn_write"
    LILAC_FAULTS_SEED=7

Programmatic use (tests) is a context manager::

    from repro.core import faults
    with faults.inject("kernel_raise:jnp.segment", seed=3) as plan:
        fast(*args)
    assert plan.fired          # [(kind, site, attempt#), ...]

When no plan is active every injection point is a module-global ``None``
check — the steady-state dispatch path stays measurably free of chaos
machinery (the ``containment_overhead_leq_2pct`` benchmark gate).
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import hashlib
import os
from typing import Dict, List, Optional, Sequence, Tuple

_ENV_SPEC = "LILAC_FAULTS"
_ENV_SEED = "LILAC_FAULTS_SEED"

#: every kind `parse_spec` accepts — a typo'd class is an error, not a
#: silently dead rule
KINDS = ("kernel_raise", "nan_output", "marshal_raise", "tune_raise",
         "bake_raise", "cache_torn_write", "decode_raise", "decode_nan",
         "replica_crash", "shadow_diverge")


class FaultSpecError(ValueError):
    """Malformed ``LILAC_FAULTS`` rule (unknown kind / bad probability)."""


class InjectedFault(RuntimeError):
    """The exception raised by firing ``*_raise`` injection points.

    ``slot`` is meaningful only for serving decode faults: the batch slot
    the fault is attributed to, so the engine can evict exactly the
    poisoned request.
    """

    def __init__(self, kind: str, site: str, slot: Optional[int] = None):
        super().__init__(f"injected fault {kind} at {site!r}"
                         + (f" (slot {slot})" if slot is not None else ""))
        self.kind = kind
        self.site = site
        self.slot = slot


@dataclasses.dataclass(frozen=True)
class FaultRule:
    kind: str
    site: str = "*"           # fnmatch pattern over injection-point names
    prob: float = 1.0


def parse_spec(spec: str) -> List[FaultRule]:
    """Parse a ``LILAC_FAULTS`` string into rules (see module docstring)."""
    rules: List[FaultRule] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        kind = bits[0].strip()
        if kind not in KINDS:
            raise FaultSpecError(
                f"unknown fault kind {kind!r} (valid: {', '.join(KINDS)})")
        site = bits[1].strip() if len(bits) > 1 and bits[1].strip() else "*"
        prob = 1.0
        if len(bits) > 2 and bits[2].strip():
            try:
                prob = float(bits[2])
            except ValueError:
                raise FaultSpecError(
                    f"bad probability {bits[2]!r} in rule {part!r}") from None
            if not (0.0 <= prob <= 1.0):
                raise FaultSpecError(
                    f"probability {prob} out of [0, 1] in rule {part!r}")
        rules.append(FaultRule(kind, site, prob))
    return rules


class FaultPlan:
    """An active set of rules plus the deterministic firing state.

    ``fires`` is a pure function of ``(seed, kind, site, attempt#)``; the
    per-``(kind, site)`` attempt counters are the only mutable state, so
    re-running the same call sequence re-injects the same faults.
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = int(seed)
        self._attempts: Dict[Tuple[str, str], int] = {}
        #: chronological (kind, site, attempt#) log of every fired fault
        self.fired: List[Tuple[str, str, int]] = []

    def _rule_for(self, kind: str, site: str) -> Optional[FaultRule]:
        for r in self.rules:
            if r.kind == kind and fnmatch.fnmatchcase(site, r.site):
                return r
        return None

    def attempts(self, kind: str, site: str) -> int:
        return self._attempts.get((kind, site), 0)

    def fires(self, kind: str, site: str) -> bool:
        rule = self._rule_for(kind, site)
        if rule is None:
            return False
        key = (kind, site)
        n = self._attempts.get(key, 0)
        self._attempts[key] = n + 1
        if rule.prob >= 1.0:
            hit = True
        elif rule.prob <= 0.0:
            hit = False
        else:
            h = hashlib.blake2b(f"{self.seed}|{kind}|{site}|{n}".encode(),
                                digest_size=8).digest()
            hit = int.from_bytes(h, "big") / 2.0 ** 64 < rule.prob
        if hit:
            self.fired.append((kind, site, n))
        return hit


#: the active plan; ``None`` means every injection point is a no-op.
#: Injection sites read this module global directly (one attribute load)
#: before doing any other work.
ACTIVE: Optional[FaultPlan] = None


def load_env() -> Optional[FaultPlan]:
    """(Re-)activate from ``LILAC_FAULTS`` / ``LILAC_FAULTS_SEED``; called
    at import and by test isolation to resynchronize with the env."""
    global ACTIVE
    spec = os.environ.get(_ENV_SPEC, "")
    if spec:
        try:
            seed = int(os.environ.get(_ENV_SEED, "0") or 0)
        except ValueError:
            seed = 0
        ACTIVE = FaultPlan(parse_spec(spec), seed=seed)
    else:
        ACTIVE = None
    return ACTIVE


@contextlib.contextmanager
def inject(spec, seed: int = 0):
    """Context-manager activation: ``spec`` is a ``LILAC_FAULTS`` string
    or a list of :class:`FaultRule`.  Restores the previous plan on exit."""
    global ACTIVE
    rules = parse_spec(spec) if isinstance(spec, str) else list(spec)
    prev = ACTIVE
    plan = FaultPlan(rules, seed=seed)
    ACTIVE = plan
    try:
        yield plan
    finally:
        ACTIVE = prev


def check(kind: str, site: str = "*") -> bool:
    """True when an active plan fires ``kind`` at ``site`` this attempt."""
    plan = ACTIVE
    if plan is None:
        return False
    return plan.fires(kind, site)


def fail(kind: str, site: str = "*", slot: Optional[int] = None):
    """Raise :class:`InjectedFault` when the plan fires, else no-op."""
    plan = ACTIVE
    if plan is not None and plan.fires(kind, site):
        raise InjectedFault(kind, site, slot=slot)


def corrupt(kind: str, site: str, out):
    """Poison a *concrete* floating-point harness output with NaNs when
    the plan fires.  Tracers pass through untouched: an abstract output is
    on its way into a jitted executable, where a silently baked NaN could
    never be attributed back to its harness — corruption faults only fire
    where containment can observe them (the same boundary at which real
    kernel NaNs are detected)."""
    plan = ACTIVE
    if plan is None:
        return out
    try:
        import jax
        import jax.numpy as jnp
        if isinstance(out, jax.core.Tracer):
            return out
        dtype = getattr(out, "dtype", None)
        if dtype is None or not jnp.issubdtype(dtype, jnp.floating):
            return out
        if not plan.fires(kind, site):
            return out
        return jnp.asarray(out) * jnp.nan
    except InjectedFault:
        raise
    except Exception:
        return out


load_env()
