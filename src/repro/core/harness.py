"""LiLAC-How harnesses: how detected computations are executed (paper §3.3).

A ``Harness`` is the executable form of a spec's HARNESS block: a named
implementation of one What-computation, with marshaling, persistence and
platform constraints.  Multiple harnesses per computation reproduce the
paper's central observation (Table 2): no backend wins everywhere, so the
registry supports per-platform defaults, explicit pinning and an autotune
policy (the SparseX analogue).

This module holds the *mechanism* (Harness, HarnessRegistry, the global
REGISTRY) and the builtin jnp.* kernel bodies.  The *policy* — which
harness exists, its formats/platforms, and its marshaled inputs — lives in
the spec texts (``what_lang.BUILTIN_SPECS`` plus the HARNESS blocks
declared next to the Pallas kernels under ``repro/kernels/``); the spec
compiler (``repro.core.spec``) populates REGISTRY from them at import time
of ``repro.core``.  Kernel bodies receive marshaled inputs as keyword
arguments generated from the declared repack clauses instead of
open-coding the cache lookups.

Backends provided out of the box:

  spmv_*      jnp.segment   XLA-native segment-sum           (cpu + tpu)
              jnp.ell       marshaled CSR->ELL slab repack    (host calls)
              jnp.bcsr      marshaled CSR->BCSR tile repack   (host calls)
              jnp.dense     marshaled densify fallback        (host calls)
              pallas.ell    hand-tiled VPU row-slab kernel    (tpu target)
              pallas.bcsr   hand-tiled MXU block kernel       (tpu target)
  dotproduct  jnp.dot
  gemv        jnp.dot
  moe_ffn     jnp.capacity  sorted capacity-bucket dispatch   (cpu + tpu)
              pallas.gmm    ragged grouped matmul             (tpu target)
              dense         the naive einsum itself (baseline)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.marshal import DataPlane, MarshalingCache

Binding = Dict[str, Any]


class DuplicateHarnessError(ValueError):
    """A harness with the same (implements, name) is already registered."""


@dataclasses.dataclass
class CallCtx:
    mode: str                      # 'trace' | 'host'
    cache: Optional[MarshalingCache]   # usually a DataPlane (plan-level,
                                       # shared across a call's harnesses)
    format: str                    # match format: CSR/COO/ELL/JDS/DOT/...
    platform: str = "cpu"
    # Selected schedule variant: tune-param name -> value.  None (or {})
    # means the harness's declared default schedule.  Set by the autotuner
    # when it sweeps/pins a variant and by explicit callers; the generated
    # spec wrapper merges it over the defaults and passes the result to the
    # kernel body as keyword arguments.
    schedule: Optional[Dict[str, Any]] = None
    # Detected fused epilogue for this call site: 'relu' | 'silu' | 'none'
    # (bias only) | None (no epilogue).  Harnesses declaring
    # ``fuse epilogue`` apply it in-kernel (reading ``binding['bias']``
    # when present); for all others the rewriter applies it after the call.
    epilogue: Optional[str] = None
    # Fusion decision for this call: None = the harness's declared default
    # (fuse iff it declares ``fuse epilogue``); False pins the UNFUSED
    # realization of a fuse-capable harness (the epilogue is applied at the
    # jnp level after the call instead of in-kernel).  Swept as a variant
    # dimension by the autotuner and pinned by the joint plan search —
    # fusion is only applied where it measured faster (plan_search.py).
    fuse: Optional[bool] = None


@dataclasses.dataclass
class Harness:
    name: str
    implements: str                               # What-computation name
    fn: Callable[[Binding, CallCtx], Any]
    jit_safe: bool = True                         # can run under tracing
    platforms: Tuple[str, ...] = ("cpu", "tpu")
    formats: Tuple[str, ...] = ()                 # () = any
    persistent: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # declared marshal clauses (what_lang.MarshalClause): the autotuner
    # reads these to fold repack cost into winner selection; NOT part of
    # the registry fingerprint (formats/platforms/jit_safe identify the
    # harness, marshaling is an implementation detail of its data plane)
    marshal: Tuple[Any, ...] = ()
    # declared schedule space (what_lang.TuneClause / Constraint): the
    # autotuner sweeps the variant cross-product and pins (harness,
    # schedule) pairs.  Also NOT in the fingerprint: growing or shrinking
    # a tune space must not invalidate every persisted decision — stale
    # schedules are detected per-record instead (autotune.py).
    tune: Tuple[Any, ...] = ()
    constraints: Tuple[Any, ...] = ()
    # True when the body applies detected epilogues (ctx.epilogue +
    # binding['bias']) itself — in-register for Pallas kernels; False
    # harnesses get the epilogue applied by the rewriter after the call.
    fuse_epilogue: bool = False
    # Declared custom backward (what_lang.VjpClause): the rewriter wraps
    # the call in jax.custom_vjp over the clause's wrt keys, using the
    # registered backward body (spec.VJPS).  None means jax differentiates
    # straight through the body — fine for pure-jnp harnesses, fatal for
    # Pallas/host kernels, which is why those declare one.  NOT in the
    # fingerprint: adding a backward must not invalidate persisted tunings.
    vjp: Optional[Any] = None
    # Opt-out for executable-plan baking (repro.core.plan): set False for
    # a backend whose body has per-call HOST-side behavior beyond its
    # declared marshal clauses (RNG, mutable globals, external I/O) — a
    # baked plan would freeze the first call's behavior at trace time.
    # Harnesses with persistent state / lifecycle hooks are treated as
    # unbakeable automatically.
    bakeable: bool = True
    setup: Optional[Callable] = None              # BeforeFirstExecution
    teardown: Optional[Callable] = None           # AfterLastExecution
    # Shared mutable {"up": bool} when one HARNESS block implements several
    # computations: the sibling Harness objects are ONE backend, so setup
    # runs once on the first call through any of them, release through any
    # of them tears down for all, and a later call sets up again.
    lifecycle: Optional[Dict[str, bool]] = None
    _setup_done: bool = False
    _schedules: Optional[Tuple[Dict[str, Any], ...]] = None

    @property
    def schedules(self) -> Tuple[Dict[str, Any], ...]:
        """The lazy schedule-variant family: every constraint-satisfying
        assignment of the declared tune params, default first.  Empty for
        untuned harnesses."""
        if self._schedules is None:
            from repro.core.what_lang import enumerate_schedules
            self._schedules = enumerate_schedules(self.tune, self.constraints)
        return self._schedules

    @property
    def default_schedule(self) -> Dict[str, Any]:
        return {t.name: t.values[0] for t in self.tune}

    def _is_up(self) -> bool:
        if self.lifecycle is not None:
            return self.lifecycle["up"]
        return self._setup_done

    def _mark(self, up: bool):
        if self.lifecycle is not None:
            self.lifecycle["up"] = up
        self._setup_done = up

    def __call__(self, binding: Binding, ctx: CallCtx):
        from repro.core import faults
        if faults.ACTIVE is not None:
            faults.fail("kernel_raise", self.name)
        if not self._is_up() and self.setup is not None:
            self.setup(self.persistent)
            self._mark(True)
        out = self.fn(binding, ctx)
        if faults.ACTIVE is not None:
            out = faults.corrupt("nan_output", self.name, out)
        return out

    def release(self):
        if self._is_up() and self.teardown is not None:
            self.teardown(self.persistent)
            self._mark(False)


class HarnessRegistry:
    def __init__(self, version: int = 0):
        self._by_comp: Dict[str, List[Harness]] = {}
        self._defaults: Dict[Tuple[str, str], str] = {}  # (comp, platform) -> name
        self.version = version        # bump to invalidate persisted tunings
        # monotone registration counter: unlike the fingerprint (which
        # hashes declared metadata and cannot see a same-name body
        # replacement via override=True), the epoch moves on EVERY
        # register — baked executable plans compare it per dispatch so a
        # replaced kernel is never served from a stale jitted executable
        self.epoch = 0
        self._autotuner = None
        self._fp_cache: Optional[Tuple[int, str]] = None  # (version, fp)

    def register(self, h: Harness, default_for: Tuple[str, ...] = (),
                 override: bool = False):
        """Register a harness.  Re-registering the same ``(implements,
        name)`` is an error unless ``override=True``, which replaces the
        existing harness in place (same candidate-order slot) — the escape
        hatch that makes spec re-loading safe."""
        hs = self._by_comp.setdefault(h.implements, [])
        for i, existing in enumerate(hs):
            if existing.name == h.name:
                if not override:
                    raise DuplicateHarnessError(
                        f"harness {h.name!r} is already registered for "
                        f"{h.implements!r}; pass override=True to replace it")
                existing.release()   # run AfterLastExecution before dropping
                hs[i] = h
                break
        else:
            hs.append(h)
        for plat in default_for:
            self._defaults[(h.implements, plat)] = h.name
        self._autotuner = None        # harness set changed -> new fingerprint
        self._fp_cache = None
        self.epoch += 1
        return h

    def fingerprint(self) -> str:
        """Stable hash of (version, registered harness set).  Persisted
        tunings (and executable plans) are invalidated whenever this
        changes.  Memoized until the next ``register``/version bump: the
        pass manager reads it per compiled function and the steady-state
        path must not re-hash the whole registry."""
        cached = self._fp_cache
        if cached is not None and cached[0] == self.version:
            return cached[1]
        import hashlib

        items = sorted(
            (h.implements, h.name, h.platforms, h.formats, h.jit_safe)
            for hs in self._by_comp.values() for h in hs)
        blob = repr((self.version, items)).encode()
        fp = hashlib.blake2b(blob, digest_size=8).hexdigest()
        self._fp_cache = (self.version, fp)
        return fp

    @property
    def autotuner(self):
        from repro.core.autotune import Autotuner

        fp = self.fingerprint()
        if self._autotuner is None or self._autotuner.registry_fingerprint != fp:
            self._autotuner = Autotuner(registry_fingerprint=fp)
        return self._autotuner

    def reset_autotuner(self):
        self._autotuner = None

    @property
    def _autotune_cache(self) -> Dict[Tuple, str]:
        """Back-compat view: (signature, mode) -> winning harness name."""
        if self._autotuner is None:
            return {}
        return self._autotuner.pinned()

    def default_name(self, comp: str, platform: str) -> Optional[str]:
        return self._defaults.get((comp, platform))

    def harnesses_for(self, comp: str) -> List[Harness]:
        return list(self._by_comp.get(comp, []))

    def get(self, comp: str, name: str) -> Harness:
        for h in self._by_comp.get(comp, []):
            if h.name == name:
                return h
        raise KeyError(f"no harness {name!r} for {comp!r}")

    def candidates(self, comp: str, fmt: str, platform: str,
                   mode: str) -> List[Harness]:
        out = []
        for h in self._by_comp.get(comp, []):
            if platform not in h.platforms:
                continue
            if h.formats and fmt not in h.formats:
                continue
            if mode == "trace" and not h.jit_safe:
                continue
            out.append(h)
        return out

    def select(self, comp: str, fmt: str, platform: str, mode: str,
               policy: str = "default",
               binding: Optional[Binding] = None,
               ctx: Optional[CallCtx] = None) -> Harness:
        cands = self.candidates(comp, fmt, platform, mode)
        if not cands:
            raise KeyError(f"no harness for {comp}/{fmt} on {platform} ({mode})")
        if policy not in ("default", "autotune"):
            return self.get(comp, policy)  # explicit pin by name
        dname = self._defaults.get((comp, platform))
        if policy == "autotune" and binding is not None:
            # SparseX-style persistent tuning (autotune.py): signature-keyed
            # winner, measured once, reused across calls AND processes; in
            # trace mode the winner is pinned at first lowering.
            if ctx is None:
                ctx = CallCtx(mode=mode, cache=DataPlane(), format=fmt,
                              platform=platform)
            h = self.autotuner.select(comp, fmt, platform, mode, cands,
                                      binding, ctx, default_name=dname)
            if h is not None:
                return h
        if dname is not None:
            for h in cands:
                if h.name == dname:
                    return h
        return cands[0]


REGISTRY = HarnessRegistry()


# ---------------------------------------------------------------------------
# Builtin jnp.* kernel bodies.  Marshaled inputs (ell/bcsr/dense keyword
# args) are produced by the repack clauses declared in the spec texts and
# injected by the generated wrapper (repro.core.spec.build_harnesses).
# ---------------------------------------------------------------------------

def _row_ids(binding: Binding) -> jax.Array:
    """CSR binding carries `rowstr`; COO carries `rowidx`."""
    if "rowidx" in binding:
        return binding["rowidx"]
    row_ptr = binding["rowstr"]
    return jnp.repeat(
        jnp.arange(binding["rows"], dtype=jnp.int32),
        jnp.diff(row_ptr),
        total_repeat_length=binding["nnz"],
    )


def _spmv_segment(b: Binding, ctx: CallCtx):
    prod = b["a"] * b["iv"][b["colidx"]]
    return jax.ops.segment_sum(prod, _row_ids(b), num_segments=b["rows"])


@jax.jit
def _ell_spmv_jit(val, col, perm, vec):
    acc = jnp.sum(val * vec[col], axis=1)
    out = jnp.zeros((val.shape[0],), acc.dtype)
    return out.at[perm].set(acc)


def _spmv_ell_host(b: Binding, ctx: CallCtx, *, ell):
    """CSR/COO match with a marshaled ELL repack: the repack is the
    'transfer' that the cache amortizes across calls (paper Fig. 18)."""
    return _ell_spmv_jit(ell.val, ell.col, ell.perm, b["iv"])


def _binding_to_csr(b: Binding):
    from repro.sparse.formats import CSR

    cols = int(np.asarray(b["iv"]).shape[0])
    if "rowstr" in b:
        return CSR(val=b["a"], col_ind=b["colidx"], row_ptr=b["rowstr"],
                   shape=(b["rows"], cols))
    # COO -> CSR on host (sorted by row)
    row = np.asarray(b["rowidx"])
    order = np.argsort(row, kind="stable")
    val = np.asarray(b["a"])[order]
    col = np.asarray(b["colidx"])[order]
    counts = np.bincount(row, minlength=b["rows"])
    row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return CSR(val=jnp.asarray(val), col_ind=jnp.asarray(col.astype(np.int32)),
               row_ptr=jnp.asarray(row_ptr), shape=(b["rows"], cols))


def _spmv_bcsr_host(b: Binding, ctx: CallCtx, *, bcsr):
    from repro.sparse.ops import bcsr_spmm_ref

    vec = b["iv"]
    pad = bcsr.shape[1] - vec.shape[0]
    if pad > 0:
        vec = jnp.pad(vec, (0, pad))
    out = bcsr_spmm_ref(bcsr, vec[:, None])[:, 0]
    return out[: b["rows"]]


def _spmv_dense_host(b: Binding, ctx: CallCtx, *, dense):
    return dense @ b["iv"]


def _spmv_ell_direct(b: Binding, ctx: CallCtx):
    """For matches already in ELL/JDS layout (2D val/col binding)."""
    perm = b.get("perm")
    acc = jnp.sum(b["val"] * b["vector"][b["col_ind"]], axis=1)
    if perm is None:
        return acc
    out = jnp.zeros((b["rows"],), acc.dtype)
    return out.at[perm].set(acc)


def _spmm_segment(b: Binding, ctx: CallCtx):
    """CSR/COO x dense-matrix via segment-sum (trace-safe)."""
    prod = b["a"][:, None] * b["dense"][b["colidx"]]
    return jax.ops.segment_sum(prod, _row_ids(b), num_segments=b["rows"])


def _spmm_bcsr_host(b: Binding, ctx: CallCtx, *, bcsr):
    """Marshaled CSR->BCSR repack + block SpMM (cuSPARSE csrmm analogue;
    on TPU this is the bsr_spmm Pallas kernel's home case)."""
    from repro.sparse.ops import bcsr_spmm_ref

    dense = b["dense"]
    pad = bcsr.shape[1] - dense.shape[0]
    if pad > 0:
        dense = jnp.pad(dense, ((0, pad), (0, 0)))
    return bcsr_spmm_ref(bcsr, dense)[: b["rows"]]


def _binding_to_csr_spmm(b: Binding):
    """Like _binding_to_csr but the column count comes from the dense
    operand's leading dim (the paper's Fig. 9 `cols` invariant)."""
    bb = dict(b)
    bb["iv"] = jnp.zeros((int(np.asarray(b["dense"]).shape[0]),))
    return _binding_to_csr(bb)


def _dot_jnp(b: Binding, ctx: CallCtx):
    return jnp.dot(b["a"], b["b"])


def _gemv_jnp(b: Binding, ctx: CallCtx):
    return b["mat"] @ b["vec"]


def _moe_capacity(b: Binding, ctx: CallCtx, capacity_factor: float = 2.0):
    """Sorted capacity-bucket dispatch: compute only routed tokens.

    Naive dense-dispatch FLOPs  ~ E * T * (3 D F)
    This implementation        ~ E * C * (3 D F), C = ceil(T*K/E * cf)
    -> compute reduction E/(K*cf): 4x (olmoe) to 2.5x (granite-moe).
    """
    x, gate, idx = b["x"], b["gate"], b["idx"]
    wg, wu, wd = b["wg"], b["wu"], b["wd"]
    T, K = idx.shape
    E = b["experts"]
    C = int(np.ceil(T * K / E * capacity_factor))
    C = max(8, min(C, T * K))
    flat_e = idx.reshape(-1)                                    # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)      # (T*K,)
    flat_g = gate.reshape(-1)
    # position of each routed pair within its expert queue
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (TK, E)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(T * K), flat_e]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)             # overflow -> drop
    # gather tokens into (E*C+1, D) buckets
    xb = jnp.zeros((E * C + 1, x.shape[1]), x.dtype).at[slot].set(x[flat_t])
    xb = xb[:-1].reshape(E, C, x.shape[1])
    g = jnp.einsum("ecd,edf->ecf", xb, wg)
    u = jnp.einsum("ecd,edf->ecf", xb, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E * C, -1)
    y = jnp.concatenate([y, jnp.zeros((1, y.shape[1]), y.dtype)])
    out = jax.ops.segment_sum(
        y[jnp.where(keep, slot, E * C)] * flat_g[:, None],
        flat_t, num_segments=T)
    return out.astype(x.dtype)


def _spmv_csr_bwd(b: Binding, ctx: CallCtx, primal, ct):
    """SpMV transpose-products for CSR/COO bindings: ``d_a`` is the
    per-nonzero product, ``d_iv`` the A^T @ ct scatter (the grad jaxpr's
    SpMVᵀ — itself a COO SpMV, re-detectable by an outer compiled grad).
    O(nnz) in both, never densifying A."""
    r = _row_ids(b)
    return {
        "a": ct[r] * b["iv"][b["colidx"]],
        "iv": jnp.zeros_like(b["iv"]).at[b["colidx"]].add(b["a"] * ct[r]),
    }


def _spmv_ell_bwd(b: Binding, ctx: CallCtx, primal, ct):
    """ELL/JDS direct-match backward: padded (val==0) slots receive the
    cotangent product like any other slot — that IS the gradient of the
    forward wrt the padded val array, matching the dense-jaxpr oracle."""
    perm = b.get("perm")
    dacc = ct if perm is None else ct[perm]
    return {
        "val": dacc[:, None] * b["vector"][b["col_ind"]],
        "vector": jnp.zeros_like(b["vector"]).at[b["col_ind"]].add(
            b["val"] * dacc[:, None]),
    }


def _spmm_csr_bwd(b: Binding, ctx: CallCtx, primal, ct):
    """BSR/CSR SpMM backward: ``d_dense = Aᵀ @ ct`` as an O(nnz·N)
    scatter, ``d_a`` the per-nonzero row-dot."""
    r = _row_ids(b)
    return {
        "a": jnp.sum(ct[r] * b["dense"][b["colidx"]], axis=-1),
        "dense": jnp.zeros_like(b["dense"]).at[b["colidx"]].add(
            b["a"][:, None] * ct[r]),
    }


def _moe_ffn_bwd(b: Binding, ctx: CallCtx, primal, ct):
    """MoE scatter-grad via capacity-bucket recomputation: the backward
    re-runs the E·C-token sorted dispatch (not the E·T dense form) and
    pulls the cotangent through it, so grads cost the same compute
    reduction as the sparse forward.  Exact whenever no token exceeds
    capacity (e.g. balanced routing); dropped tokens get zero grad, the
    standard capacity-truncation semantics."""
    def f(x, gate, wg, wu, wd):
        bb = dict(b)
        bb.update(x=x, gate=gate, wg=wg, wu=wu, wd=wd)
        return _moe_capacity(bb, ctx)

    _, pull = jax.vjp(f, b["x"], b["gate"], b["wg"], b["wu"], b["wd"])
    gx, gg, gwg, gwu, gwd = pull(ct)
    return {"x": gx, "gate": gg, "wg": gwg, "wu": gwu, "wd": gwd}


#: Builtin backward bodies for ``vjp`` clauses, keyed by the name the
#: clause cites.  ``repro.core.spec`` enters these into its VJPS registry
#: at import, so they are declarable from any HARNESS block (builtin spec
#: texts and the kernel packages alike).
BUILTIN_VJPS: Dict[str, Callable] = {
    "spmv_csr_bwd": _spmv_csr_bwd,
    "spmv_ell_bwd": _spmv_ell_bwd,
    "spmm_csr_bwd": _spmm_csr_bwd,
    "moe_ffn_bwd": _moe_ffn_bwd,
}


def _moe_dense(b: Binding, ctx: CallCtx):
    """The naive formulation itself — the paper's '-O2 baseline' harness."""
    x, gate, idx = b["x"], b["gate"], b["idx"]
    onehot = jax.nn.one_hot(idx, b["experts"], dtype=x.dtype)
    combine = jnp.einsum("tke,tk->te", onehot, gate)
    g = jnp.einsum("td,edf->etf", x, b["wg"])
    u = jnp.einsum("td,edf->etf", x, b["wu"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("etf,efd->etd", h, b["wd"])
    return jnp.einsum("te,etd->td", combine, y)


# Kernel bodies for the builtin spec texts, keyed by spec family then by
# harness name (repro.core.spec.register_builtins consumes this).
BUILTIN_BODIES: Dict[str, Dict[str, Callable]] = {
    "spmv": {
        "jnp.segment": _spmv_segment,
        "jnp.ell": _spmv_ell_host,
        "jnp.bcsr": _spmv_bcsr_host,
        "jnp.dense": _spmv_dense_host,
    },
    "spmv_padded": {"jnp.ell": _spmv_ell_direct},
    "spmm": {"jnp.segment": _spmm_segment, "jnp.bcsr": _spmm_bcsr_host},
    "dotproduct": {"jnp.dot": _dot_jnp},
    "gemv": {"jnp.dot": _gemv_jnp},
    "moe_ffn": {"jnp.capacity": _moe_capacity},
    "moe_ffn_baseline": {"dense": _moe_dense},
}
