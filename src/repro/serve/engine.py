"""The serving engine: continuous batching over a lilac-compiled decode.

One :class:`Engine` owns one replica's state — the batched KV cache, the
:class:`~repro.serve.scheduler.Scheduler`, the lilac-compiled decode step
and a :class:`~repro.serve.metrics.ServeMetrics` sink — and advances it
one decode step at a time:

1. **admit** — pop waiting requests into free slots (continuous mode:
   any step with a free slot; static mode: only when the batch drained).
   Each admission runs an exact-length jitted prefill, converts the
   collected caches into one batched-cache row, and takes its first token
   from the prefill logits (greedy).
2. **re-bucket** — resize the batched cache to the smallest
   ``(batch, seq-capacity)`` bucket that holds the active set (see
   :mod:`repro.serve.buckets`).  Every bucket pair was prewarmed at
   startup, so the resized shape dispatches onto an already-baked
   :class:`~repro.core.plan.ExecutablePlan` — never detect/tune/bake.
3. **decode** — one batched step with *per-slot* positions (each row of
   the cache is at its own depth); greedy next token per active row.
4. **evict** — finished requests leave; tail survivors compact into the
   holes via ``(src, dst)`` cache-row moves so the active prefix invariant
   holds for the next step.

``prewarm()`` walks the bucket grid through
:meth:`~repro.core.pass_manager.LilacFunction.prewarm` before any traffic,
so steady-state decode is plan dispatch only; with a persistent plan
cache shared across replicas, even the *first* replica boot after a fleet
has run pays zero detection (the serving benchmark gates on this).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.buckets import BucketPolicy, default_buckets
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import Request, Scheduler

DEFAULT_MAX_STEPS = 200_000


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine configuration (model-independent knobs)."""
    buckets: Optional[BucketPolicy] = None   # None -> LILAC_SERVE_BUCKETS/env
    mode: str = "continuous"                 # continuous | static
    queue_capacity: int = 1024
    eos_id: Optional[int] = None             # default eos for submitted text
    use_lilac: bool = True                   # lilac-compile the decode step
    lilac_mode: str = "host"
    policy: str = "default"
    plan_cache: Any = None                   # forwarded to lilac.compile
    # jit the admission/eviction tensor plumbing (prefill, cache-row
    # install, slot moves).  True requires a jax-traceable model; mock
    # models in tests turn it off and the engine calls the model's cache
    # hooks directly.
    jit_prefill: bool = True
    prewarm_on_start: bool = True
    # prompt lengths whose prefill XLA executables are compiled during
    # prewarm — requests at other lengths still work, they just pay a
    # first-occurrence jit compile on the request path
    prefill_lengths: Tuple[int, ...] = ()
    # default per-request deadline (seconds from arrival): a request past
    # it is evicted with failed="deadline" instead of holding a slot;
    # None = no deadline unless the Request carries its own
    deadline_s: Optional[float] = None
    # when set, submit() admits via Scheduler.try_admit(deadline=...)
    # (bounded retry-with-backoff on a full queue) instead of a single
    # SchedulerFull-raising attempt
    admit_deadline_s: Optional[float] = None
    # request-level shadow verification: the floor fraction of finished
    # requests re-decoded solo on this engine and compared token-for-token
    # against the batched stream (catches slot mix-ups / compaction bugs
    # the per-dispatch shadow cannot see).  None -> the
    # LILAC_REQUEST_SHADOW_RATE env var (default 0 = off); the effective
    # rate is adaptive — divergences spike it, clean checks decay it
    # (see repro.core.resilience.AdaptiveShadowRate)
    request_shadow_rate: Optional[float] = None

    def replace(self, **kw) -> "ServeConfig":
        return dataclasses.replace(self, **kw)


class Engine:
    """One serving replica.  ``model`` is anything with the
    :class:`repro.models.factory.Model` surface (prefill / decode /
    init_cache / cache_from_prefill / cache_set_slot / cache_move_slot /
    cache_resize); tests drive the scheduler logic with an integer mock.
    """

    def __init__(self, model, params, config: Optional[ServeConfig] = None,
                 *, clock=time.perf_counter):
        self.model = model
        self.params = params
        self.config = config or ServeConfig()
        self.buckets = self.config.buckets or default_buckets()
        self.clock = clock
        self.scheduler = Scheduler(self.buckets.max_batch,
                                   queue_capacity=self.config.queue_capacity,
                                   mode=self.config.mode)
        self.metrics = ServeMetrics(clock=clock)
        self._cache = None
        self._shape: Optional[Tuple[int, int]] = None    # (batch, seq) bucket
        self._prewarmed: set = set()
        from repro.core.resilience import AdaptiveShadowRate
        self._request_shadow = AdaptiveShadowRate(
            "LILAC_REQUEST_SHADOW_RATE",
            floor=self.config.request_shadow_rate)
        self._req_shadow_ctr = 0
        self.metrics.set_request_shadow_provider(self._request_shadow.snapshot)
        if self.config.use_lilac:
            from repro import lilac
            self._decode = lilac.compile(
                model.decode, mode=self.config.lilac_mode,
                policy=self.config.policy,
                plan_cache=self.config.plan_cache)
            info = getattr(self._decode, "resilience_info", None)
            if info is not None:
                self.metrics.set_resilience_provider(info)
        else:
            self._decode = model.decode
        if self.config.jit_prefill:
            import jax
            self._prefill = jax.jit(
                lambda p, toks: model.prefill(p, {"tokens": toks}))
            # admission install and eviction compaction as single jitted
            # programs with a *dynamic* slot index: one XLA executable per
            # (prompt-length, bucket) combination, reused for every slot —
            # the eager tree-op spelling pays per-op dispatch/compile on
            # every admission instead

            def _install(cache, caches, slot, L, S):
                row = model.cache_from_prefill(caches, L, S)
                return jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_index_in_dim(
                        full, one[0].astype(full.dtype), slot, 0),
                    cache, row)

            def _move(cache, src, dst):
                return jax.tree.map(
                    lambda a: jax.lax.dynamic_update_index_in_dim(
                        a, jax.lax.dynamic_index_in_dim(
                            a, src, 0, keepdims=False), dst, 0),
                    cache)

            self._install = jax.jit(_install, static_argnums=(3, 4))
            self._move = jax.jit(_move)
        else:
            self._prefill = lambda p, toks: model.prefill(
                p, {"tokens": toks})

            def _install(cache, caches, slot, L, S):
                row = model.cache_from_prefill(caches, L, S)
                return model.cache_set_slot(cache, slot, row)

            self._install = _install
            self._move = model.cache_move_slot
        if self.config.prewarm_on_start and self.config.use_lilac:
            self.prewarm()

    # -- startup ---------------------------------------------------------

    def prewarm(self) -> Dict[str, Any]:
        """Bake one decode plan per bucket-grid point before traffic.

        Builds each ``(batch, seq)`` signature from shape specs (zero
        allocation for the caller) and funnels them through
        ``LilacFunction.prewarm``; the returned report carries per-bucket
        ``{baked, detect_calls, from_plan_cache}``.  With a warm
        persistent plan cache, ``detect_calls`` is 0 across the board.
        """
        import jax
        import jax.numpy as jnp
        sigs = []
        for (b, s) in self.buckets.grid():
            cache_sds = jax.eval_shape(lambda: self.model.init_cache(b, s))
            sigs.append((self.params, cache_sds,
                         jax.ShapeDtypeStruct((b, 1), jnp.int32),
                         jax.ShapeDtypeStruct((b,), jnp.int32)))
        report = self._decode.prewarm(*sigs)
        report["grid"] = [list(g) for g in self.buckets.grid()]
        self._prewarmed = set(self.buckets.grid())
        # prefill/admission warmup: trigger the per-(length, bucket) XLA
        # compiles of the prefill step, the cache-row install and the
        # slot-move compaction now, so admission and eviction at any
        # prewarmed shape are pure execution
        lengths = [L for L in self.config.prefill_lengths]
        prefills = {}
        for L in lengths:
            prefills[L] = self._prefill(self.params,
                                        jnp.zeros((1, L), jnp.int32))
            jax.block_until_ready(prefills[L])
        if lengths and self.config.jit_prefill:
            for (b, s) in self.buckets.grid():
                cache = self.model.init_cache(b, s)
                for L in lengths:
                    if L <= s:
                        _, caches = prefills[L]
                        cache = self._install(cache, caches, 0, L, s)
                jax.block_until_ready(self._move(cache, 0, b - 1))
        report["prefill_warmed"] = lengths
        self.metrics.record_prewarm(report)
        return report

    # -- request intake --------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Enqueue a request; False (and a rejection metric) when the
        queue is full or the request cannot fit any bucket.  With
        ``config.admit_deadline_s`` set, a full queue is retried with
        bounded backoff (``Scheduler.try_admit``) before rejecting."""
        from repro.serve.buckets import BucketError
        from repro.serve.scheduler import SchedulerFull
        if req.eos_id is None:
            req.eos_id = self.config.eos_id
        if req.deadline_s is None:
            req.deadline_s = self.config.deadline_s
        try:
            self.buckets.seq_bucket(req.prompt_len + req.max_new_tokens)
        except BucketError:
            self.metrics.record_rejected()
            return False
        if self.config.admit_deadline_s is not None:
            retries = 0

            def _sleep(dt, _sleep=time.sleep):
                nonlocal retries
                retries += 1
                _sleep(dt)

            ok = self.scheduler.try_admit(
                req, deadline=self.config.admit_deadline_s, sleep=_sleep)
            if retries:
                self.metrics.record_admission_retries(retries)
            if not ok:
                self.metrics.record_admission_timeout()
                self.metrics.record_rejected()
                return False
        else:
            try:
                self.scheduler.submit(req)
            except SchedulerFull:
                self.metrics.record_rejected()
                return False
        req.arrival_t = self.clock()
        self.metrics.record_submit(req.rid, req.arrival_t, req.prompt_len)
        return True

    # -- one engine step --------------------------------------------------

    def step(self) -> List[Request]:
        """Admit -> re-bucket -> prefill admissions -> decode -> evict.
        Returns the requests that finished during this step."""
        finished: List[Request] = []
        self._expire_deadlines()
        admitted = self.scheduler.admissions()
        if self.scheduler.active:
            self._fit_buckets()
        if admitted:
            self._admit(admitted)
            finished += self._evict()
        if self.scheduler.active:
            self._decode_once()
            finished += self._evict()
        return finished

    def run_until_idle(self, max_steps: int = DEFAULT_MAX_STEPS
                       ) -> List[Request]:
        out: List[Request] = []
        steps = 0
        while not self.scheduler.idle:
            out += self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} "
                                   f"steps (livelock?)")
        return out

    def run(self, workload=None, max_steps: int = DEFAULT_MAX_STEPS
            ) -> Dict[str, Any]:
        """Drive a workload (iterable of ``(arrival_offset_s, Request)``)
        plus anything already submitted until drained; returns the metrics
        snapshot."""
        pending = deque(sorted(workload, key=lambda ar: ar[0])
                        if workload is not None else [])
        start = self.clock()
        steps = 0
        while pending or not self.scheduler.idle:
            now = self.clock() - start
            while pending and pending[0][0] <= now:
                _, req = pending.popleft()
                self.submit(req)
            if self.scheduler.idle:
                if pending:
                    wait = pending[0][0] - (self.clock() - start)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                continue
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"workload did not drain in {max_steps} "
                                   f"steps")
        return self.metrics.snapshot()

    def drain(self) -> List[Request]:
        """Remove and return every in-flight request (active in slot
        order, then waiting in arrival order), resetting the replica's
        batch state.  The front door calls this on a failed replica; the
        caller discards partial generation before resubmitting — greedy
        decode is deterministic, so a re-run on a survivor regenerates
        the identical token stream."""
        out = self.scheduler.drain()
        self._cache = None
        self._shape = None
        return out

    def replay_solo(self, req: Request) -> List[int]:
        """Re-decode a finished request's stream solo ON THIS ENGINE: a
        fresh single-request cache at the smallest batch bucket, the same
        compiled prefill/install/decode the batched path used.  Returns
        exactly ``len(req.tokens)`` greedy tokens — the reference the
        request-level shadow compares against."""
        B = self.buckets.batch_bucket(1)
        S = self.buckets.seq_bucket(req.prompt_len + req.max_new_tokens)
        cache = self.model.init_cache(B, S)
        logits, caches = self._prefill(self.params, req.prompt[None, :])
        cache = self._install(cache, caches, 0, req.prompt_len, S)
        toks = [int(np.argmax(np.asarray(logits)[0]))]
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        while len(toks) < len(req.tokens):
            tokens[0, 0] = toks[-1]
            pos[0] = req.prompt_len + len(toks) - 1
            logits, cache = self._decode(self.params, cache, tokens, pos)
            toks.append(int(np.argmax(np.asarray(logits)[0])))
        return toks

    def generate_solo(self, prompt, max_new_tokens: int, *,
                      eos_id: Optional[int] = None) -> List[int]:
        """Run one request on a FRESH engine (same model/params/buckets,
        no prewarm) — the per-request reference stream the batching
        property tests compare against."""
        eng = Engine(self.model, self.params,
                     self.config.replace(prewarm_on_start=False,
                                         request_shadow_rate=0.0),
                     clock=self.clock)
        req = Request(prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id)
        if not eng.submit(req):
            raise ValueError("request does not fit any bucket")
        eng.run_until_idle()
        return list(req.tokens)

    # -- internals --------------------------------------------------------

    def _fit_buckets(self):
        active = self.scheduler.active
        need_s = max(r.prompt_len + r.max_new_tokens for r in active)
        target = (self.buckets.batch_bucket(len(active)),
                  self.buckets.seq_bucket(need_s))
        if target == self._shape:
            return
        if self._cache is None:
            self._cache = self.model.init_cache(*target)
        else:
            self._cache = self.model.cache_resize(
                self._cache, B=target[0], max_seq=target[1])
            self.metrics.record_resize()
        self._shape = target

    def _admit(self, admitted: Sequence[Request]):
        for req in admitted:
            slot = self.scheduler.active.index(req)
            t0 = self.clock()
            logits, caches = self._prefill(self.params, req.prompt[None, :])
            self._cache = self._install(self._cache, caches, slot,
                                        req.prompt_len, self._shape[1])
            req.tokens.append(int(np.argmax(np.asarray(logits)[0])))
            req.prefill_s = self.clock() - t0
            req.ttft_s = self.clock() - req.arrival_t
            self.metrics.record_admit(req.rid, req.prefill_s, req.ttft_s)

    def _decode_once(self):
        from repro.core import faults
        tb, ts = self._shape
        active = self.scheduler.active
        tokens = np.zeros((tb, 1), np.int32)
        pos = np.zeros((tb,), np.int32)
        for i, r in enumerate(active):
            tokens[i, 0] = r.tokens[-1]
            # the new token is written at the row's current depth
            pos[i] = r.prompt_len + len(r.tokens) - 1
        t0 = self.clock()
        try:
            if faults.ACTIVE is not None:
                # attribute the injected fault to a rotating batch slot so
                # chaos runs exercise eviction at every position
                slot = faults.ACTIVE.attempts(
                    "decode_raise", "decode") % len(active)
                faults.fail("decode_raise", "decode", slot=slot)
            logits, self._cache = self._decode(self.params, self._cache,
                                               tokens, pos)
        except Exception as e:   # containment boundary: poison one slot
            slot = getattr(e, "slot", None)
            if not isinstance(slot, int) or not 0 <= slot < len(active):
                slot = len(active) - 1
            active[slot].failed = \
                f"decode: {type(e).__name__}: {e}"[:200]
            self.metrics.record_decode_fault()
            # the cache was NOT reassigned, so this step is a no-op for
            # the survivors: they redo the identical decode next step and
            # their streams stay bit-identical to a fault-free run
            return
        dt = self.clock() - t0
        logits_np = np.asarray(logits)
        if faults.ACTIVE is not None and np.issubdtype(
                logits_np.dtype, np.floating):
            if faults.check("decode_nan", "decode"):
                slot = faults.ACTIVE.attempts(
                    "decode_nan", "decode") % len(active)
                logits_np = np.array(logits_np, copy=True)
                logits_np[slot] = np.nan
        # per-row finite check: a NaN/Inf row fails only that request; the
        # cache row itself is overwritten or compacted away at eviction
        finite = np.isfinite(
            logits_np.reshape(logits_np.shape[0], -1)).all(axis=1)
        nxt = np.argmax(logits_np, axis=-1)
        for i, r in enumerate(active):
            if not finite[i]:
                r.failed = "non-finite decode logits"
                self.metrics.record_decode_fault()
                continue
            r.tokens.append(int(nxt[i]))
        self.metrics.record_step(
            dt, batch=tb, active=len(active),
            queue_depth=self.scheduler.queue_depth,
            bucket_hit=(tb, ts) in self._prewarmed)

    def _expire_deadlines(self):
        """Evict requests past their per-request deadline.  Active ones
        are marked failed and leave through the ordinary compaction;
        waiting ones are dropped from the queue directly (they hold no
        cache slot, so no moves are needed)."""
        now = self.clock()

        def _past(r: Request) -> bool:
            return (r.deadline_s is not None and r.failed is None
                    and r.arrival_t and now - r.arrival_t > r.deadline_s)

        for r in self.scheduler.active:
            if _past(r):
                r.failed = "deadline"
        expired = [r for r in self.scheduler.waiting if _past(r)]
        if expired:
            self.scheduler.waiting = deque(
                r for r in self.scheduler.waiting if r not in expired)
            for r in expired:
                r.failed = "deadline"
                r.finish_t = now
                self.metrics.record_fault_eviction("deadline")
                self.metrics.record_finish(r.rid, len(r.tokens),
                                           now - r.arrival_t)

    def _evict(self) -> List[Request]:
        finished, moves = self.scheduler.evict_finished()
        for src, dst in moves:
            self._cache = self._move(self._cache, src, dst)
        now = self.clock()
        for r in finished:
            r.finish_t = now
            if r.failed is not None:
                self.metrics.record_fault_eviction(r.failed)
            self.metrics.record_finish(r.rid, len(r.tokens),
                                       now - r.arrival_t)
            if r.failed is None and r.tokens:
                self._maybe_shadow_request(r)
        return finished

    def _maybe_shadow_request(self, req: Request):
        """Request-level shadow verification on a deterministic stratified
        sample of finished requests (same scheme as the dispatch-level
        shadow: rate r checks finish n iff the integer part of n*r
        advances).  The batched stream is compared token-for-token with a
        solo replay on this same engine — any difference means the
        *batched path* (slot map, compaction, cache moves) corrupted the
        request, which per-dispatch shadowing of the decode fn cannot
        see.  Divergence feeds the compiled decode's quarantine→re-tune
        path and spikes both adaptive rates."""
        from repro.core import faults
        r = self._request_shadow.effective()
        if r <= 0.0:
            return
        self._req_shadow_ctr = n = self._req_shadow_ctr + 1
        if int(n * r) == int((n - 1) * r):
            return
        try:
            solo = self.replay_solo(req)
        except Exception:
            return      # the replay itself failed; never punish the served path
        diverged = (solo != list(req.tokens)
                    or faults.check("shadow_diverge", "request"))
        self.metrics.record_request_shadow(diverged)
        if not diverged:
            self._request_shadow.clean()
            return
        self._request_shadow.spike("request shadow divergence")
        report = getattr(self._decode, "report_divergence", None)
        if report is not None:
            report(reason=f"request-shadow divergence (rid {req.rid})")


def build_engine(arch: str = "olmoe-1b-7b", *, smoke: bool = True,
                 seed: int = 0, config: Optional[ServeConfig] = None,
                 moe_decode_impl: Optional[str] = "naive_flat") -> Engine:
    """Convenience constructor: registry arch -> (smoke-sized) model ->
    initialized params -> Engine.  ``moe_decode_impl="naive_flat"`` makes
    the decode jaxpr carry the canonical dense-dispatch MoE form so the
    LiLAC detector can target it; None keeps the arch default."""
    import jax
    from repro.configs.base import get_arch, smoke_config
    from repro.models.factory import build_model
    cfg = get_arch(arch)
    if smoke:
        cfg = smoke_config(cfg)
    if moe_decode_impl is not None and cfg.moe_experts:
        cfg = cfg.replace(moe_decode_impl=moe_decode_impl)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return Engine(model, params, config)
