"""Serving observability: per-request and per-step counters + percentiles.

One :class:`ServeMetrics` instance per engine records

* per-request **TTFT** (arrival -> first token, i.e. queueing + prefill),
  end-to-end latency and time-per-token;
* per-step **decode latency**, active-batch size and queue depth;
* **bucket hit/miss** — whether a decode step was served by a shape the
  engine prewarmed (hit) or forced a new signature onto the request path
  (miss: detect/tune/bake happened while a user waited);
* **plan / prewarm counters** — detector invocations and persistent
  plan-cache hits observed during prewarm, so a fleet operator can verify
  the "pay detection once per fleet, not once per replica" economics.

``snapshot()`` returns a JSON-able dict (``save()`` writes it) — the
exported form the serving benchmark and any external scraper consume.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


def percentiles(samples: Sequence[float],
                qs: Sequence[float] = (50, 90, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p90": ..., "p99": ...}`` over ``samples`` (empty
    input -> NaNs, so reports stay structurally stable)."""
    out: Dict[str, float] = {}
    arr = np.asarray(list(samples), dtype=np.float64)
    for q in qs:
        key = f"p{q:g}"
        out[key] = float(np.percentile(arr, q)) if arr.size else float("nan")
    return out


def latency_histogram(samples: Sequence[float], bins: int = 12,
                      ) -> Dict[str, List[float]]:
    """Log-spaced latency histogram ``{"edges_s": [...], "counts": [...]}``
    (log-spaced because serving latencies are long-tailed; a linear grid
    puts every bucket boundary below the tail it should resolve)."""
    arr = np.asarray([s for s in samples if s > 0], dtype=np.float64)
    if arr.size == 0:
        return {"edges_s": [], "counts": []}
    lo, hi = float(arr.min()), float(arr.max())
    if hi <= lo:
        hi = lo * 1.001 + 1e-12
    edges = np.geomspace(lo, hi, bins + 1)
    counts, _ = np.histogram(arr, bins=edges)
    return {"edges_s": [float(e) for e in edges],
            "counts": [int(c) for c in counts]}


@dataclasses.dataclass
class _RequestRecord:
    rid: int
    arrival_t: float
    prompt_len: int = 0
    ttft_s: Optional[float] = None
    tokens: int = 0
    latency_s: Optional[float] = None


class ServeMetrics:
    """Accumulates serving telemetry; cheap enough to always leave on."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.started_t = clock()
        self.requests: Dict[int, _RequestRecord] = {}
        self.decode_step_s: List[float] = []
        self.step_batch: List[int] = []
        self.step_active: List[int] = []
        self.queue_depth: List[int] = []
        self.prefill_s: List[float] = []
        self.bucket_hits = 0
        self.bucket_misses = 0
        self.cache_resizes = 0
        self.evictions = 0
        self.admissions = 0
        self.rejected = 0
        self.prewarm: Dict[str, Any] = {}
        # resilience counters (see docs/resilience.md)
        self.decode_faults = 0         # decode steps that raised / went NaN
        self.fault_evictions = 0       # requests evicted with .failed set
        self.deadline_evictions = 0    # subset of fault_evictions: deadline
        self.admission_retries = 0     # try_admit backoff sleeps
        self.admission_timeouts = 0    # try_admit gave up within deadline
        self.request_shadow_checks = 0       # finished requests re-decoded solo
        self.request_shadow_divergences = 0  # ... whose token streams differed
        self._resilience_provider = None   # e.g. LilacFunction.resilience_info
        self._request_shadow_provider = None  # AdaptiveShadowRate.snapshot

    # -- recording hooks (called by the engine) --------------------------

    def record_submit(self, rid: int, arrival_t: float, prompt_len: int):
        self.requests[rid] = _RequestRecord(rid, arrival_t, prompt_len)

    def record_rejected(self):
        self.rejected += 1

    def record_admit(self, rid: int, prefill_s: float, ttft_s: float):
        self.admissions += 1
        self.prefill_s.append(prefill_s)
        rec = self.requests.get(rid)
        if rec is not None:
            rec.ttft_s = ttft_s

    def record_step(self, seconds: float, *, batch: int, active: int,
                    queue_depth: int, bucket_hit: bool):
        self.decode_step_s.append(seconds)
        self.step_batch.append(batch)
        self.step_active.append(active)
        self.queue_depth.append(queue_depth)
        if bucket_hit:
            self.bucket_hits += 1
        else:
            self.bucket_misses += 1

    def record_finish(self, rid: int, tokens: int, latency_s: float):
        self.evictions += 1
        rec = self.requests.get(rid)
        if rec is not None:
            rec.tokens = tokens
            rec.latency_s = latency_s

    def record_resize(self):
        self.cache_resizes += 1

    def record_prewarm(self, report: Dict[str, Any]):
        self.prewarm = dict(report)

    def record_decode_fault(self):
        self.decode_faults += 1

    def record_fault_eviction(self, reason: str):
        self.fault_evictions += 1
        if reason == "deadline":
            self.deadline_evictions += 1

    def record_admission_retries(self, n: int):
        self.admission_retries += int(n)

    def record_admission_timeout(self):
        self.admission_timeouts += 1

    def record_request_shadow(self, diverged: bool):
        self.request_shadow_checks += 1
        if diverged:
            self.request_shadow_divergences += 1

    def set_request_shadow_provider(self, fn):
        """``fn() -> dict`` (an ``AdaptiveShadowRate.snapshot``) merged into
        the snapshot's resilience section as ``request_shadow``."""
        self._request_shadow_provider = fn

    def set_resilience_provider(self, fn):
        """``fn() -> dict`` merged into the snapshot's resilience section
        (the engine wires ``LilacFunction.resilience_info`` here so one
        snapshot covers both serving- and compiler-level containment)."""
        self._resilience_provider = fn

    # -- export ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """The exported JSON snapshot (see docs/serving.md for the field
        table)."""
        done = [r for r in self.requests.values() if r.latency_s is not None]
        tpt = [r.latency_s / r.tokens for r in done if r.tokens]
        ttft = [r.ttft_s for r in self.requests.values()
                if r.ttft_s is not None]
        steps = self.decode_step_s
        occupancy = (float(np.mean(np.asarray(self.step_active)
                                   / np.maximum(self.step_batch, 1)))
                     if steps else float("nan"))
        return {
            "uptime_s": self.clock() - self.started_t,
            "requests": {
                "submitted": len(self.requests),
                "admitted": self.admissions,
                "finished": len(done),
                "rejected": self.rejected,
                "tokens_generated": int(sum(r.tokens for r in done)),
            },
            "ttft_s": percentiles(ttft),
            "time_per_token_s": percentiles(tpt),
            "decode_step_s": {**percentiles(steps),
                              "mean": (float(np.mean(steps)) if steps
                                       else float("nan")),
                              "histogram": latency_histogram(steps)},
            "prefill_s": percentiles(self.prefill_s),
            "queue_depth": percentiles(self.queue_depth, (50, 99)),
            "steps": len(steps),
            "batch_occupancy": occupancy,
            "buckets": {"hits": self.bucket_hits,
                        "misses": self.bucket_misses,
                        "cache_resizes": self.cache_resizes},
            "resilience": self._resilience_section(),
            "prewarm": self.prewarm,
        }

    def _resilience_section(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "decode_faults": self.decode_faults,
            "fault_evictions": self.fault_evictions,
            "deadline_evictions": self.deadline_evictions,
            "admission_retries": self.admission_retries,
            "admission_timeouts": self.admission_timeouts,
            "request_shadow_checks": self.request_shadow_checks,
            "request_shadow_divergences": self.request_shadow_divergences,
        }
        if self._request_shadow_provider is not None:
            try:
                out["request_shadow"] = self._request_shadow_provider()
            except Exception:
                pass
        if self._resilience_provider is not None:
            try:
                out["lilac"] = self._resilience_provider()
            except Exception:
                pass
        return out

    def save(self, path: str):
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
