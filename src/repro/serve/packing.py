"""Ragged batch packing for the sparse-MoE / grouped-matmul path.

Requests in a serving batch carry different token counts (chunked
prefill, speculative verification, mixed prompt tails).  The dense way to
batch them is per-request padding — ``(R, T_max, D)`` with every short
request padded to the longest — which wastes FLOPs and, worse, routes
*padding tokens* through the MoE router into the expert buckets.

The grouped-matmul kernel (``repro.kernels.moe_gmm``) doesn't need a
rectangle: it takes a FLAT ``(T, D)`` token batch and groups rows by
expert internally (sort + group-aligned tiles).  So the ragged pack is a
concatenation: requests' tokens are laid end to end, the single grouped
call does exactly ``sum(T_i)`` tokens of work, and per-request outputs
are sliced back out by offset.  Per-token math is independent of batch
layout, so packed outputs equal the per-request results.

``moe_ffn_ragged`` is the engine/benchmark entry point; ``pack`` /
``unpack`` are the layout helpers; ``padding_waste`` quantifies what the
rectangle would have burned.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pack(parts: Sequence[Any]) -> Tuple[jax.Array, np.ndarray]:
    """Concatenate ragged ``(T_i, ...)`` arrays into one flat array plus
    the ``(R+1,)`` offset table (``flat[offsets[i]:offsets[i+1]]`` is
    request ``i``)."""
    if not parts:
        raise ValueError("nothing to pack")
    lengths = [int(p.shape[0]) for p in parts]
    offsets = np.concatenate([[0], np.cumsum(lengths)]).astype(np.int64)
    return jnp.concatenate(list(parts), axis=0), offsets


def unpack(flat: Any, offsets: np.ndarray) -> List[Any]:
    """Inverse of :func:`pack`."""
    return [flat[int(offsets[i]):int(offsets[i + 1])]
            for i in range(len(offsets) - 1)]


def padding_waste(lengths: Sequence[int],
                  pad_to: Optional[int] = None) -> float:
    """Fraction of a padded-rectangle batch that is padding: what the
    per-request-padded layout wastes relative to the ragged pack."""
    lengths = [int(x) for x in lengths]
    if not lengths:
        return 0.0
    tmax = max(max(lengths), pad_to or 0)
    total = tmax * len(lengths)
    return 1.0 - sum(lengths) / total


def moe_ffn_ragged(xs: Sequence[Any], gates: Sequence[Any],
                   idxs: Sequence[Any], wg, wu, wd, *,
                   backend: str = "gmm",
                   interpret: Optional[bool] = None) -> List[Any]:
    """One grouped-matmul call over the ragged pack of ``R`` requests.

    ``xs[i]``: (T_i, D); ``gates[i]``/``idxs[i]``: (T_i, K).  Returns the
    per-request ``(T_i, D)`` outputs.  ``backend="gmm"`` feeds the
    existing ``moe_gmm`` Pallas kernel directly (group-by-expert packing
    happens inside: sort + aligned row tiles — zero padding rows beyond
    tile alignment); ``backend="naive"`` is the dense-dispatch oracle the
    tests compare against.
    """
    flat_x, offsets = pack(xs)
    flat_g, _ = pack(gates)
    flat_i, _ = pack(idxs)
    if backend == "gmm":
        from repro.kernels.moe_gmm import ops as gmm_ops
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        out = gmm_ops.moe_ffn(flat_x, flat_g, flat_i, wg, wu, wd,
                              interpret=interpret)
    elif backend == "naive":
        from repro.models.layers import _moe_naive_2d
        out = _moe_naive_2d(flat_x, flat_g, flat_i, wg, wu, wd)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return unpack(out, offsets)


def moe_ffn_padded(xs: Sequence[Any], gates: Sequence[Any],
                   idxs: Sequence[Any], wg, wu, wd) -> List[Any]:
    """The per-request-padded baseline: pad every request to ``T_max``,
    run the rectangle, slice the padding back off.  Routing gates of the
    padding rows are zeroed so padding cannot contaminate real tokens —
    the cost is pure wasted work, which is the point being measured."""
    from repro.models.layers import _moe_naive_2d
    lengths = [int(x.shape[0]) for x in xs]
    tmax = max(lengths)

    def padrow(a):
        return jnp.pad(a, ((0, tmax - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))

    px = jnp.stack([padrow(x) for x in xs])               # (R, Tmax, D)
    pg = jnp.stack([padrow(g) for g in gates])
    pi = jnp.stack([padrow(i) for i in idxs])
    mask = jnp.stack([jnp.arange(tmax) < n for n in lengths])
    pg = pg * mask[..., None].astype(pg.dtype)
    out = jax.vmap(lambda x, g, i: _moe_naive_2d(x, g, i, wg, wu, wd))(
        px, pg, pi)
    return [out[r, :lengths[r]] for r in range(len(xs))]
