"""Shape-bucket policy for the serving tier.

Every distinct ``(batch, cache capacity)`` pair is a distinct decode
signature — a separate trace, detection pass and baked
:class:`~repro.core.plan.ExecutablePlan`.  Continuous batching changes the
active batch every step, so unbucketed shapes would re-compile on nearly
every admit/evict.  The bucket policy quantizes both axes to a small grid:

* **batch buckets** — the decode batch is padded up to the smallest bucket
  that holds the active request count (inactive rows compute garbage that
  is never read back);
* **sequence buckets** — the KV-cache capacity is padded up to the
  smallest bucket that holds ``prompt_len + max_new_tokens`` of the
  longest active request.

The grid is exactly what :meth:`repro.serve.Engine.prewarm` bakes plans
for at startup, so a steady-state decode step never pays detect / tune /
bake on the request path.

``LILAC_SERVE_BUCKETS`` overrides the default grid with
``"<batch,...>x<seq,...>"``, e.g. ``LILAC_SERVE_BUCKETS=1,2,4x128,512``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Tuple

_ENV_BUCKETS = "LILAC_SERVE_BUCKETS"

DEFAULT_BATCH_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8)
DEFAULT_SEQ_BUCKETS: Tuple[int, ...] = (128, 256, 512, 1024)


class BucketError(ValueError):
    """Malformed bucket spec, or a request that exceeds every bucket."""


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """A sorted grid of batch and sequence-capacity buckets."""
    batch: Tuple[int, ...] = DEFAULT_BATCH_BUCKETS
    seq: Tuple[int, ...] = DEFAULT_SEQ_BUCKETS

    def __post_init__(self):
        for name, vals in (("batch", self.batch), ("seq", self.seq)):
            if not vals or any(int(v) <= 0 for v in vals):
                raise BucketError(f"{name} buckets must be positive: {vals}")
        object.__setattr__(self, "batch", tuple(sorted(set(self.batch))))
        object.__setattr__(self, "seq", tuple(sorted(set(self.seq))))

    @property
    def max_batch(self) -> int:
        return self.batch[-1]

    @property
    def max_seq(self) -> int:
        return self.seq[-1]

    def batch_bucket(self, n: int) -> int:
        """Smallest batch bucket holding ``n`` active requests."""
        for b in self.batch:
            if n <= b:
                return b
        raise BucketError(f"{n} active requests exceed the largest batch "
                          f"bucket {self.max_batch}")

    def seq_bucket(self, n: int) -> int:
        """Smallest sequence bucket with capacity for ``n`` positions."""
        for s in self.seq:
            if n <= s:
                return s
        raise BucketError(f"sequence length {n} exceeds the largest "
                          f"sequence bucket {self.max_seq}")

    def grid(self) -> Tuple[Tuple[int, int], ...]:
        """Every (batch, seq) pair — the prewarm set."""
        return tuple((b, s) for b in self.batch for s in self.seq)

    def spec(self) -> str:
        """Round-trippable ``LILAC_SERVE_BUCKETS`` form."""
        return (",".join(str(b) for b in self.batch) + "x"
                + ",".join(str(s) for s in self.seq))


def parse_buckets(spec: str) -> BucketPolicy:
    """Parse ``"1,2,4x128,256"`` into a :class:`BucketPolicy`."""
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise BucketError(
            f"bucket spec must be '<batch,...>x<seq,...>', got {spec!r}")
    try:
        batch = tuple(int(v) for v in parts[0].split(",") if v.strip())
        seq = tuple(int(v) for v in parts[1].split(",") if v.strip())
    except ValueError as e:
        raise BucketError(f"bucket spec {spec!r}: {e}") from None
    return BucketPolicy(batch=batch, seq=seq)


def default_buckets() -> BucketPolicy:
    """The env-resolved policy (``LILAC_SERVE_BUCKETS`` or the default)."""
    spec = os.environ.get(_ENV_BUCKETS)
    if spec:
        return parse_buckets(spec)
    return BucketPolicy()
