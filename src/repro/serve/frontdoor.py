"""Multi-replica front door: hashed routing, health checks, failover.

One :class:`FrontDoor` owns N :class:`~repro.serve.engine.Engine`
replicas and is the only thing traffic touches.  Its contract lifts the
single-engine never-worse guarantee to the fleet:

* **routing** — each request lands on a replica chosen by a stable hash
  of its rid over the currently-healthy set, with bounded spill to the
  next healthy replicas when the preferred queue is full;
* **health** — a replica is retired when it crashes outright (any
  exception escaping ``Engine.step``, including the injected
  ``replica_crash`` fault kind) or when its own telemetry condemns it: a
  streak of ``ServeMetrics.decode_faults``-incrementing steps longer
  than ``fault_streak`` means the replica is failing every batch it
  touches and should stop receiving traffic;
* **failover** — a retired replica is drained and its waiting + active
  requests are redistributed to survivors with bounded retry/backoff
  (the engine's ``try_admit`` path).  Partial generation is discarded:
  greedy decode is deterministic, so the survivor regenerates the
  identical token stream.  No request is silently dropped — a request
  that cannot be replaced (no healthy replica, every survivor full, or
  already past its deadline) fails loudly with ``failed="replica_lost"``;
* **shared incidents** — replicas share one process-wide
  :func:`~repro.core.resilience.shared_quarantine` store (the JsonStore
  flock merge supports concurrent writers across processes), so replica
  A's kernel quarantine immediately steers replica B's candidate
  selection.  :meth:`FrontDoor.snapshot` surfaces the fleet view:
  per-replica metrics, aggregated resilience counters, and the shared
  quarantine state.

Replica count defaults to ``LILAC_SERVE_REPLICAS`` (see
:func:`default_replicas`); every replica boots off the shared plan
cache, so replicas 2..N pay zero detection (the serving benchmark's
prewarm gate, fleet edition).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

from repro.core import faults
from repro.core import resilience as R
from repro.serve.engine import DEFAULT_MAX_STEPS, Engine, ServeConfig
from repro.serve.scheduler import Request

_ENV_REPLICAS = "LILAC_SERVE_REPLICAS"
DEFAULT_REPLICAS = 2


def default_replicas() -> int:
    """``LILAC_SERVE_REPLICAS`` (default 2, min 1)."""
    try:
        return max(1, int(os.environ.get(_ENV_REPLICAS, DEFAULT_REPLICAS)))
    except ValueError:
        return DEFAULT_REPLICAS


@dataclasses.dataclass
class _Replica:
    """Front-door bookkeeping for one engine."""
    engine: Engine
    index: int
    healthy: bool = True
    reason: Optional[str] = None          # why it was retired
    # decode-fault streak detection: consecutive front-door steps in
    # which this replica's decode_faults counter advanced
    last_decode_faults: int = 0
    fault_streak: int = 0


class FrontDoor:
    """Health-checked request router over a fleet of engine replicas.

    ``engines`` is the fleet (build them sharing one plan cache — the
    default — so later replicas boot with zero detection); or use
    :func:`build_fleet` to construct one from an arch name.

    ``fault_streak`` retires a replica whose decode_faults counter grows
    for that many *consecutive* front-door steps (0 disables telemetry
    health checks; crashes always retire).  ``max_spill`` bounds how many
    alternative healthy replicas a rejected submit tries.
    """

    def __init__(self, engines: Sequence[Engine], *,
                 fault_streak: int = 8, max_spill: Optional[int] = None,
                 clock=time.perf_counter):
        if not engines:
            raise ValueError("FrontDoor needs at least one engine")
        self.replicas = [_Replica(engine=e, index=i)
                         for i, e in enumerate(engines)]
        self.fault_streak = int(fault_streak)
        self.max_spill = max_spill
        self.clock = clock
        #: every request ever accepted by submit(), for accounting
        self.requests: List[Request] = []
        self.assignment: Dict[int, int] = {}      # rid -> replica index
        self._arrival: Dict[int, float] = {}      # rid -> first arrival_t
        # fleet counters
        self.submitted = 0
        self.rejected = 0
        self.failovers = 0          # replicas retired
        self.redistributed = 0      # requests moved to a survivor
        self.lost = 0               # requests failed with "replica_lost"

    # -- routing ---------------------------------------------------------

    def healthy_replicas(self) -> List[_Replica]:
        return [r for r in self.replicas if r.healthy]

    @staticmethod
    def _hash(rid: int) -> int:
        h = hashlib.blake2b(str(rid).encode(), digest_size=8).digest()
        return int.from_bytes(h, "big")

    def submit(self, req: Request) -> bool:
        """Route a request onto the fleet.  Returns False (and counts a
        rejection) only when every healthy replica refused it — the
        caller's backpressure signal."""
        healthy = self.healthy_replicas()
        if not healthy:
            self.rejected += 1
            return False
        start = self._hash(req.rid) % len(healthy)
        spill = len(healthy) if self.max_spill is None \
            else min(len(healthy), self.max_spill + 1)
        for k in range(spill):
            rep = healthy[(start + k) % len(healthy)]
            if rep.engine.submit(req):
                self.assignment[req.rid] = rep.index
                self._arrival.setdefault(req.rid, req.arrival_t)
                if req.rid not in (r.rid for r in self.requests):
                    self.requests.append(req)
                    self.submitted += 1
                return True
        self.rejected += 1
        return False

    # -- fleet step -------------------------------------------------------

    def step(self) -> List[Request]:
        """Advance every healthy replica one engine step.  A replica that
        raises (an uncontained failure — the engine's own containment
        keeps kernel faults from escaping, so what does escape is the
        process-death class, e.g. the injected ``replica_crash``) is
        retired and its requests fail over.  Returns the requests that
        finished this step, fleet-wide."""
        finished: List[Request] = []
        for rep in self.replicas:
            if not rep.healthy:
                continue
            try:
                faults.fail("replica_crash", f"replica{rep.index}")
                finished += rep.engine.step()
            except Exception as e:
                self._retire(rep, f"crash: {type(e).__name__}: {e}"[:200])
                continue
            self._health_check(rep)
        return finished

    def _health_check(self, rep: _Replica):
        """Telemetry-driven retirement: a replica whose decode_faults
        counter advances for ``fault_streak`` consecutive steps is failing
        every batch it touches — stop routing to it before it burns its
        whole queue."""
        if self.fault_streak <= 0:
            return
        df = rep.engine.metrics.decode_faults
        rep.fault_streak = rep.fault_streak + 1 \
            if df > rep.last_decode_faults else 0
        rep.last_decode_faults = df
        if rep.fault_streak >= self.fault_streak:
            self._retire(rep, f"unhealthy: decode-fault streak "
                              f"{rep.fault_streak}")

    def _retire(self, rep: _Replica, reason: str):
        rep.healthy = False
        rep.reason = reason
        self.failovers += 1
        self._redistribute(rep.engine.drain())

    def _redistribute(self, drained: Sequence[Request]):
        """Fail a retired replica's in-flight requests over to survivors.

        Already-finished/poisoned records pass through untouched (they are
        accounted), partial generation is reset (the survivor regenerates
        the identical greedy stream), and anything unplaceable — past its
        original deadline, no healthy replica, every survivor full — fails
        loudly with ``failed="replica_lost"``.  Nothing is dropped."""
        now = self.clock()
        for req in drained:
            if req.done:            # finished or already-poisoned record
                if req.finish_t is None:
                    req.finish_t = now
                continue
            # deadline is measured from the ORIGINAL arrival, not the
            # resubmission — failover must not extend a request's budget
            arrival = self._arrival.get(req.rid, req.arrival_t)
            if req.deadline_s is not None \
                    and now - arrival > req.deadline_s:
                self._lose(req, now)
                continue
            req.tokens.clear()
            req.ttft_s = None
            req.prefill_s = None
            if self.submit(req):
                self.redistributed += 1
            else:
                self._lose(req, now)

    def _lose(self, req: Request, now: float):
        req.failed = "replica_lost"
        req.finish_t = now
        self.lost += 1

    # -- driving ----------------------------------------------------------

    @property
    def idle(self) -> bool:
        return all(r.engine.scheduler.idle
                   for r in self.replicas if r.healthy)

    def run_until_idle(self, max_steps: int = DEFAULT_MAX_STEPS
                       ) -> List[Request]:
        out: List[Request] = []
        steps = 0
        while not self.idle:
            out += self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"fleet did not drain in {max_steps} steps (livelock?)")
        return out

    def run(self, workload=None, max_steps: int = DEFAULT_MAX_STEPS
            ) -> Dict[str, Any]:
        """Drive a workload (iterable of ``(arrival_offset_s, Request)``)
        plus anything already submitted until the fleet drains; returns
        the fleet snapshot."""
        pending = deque(sorted(workload, key=lambda ar: ar[0])
                        if workload is not None else [])
        start = self.clock()
        steps = 0
        while pending or not self.idle:
            now = self.clock() - start
            while pending and pending[0][0] <= now:
                _, req = pending.popleft()
                self.submit(req)
            if self.idle:
                if pending:
                    wait = pending[0][0] - (self.clock() - start)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                continue
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"workload did not drain in {max_steps} steps")
        return self.snapshot()

    # -- fleet telemetry ---------------------------------------------------

    def accounted(self) -> bool:
        """True iff every request ever accepted either finished or failed
        with an attributed reason — the no-silent-drops invariant."""
        return all(r.done for r in self.requests)

    def snapshot(self) -> Dict[str, Any]:
        """Fleet-level aggregation: per-replica state + metrics, summed
        resilience counters, the no-silent-drops accounting, and the
        shared quarantine store every replica reports into."""
        finished = [r for r in self.requests
                    if r.done and r.failed is None]
        failed = [r for r in self.requests if r.failed is not None]
        reasons: Dict[str, int] = {}
        for r in failed:
            reasons[r.failed] = reasons.get(r.failed, 0) + 1
        reps = []
        agg = {"decode_faults": 0, "fault_evictions": 0,
               "deadline_evictions": 0, "request_shadow_checks": 0,
               "request_shadow_divergences": 0}
        peak_mult = 1.0
        max_mult = 0.0
        for rep in self.replicas:
            m = rep.engine.metrics
            shadow = rep.engine._request_shadow.snapshot()
            peak_mult = max(peak_mult, shadow["peak_multiplier"])
            max_mult = max(max_mult, shadow["multiplier"])
            agg["decode_faults"] += m.decode_faults
            agg["fault_evictions"] += m.fault_evictions
            agg["deadline_evictions"] += m.deadline_evictions
            agg["request_shadow_checks"] += m.request_shadow_checks
            agg["request_shadow_divergences"] += m.request_shadow_divergences
            reps.append({
                "index": rep.index,
                "healthy": rep.healthy,
                "reason": rep.reason,
                "metrics": m.snapshot(),
            })
        q = R.shared_quarantine()
        return {
            "fleet": {
                "replicas": len(self.replicas),
                "healthy": len(self.healthy_replicas()),
                "submitted": self.submitted,
                "finished": len(finished),
                "failed": len(failed),
                "failed_reasons": reasons,
                "rejected": self.rejected,
                "failovers": self.failovers,
                "redistributed": self.redistributed,
                "replica_lost": self.lost,
                "all_requests_accounted_for": self.accounted(),
                "tokens_generated": int(sum(len(r.tokens)
                                            for r in finished)),
            },
            "resilience": {
                **agg,
                "request_shadow_peak_multiplier": peak_mult,
                "request_shadow_multiplier": max_mult,
            },
            "quarantine": {
                "active": len(q.active()),
                "path": str(q.path),
                "stats": q.stats.as_dict(),
            },
            "replicas": reps,
        }


def build_fleet(arch: str = "olmoe-1b-7b", *, smoke: bool = True,
                seed: int = 0, n_replicas: Optional[int] = None,
                config: Optional[ServeConfig] = None,
                moe_decode_impl: Optional[str] = "naive_flat",
                **frontdoor_kw) -> FrontDoor:
    """Build one model + params, then N engine replicas over them behind
    a front door.  All replicas share the process-wide plan cache (and
    the model/params — replicas differ only in serving state), so only
    the first prewarm can pay detection; the rest rehydrate."""
    from repro.serve.engine import build_engine
    n = n_replicas if n_replicas is not None else default_replicas()
    first = build_engine(arch, smoke=smoke, seed=seed, config=config,
                         moe_decode_impl=moe_decode_impl)
    engines = [first]
    for _ in range(1, n):
        engines.append(Engine(first.model, first.params, first.config))
    return FrontDoor(engines, **frontdoor_kw)
