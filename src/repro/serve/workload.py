"""Deterministic synthetic serving workloads (the ``train/data.py`` idiom:
a pure function of ``(seed, index)``, so benchmarks and tests replay the
exact same traffic with no reader state).

A workload is a sequence of :class:`~repro.serve.scheduler.Request`
blueprints with arrival offsets.  ``rate_rps <= 0`` means a *closed
burst*: every request arrives at t=0 (the batch-formation worst case the
static-batching baseline is measured against); a positive rate draws
exponential inter-arrival gaps (Poisson offered load).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np

from repro.serve.scheduler import Request


@dataclasses.dataclass(frozen=True)
class SyntheticWorkload:
    n_requests: int
    vocab: int
    prompt_len: Tuple[int, int] = (8, 32)        # inclusive range
    new_tokens: Tuple[int, int] = (4, 24)        # inclusive range
    rate_rps: float = 0.0                        # <= 0: closed burst at t=0
    seed: int = 0
    # when set, prompt lengths are drawn from this grid instead of the
    # prompt_len range — a small length set lets the engine prewarm every
    # prefill shape (ServeConfig.prefill_lengths) so no XLA compile lands
    # on the request path
    prompt_grid: Tuple[int, ...] = ()

    def _gap(self, j: int) -> float:
        """Exponential inter-arrival gap before request ``j`` — pure in
        ``(seed, j)``, so any prefix of the arrival process replays
        identically regardless of how it is enumerated."""
        return float(np.random.default_rng((self.seed, 7, j)).exponential(
            1.0 / self.rate_rps))

    def request_at(self, i: int) -> Tuple[float, Request]:
        """(arrival offset seconds, request) for index ``i``; pure in
        ``(seed, i)`` except the arrival prefix, which is pure in
        ``(seed, 0..i)``."""
        rng = np.random.default_rng((self.seed, i))
        if self.prompt_grid:
            plen = int(self.prompt_grid[
                int(rng.integers(0, len(self.prompt_grid)))])
        else:
            lo, hi = self.prompt_len
            plen = int(rng.integers(lo, hi + 1))
        nlo, nhi = self.new_tokens
        nnew = int(rng.integers(nlo, nhi + 1))
        prompt = rng.integers(1, max(self.vocab - 1, 2),
                              size=plen).astype(np.int32)
        arrival = 0.0
        if self.rate_rps > 0:
            arrival = float(sum(self._gap(j) for j in range(i + 1)))
        return arrival, Request(prompt=prompt, max_new_tokens=nnew)

    def requests(self) -> List[Tuple[float, Request]]:
        """All ``(arrival, request)`` pairs.  Arrivals accumulate the gap
        sequence once (O(n) total, vs. O(n^2) if each index re-summed its
        own prefix via ``request_at``)."""
        burst = dataclasses.replace(self, rate_rps=0.0)
        out: List[Tuple[float, Request]] = []
        arrival = 0.0
        for i in range(self.n_requests):
            _, req = burst.request_at(i)
            if self.rate_rps > 0:
                arrival += self._gap(i)
            out.append((arrival, req))
        return out

    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        return iter(self.requests())
