"""repro.serve — continuous-batching serving tier on baked LiLAC plans.

Public surface::

    from repro.serve import (Engine, ServeConfig, build_engine,
                             FrontDoor, build_fleet, default_replicas,
                             Scheduler, Request, SchedulerFull,
                             BucketPolicy, BucketError, parse_buckets,
                             default_buckets,
                             ServeMetrics, percentiles, latency_histogram,
                             SyntheticWorkload)

See ``docs/serving.md`` for the scheduler lifecycle, the bucket/prewarm
semantics, the multi-replica front door and the metrics schema.
"""
from repro.serve.buckets import (BucketError, BucketPolicy, default_buckets,
                                 parse_buckets)
from repro.serve.engine import Engine, ServeConfig, build_engine
from repro.serve.frontdoor import FrontDoor, build_fleet, default_replicas
from repro.serve.metrics import (ServeMetrics, latency_histogram,
                                 percentiles)
from repro.serve.packing import (moe_ffn_padded, moe_ffn_ragged, pack,
                                 padding_waste, unpack)
from repro.serve.scheduler import Request, Scheduler, SchedulerFull
from repro.serve.workload import SyntheticWorkload

__all__ = [
    "BucketError", "BucketPolicy", "default_buckets", "parse_buckets",
    "Engine", "ServeConfig", "build_engine",
    "FrontDoor", "build_fleet", "default_replicas",
    "ServeMetrics", "latency_histogram", "percentiles",
    "moe_ffn_padded", "moe_ffn_ragged", "pack", "padding_waste", "unpack",
    "Request", "Scheduler", "SchedulerFull",
    "SyntheticWorkload",
]
