"""Request scheduler: continuous (per-step admit/evict) and static batching.

The scheduler is deliberately model-free: it owns the waiting queue and
the *slot map* (which request occupies which row of the batched KV cache)
and returns pure bookkeeping decisions — which requests to admit this
step, and which ``(src, dst)`` row moves compact the active prefix after
evictions.  The :class:`~repro.serve.engine.Engine` owns the tensors and
applies those moves with the model's cache hooks; property tests drive
the scheduler against a mock model with no accelerator at all.

Invariant: active requests always occupy slots ``[0, n)`` in slot order
(``active[i]`` lives in cache row ``i``).  Evicting compacts by moving
tail survivors into the holes (swap-remove), so the decode batch can
always be served from a ``[:bucket]`` prefix of the cache.

Two admission modes:

* ``"continuous"`` — admit whenever a slot is free (the tentpole path:
  a finished request's slot is refilled on the very next step);
* ``"static"`` — the classic baseline: admit only when the batch is
  EMPTY, then run that batch until every member finishes.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np

_rid_counter = itertools.count()


class SchedulerFull(RuntimeError):
    """The waiting queue is at ``queue_capacity``; the caller must apply
    backpressure (retry later / reject upstream) instead of queueing
    unboundedly."""


@dataclasses.dataclass(eq=False)      # identity equality: requests are
class Request:                        # stateful records, not values
    """One generation request and its lifecycle record."""
    prompt: np.ndarray                    # (L,) int32 token ids
    max_new_tokens: int
    rid: int = dataclasses.field(default_factory=lambda: next(_rid_counter))
    eos_id: Optional[int] = None
    arrival_t: float = 0.0
    # per-request deadline: seconds from arrival after which the engine
    # evicts the request instead of letting it occupy a slot forever
    # (None = no deadline; a ServeConfig default may fill it at submit)
    deadline_s: Optional[float] = None
    # filled by the engine as the request progresses
    tokens: List[int] = dataclasses.field(default_factory=list)
    ttft_s: Optional[float] = None        # arrival -> first token
    finish_t: Optional[float] = None
    prefill_s: Optional[float] = None
    # non-None terminates the request abnormally (decode fault, NaN
    # logits, deadline): ``done`` turns True so the ordinary eviction
    # compaction removes it — only the poisoned request leaves the batch
    failed: Optional[str] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        if self.failed is not None:
            return True
        if len(self.tokens) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.tokens
                and self.tokens[-1] == self.eos_id)

    def time_per_token(self) -> Optional[float]:
        """End-to-end seconds per generated token (the serving-latency
        metric the benchmark gates on)."""
        if self.finish_t is None or not self.tokens:
            return None
        return (self.finish_t - self.arrival_t) / len(self.tokens)


class Scheduler:
    """Slot bookkeeping for one replica. See the module docstring."""

    def __init__(self, max_batch: int, *, queue_capacity: int = 1024,
                 mode: str = "continuous"):
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown scheduler mode {mode!r}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.queue_capacity = queue_capacity
        self.mode = mode
        self.waiting: Deque[Request] = deque()
        self.active: List[Request] = []    # index == cache slot

    # -- queue -----------------------------------------------------------

    def submit(self, req: Request):
        if len(self.waiting) >= self.queue_capacity:
            raise SchedulerFull(
                f"waiting queue at capacity ({self.queue_capacity})")
        self.waiting.append(req)

    def try_admit(self, req: Request, *, deadline: Optional[float] = None,
                  retries: int = 8, backoff_s: float = 0.005,
                  sleep: Callable[[float], None] = time.sleep,
                  clock: Callable[[], float] = time.monotonic) -> bool:
        """Bounded retry-with-backoff admission: ``submit`` with up to
        ``retries`` attempts, doubling the sleep between them, giving up
        once ``deadline`` seconds (when given) would be exceeded.  Returns
        False instead of raising :class:`SchedulerFull` — the caller
        applies upstream rejection, not an unbounded spin.  ``sleep`` and
        ``clock`` are injectable so tests (and retry-counting callers)
        never actually wait."""
        t0 = clock()
        delay = max(backoff_s, 0.0)
        for attempt in range(max(1, retries)):
            try:
                self.submit(req)
                return True
            except SchedulerFull:
                if attempt + 1 >= max(1, retries):
                    return False
                if deadline is not None \
                        and clock() - t0 + delay > deadline:
                    return False
                sleep(delay)
                delay = delay * 2 if delay > 0 else backoff_s
        return False

    def drain(self) -> List[Request]:
        """Remove and return every request this scheduler holds — active
        (slot order) then waiting (arrival order) — leaving it empty.  The
        front door calls this on a crashed replica to redistribute its
        in-flight work; partial generation state on the returned requests
        is the caller's to reset."""
        out = list(self.active) + list(self.waiting)
        self.active = []
        self.waiting.clear()
        return out

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def idle(self) -> bool:
        return not self.active and not self.waiting

    # -- per-step decisions ---------------------------------------------

    def admissions(self) -> List[Request]:
        """Pop the requests to admit this step (in arrival order).  The
        caller prefills each one and assigns it the next free slot, in
        order, immediately after the current active prefix."""
        if self.mode == "static" and self.active:
            return []                     # static: batch runs to completion
        free = self.max_batch - len(self.active)
        out: List[Request] = []
        while free > 0 and self.waiting:
            out.append(self.waiting.popleft())
            free -= 1
        self.active.extend(out)
        return out

    def evict_finished(self) -> Tuple[List[Request], List[Tuple[int, int]]]:
        """Remove every finished active request.  Returns
        ``(finished, moves)`` where ``moves`` is the ordered list of
        ``(src_slot, dst_slot)`` cache-row moves that re-compact the
        survivors into slots ``[0, n)``.  Moves are safe to apply in
        order (each source is a tail slot not previously overwritten)."""
        finished = [r for r in self.active if r.done]
        if not finished:
            return [], []
        n = len(self.active)
        n_new = n - len(finished)
        # survivors stranded past the new length move into the holes below
        # it; counts match exactly (every hole below n_new strands one
        # survivor above it), and every move's src >= n_new > dst, so no
        # move ever overwrites another move's source.
        low_holes = [i for i in range(n_new) if self.active[i].done]
        tail_survivors = [i for i in range(n_new, n)
                          if not self.active[i].done]
        moves = list(zip(sorted(tail_survivors, reverse=True), low_holes))
        for src, dst in moves:
            self.active[dst] = self.active[src]
        self.active = self.active[:n_new]
        return finished, moves
