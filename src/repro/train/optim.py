"""AdamW with fp32 master weights, cosine schedule, global-norm clipping,
and optional int8 block-quantized gradient compression with error feedback.

Distributed posture: optimizer state trees inherit the parameter sharding
(FSDP x TP), so per-chip optimizer memory is params/chips * 12 bytes.
Gradient compression quantizes per 256-element block to int8 before the
data-axis all-reduce (4x collective bytes reduction) and keeps the
quantization residual in an error-feedback buffer so the bias cancels over
steps (arXiv:1712.01887-style).  It is a config flag because its win is
collective-bound-regime dependent — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False
    compress_block: int = 256


def schedule(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_init(cfg: AdamWConfig, params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, F32)  # noqa: E731
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        # copy=True: f32 params would otherwise alias their master copy,
        # breaking donation (same buffer donated twice)
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=F32, copy=True), params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(zeros, params)
    return state


# -- gradient compression -----------------------------------------------------

def _quantize_block_int8(g: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def _dequantize_block_int8(q, scale, shape):
    deq = (q.astype(F32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return deq[:n].reshape(shape)


def compress_roundtrip(g: jax.Array, err: jax.Array, block: int):
    """Quantize(g + err) -> int8; return (dequantized, new_err).

    Under jit the all-reduce happens on the int8 payload when the caller
    arranges the psum between quantize and dequantize; in the SPMD step we
    emulate by quantizing the *global* gradient (the compiled collective
    sees the int8 operand once XLA propagates the conversion)."""
    target = g.astype(F32) + err
    q, scale = _quantize_block_int8(target, block)
    deq = _dequantize_block_int8(q, scale, g.shape)
    return deq, target - deq


# -- update --------------------------------------------------------------------

def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    if cfg.compress_grads:
        pairs = jax.tree.map(
            lambda g, e: compress_roundtrip(g, e, cfg.compress_block),
            grads, state["err"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = None

    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(F32)
    bc2 = 1 - b2 ** step.astype(F32)

    def upd(g, mu, nu, master):
        g = g.astype(F32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        master = master - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                                + cfg.weight_decay * master)
        return mu, nu, master

    triples = jax.tree.map(upd, grads, state["mu"], state["nu"],
                           state["master"])
    new_mu = jax.tree.map(lambda t: t[0], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[1], triples,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree.map(lambda t: t[2], triples,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype),
                              new_master, params)
    new_state = {"step": step, "mu": new_mu, "nu": new_nu,
                 "master": new_master}
    if new_err is not None:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
