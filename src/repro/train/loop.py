"""The training loop: jit'd step + checkpoint/restart + straggler hooks.

This is the driver used by examples/train_e2e.py and launch/train.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.factory import Model
from repro.train import optim as O
from repro.train import train_step as TS
from repro.train.checkpoint import Checkpointer
from repro.train.elastic import StragglerMonitor, heartbeat


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    resume: bool = True


def train_loop(model: Model, opt_cfg: O.AdamWConfig, loop_cfg: LoopConfig,
               batch_fn: Callable[[int], Dict[str, np.ndarray]],
               mesh=None, rules=None, params=None,
               emit: Callable[[str], None] = print) -> Dict[str, Any]:
    """Runs the loop; returns {params, opt_state, history, straggler}."""
    step_fn = TS.make_train_step(model, opt_cfg)
    mesh_ctx = None
    if mesh is not None:
        from repro import compat
        mesh_ctx = compat.use_mesh(mesh)
        mesh_ctx.__enter__()   # shard_map/constraints need the context mesh
        pshard = TS.param_shardings(model, mesh, rules)
        oshard = TS.opt_state_shardings(model, opt_cfg, mesh, rules)
        step_fn = jax.jit(step_fn,
                          in_shardings=(pshard, oshard, None),
                          out_shardings=(pshard, oshard, None),
                          donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    if params is None:
        params = model.init(jax.random.key(0))
    opt_state = O.adamw_init(opt_cfg, params)
    if mesh is not None:
        params = jax.tree.map(jax.device_put, params, pshard)
        opt_state = jax.tree.map(jax.device_put, opt_state, oshard)

    start_step = 0
    ckpt = None
    if loop_cfg.ckpt_dir:
        ckpt = Checkpointer(loop_cfg.ckpt_dir)
        latest = ckpt.latest_step() if loop_cfg.resume else None
        if latest is not None:
            state = ckpt.restore(latest, {"params": params,
                                          "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            emit(f"[restart] restored checkpoint step {latest}")

    mon = StragglerMonitor()
    history = []
    for step in range(start_step, loop_cfg.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        mon.observe(step, dt)
        history.append(float(metrics["loss"]))
        heartbeat(step, {**metrics, "sec": dt},
                  log_every=loop_cfg.log_every, emit=emit)
        if ckpt and (step + 1) % loop_cfg.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(loop_cfg.steps, {"params": params, "opt": opt_state},
                  blocking=True)
    if mesh_ctx is not None:
        mesh_ctx.__exit__(None, None, None)
    return {"params": params, "opt_state": opt_state, "history": history,
            "straggler": mon}
