"""Training/serving substrate: optimizer, steps, checkpointing, data,
fault tolerance."""
from repro.train.optim import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.train.train_step import make_train_step, make_serve_step  # noqa: F401
