"""Step function builders: train_step (loss+grad+AdamW) and serve_step
(prefill / decode), with sharding annotations for the production mesh.

These are what the dry-run lowers: jax.jit(step, in_shardings, out_shardings)
.lower(**input_specs).compile().
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.models.factory import Model
from repro.models import spec as S
from repro.train import optim as O


def batch_pspec(rules) -> P:
    b = rules.get("batch", "data")
    return P(b, None)


def make_train_step(model: Model, opt_cfg: O.AdamWConfig, *,
                    lilac_grad: bool = False, lilac_options=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With cfg.microbatches > 1 the global batch is split on the batch axis
    and gradients are accumulated in f32 over a scan — activation memory
    scales with 1/microbatches (how the 50B+ cells fit HBM); the optimizer
    applies once per step.

    ``lilac_grad=True`` routes the per-(micro)batch value_and_grad through
    ``lilac.compile``: the *gradient* jaxpr is detected and rewritten too,
    so sparse computations in the backward pass (SpMVᵀ scatters, MoE
    scatter-grad) get harnessed exactly like the forward — and once the
    rewrite resolves, the whole value_and_grad bakes into one jitted plan
    (see docs/transforms.md).  ``lilac_options`` is an optional
    :class:`repro.lilac.CompileOptions` for that compile.
    """
    mb = max(1, model.cfg.microbatches)

    value_and_grad = jax.value_and_grad(model.loss_fn)
    if lilac_grad:
        from repro import lilac
        if lilac_options is not None:
            value_and_grad = lilac.compile(value_and_grad,
                                           options=lilac_options)
        else:
            value_and_grad = lilac.compile(value_and_grad)

    # gradient sharding hint: grads live in storage sharding (FSDP x TP).
    # Without this, the scan-backward accumulator round-trips full f32
    # weight gradients through all-gathers every layer; with it, GSPMD
    # reduce-scatters each layer's partial dW over the data axis.
    def _grad_constraint(grads):
        cfg = model.cfg
        if not cfg.spmd_constraints:
            return grads
        from repro.models import spec as S
        sizes = dict(cfg.mesh_axis_sizes)
        rules = S.MULTI_POD_RULES if "pod" in sizes else S.SINGLE_POD_RULES
        ps = jax.tree.map(
            lambda s: S.spec_to_pspec_sizes(s, sizes, rules),
            model.spec, is_leaf=lambda x: isinstance(x, S.ParamSpec))
        return jax.tree.map(
            lambda g, p: jax.lax.with_sharding_constraint(g, p), grads, ps)

    def train_step(params, opt_state, batch):
        if mb == 1:
            loss, grads = value_and_grad(params, batch)
            grads = _grad_constraint(grads)
        else:
            split = jax.tree.map(
                lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]),
                batch)

            def micro(carry, mbatch):
                loss_acc, gacc = carry
                loss_i, g_i = value_and_grad(params, mbatch)
                g_i = _grad_constraint(g_i)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gacc, g_i)
                return (loss_acc + loss_i, gacc), None

            gacc0 = _grad_constraint(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), gacc0), split)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        new_params, new_state, metrics = O.adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


def make_serve_step(model: Model, kind: str):
    if kind == "prefill":
        def prefill_step(params, batch):
            logits, caches = model.prefill(params, batch)
            return logits, caches
        return prefill_step
    if kind == "decode":
        def decode_step(params, cache, tokens, pos):
            return model.decode(params, cache, tokens, pos)
        return decode_step
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Sharding trees for jit in_shardings/out_shardings
# ---------------------------------------------------------------------------

def param_shardings(model: Model, mesh: Mesh, rules):
    return S.tree_shardings(model.spec, mesh, rules)


def opt_state_shardings(model: Model, opt_cfg: O.AdamWConfig, mesh: Mesh, rules):
    ps = S.tree_pspecs(model.spec, mesh, rules)
    rep = NamedSharding(mesh, P())
    tree = {
        "step": rep,
        "mu": jax.tree.map(lambda p: NamedSharding(mesh, p), ps),
        "nu": jax.tree.map(lambda p: NamedSharding(mesh, p), ps),
        "master": jax.tree.map(lambda p: NamedSharding(mesh, p), ps),
    }
    if opt_cfg.compress_grads:
        tree["err"] = jax.tree.map(lambda p: NamedSharding(mesh, p), ps)
    return tree


def prefill_cache_shardings(model: Model, shape: ShapeConfig, mesh: Mesh,
                            rules):
    """out_shardings for the prefill-collected cache: KV tensors are
    sequence-sharded over the model axis (32k x many-layer caches would
    not fit replicated)."""
    b = rules.get("batch", "data")
    msize = mesh.shape.get("model", 1)
    from repro import compat
    with compat.use_mesh(mesh):  # prefill applies sharding constraints
        cache_abs = jax.eval_shape(
            lambda p, batch: model.prefill(p, batch)[1],
            model.abstract_params(), model.input_specs(shape))

    def shard(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        entries = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2 and leaf.shape[1] == shape.global_batch:
            entries[1] = b
        if name in ("k", "v") and len(leaf.shape) == 5 \
                and leaf.shape[2] % msize == 0:
            entries[2] = "model"
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(shard, cache_abs)


def batch_shardings(model: Model, shape: ShapeConfig, mesh: Mesh, rules):
    """Sharding tree matching input_specs(shape)."""
    b = rules.get("batch", "data")
    bsh = NamedSharding(mesh, P(b))
    tok = NamedSharding(mesh, P(b, None))
    emb = NamedSharding(mesh, P(b, None, None))
    cfg = model.cfg
    if shape.kind == "train":
        out = ({"embeds": emb} if cfg.frontend == "stub" else {"tokens": tok})
        out["labels"] = tok
        return out
    if shape.kind == "prefill":
        return {"embeds": emb} if cfg.frontend == "stub" else {"tokens": tok}
    if shape.kind == "decode":
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        baxes = b if isinstance(b, tuple) else (b,)
        dsize = 1
        for a in baxes:
            dsize *= mesh.shape[a]
        msize = mesh.shape.get("model", 1)
        batch_ok = shape.global_batch % dsize == 0
        b_entry = b if batch_ok else None

        def cache_shard(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            ndim = len(leaf.shape)
            entries = [None] * ndim
            entries[0] = b_entry
            if name in ("k", "v"):
                # (B, S, KV, hd)
                if not batch_ok:
                    entries[1] = b          # sequence-sharded cache (SP)
                if leaf.shape[2] % msize == 0:
                    entries[2] = "model"
                elif (model.cfg.decode_cache_seq_shard
                        and leaf.shape[1] % msize == 0):
                    # MQA: kv unshardable -> ring-style sequence sharding
                    entries[1] = "model"
            elif name == "ssm":             # (B, di, N)
                if leaf.shape[1] % msize == 0:
                    entries[1] = "model"
            elif name == "conv":            # (B, K-1, di)
                if leaf.shape[2] % msize == 0:
                    entries[2] = "model"
            elif name == "s":               # rwkv (B, H, dh, dh)
                if leaf.shape[1] % msize == 0:
                    entries[1] = "model"
            return NamedSharding(mesh, P(*entries))

        tok_dec = NamedSharding(mesh, P(b_entry, None))
        return {"tokens": tok_dec,
                "pos": NamedSharding(mesh, P()),
                "cache": jax.tree_util.tree_map_with_path(
                    cache_shard, cache_abs)}
    raise ValueError(shape.kind)
