"""Elastic scaling + straggler mitigation hooks.

Design for 1000+ nodes (what runs here is the single-process realization of
the same control flow; multi-host specifics are marked):

* Node failure      -> jax.distributed raises / barrier timeout -> the
  launcher re-execs the job with the surviving slice list; on restart the
  loop restores the latest atomic checkpoint (checkpoint.py) and the data
  pipeline resumes purely from (seed, step).
* Elastic resize    -> ``plan_remesh`` picks the largest (data, model) mesh
  that fits the new device count while keeping the model axis intact;
  restore() reshards the checkpoint onto the new mesh (tested cross-shape
  in tests/test_checkpoint.py).
* Stragglers        -> per-step wall-time EWMA; steps slower than
  ``straggler_factor``x the EWMA are logged and counted. On real multi-pod
  deployments the hook escalates to the controller which drains the slow
  slice (here: callback + counter, exercised in tests). Data is dispatched
  with one step of lookahead (async host->device) so a slow host overlaps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    alpha: float = 0.2
    ewma: Optional[float] = None
    slow_steps: int = 0
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    def observe(self, step: int, seconds: float) -> bool:
        if self.ewma is None:
            self.ewma = seconds
            return False
        slow = seconds > self.factor * self.ewma
        if slow:
            self.slow_steps += 1
            if self.on_straggler is not None:
                self.on_straggler(step, seconds, self.ewma)
        # EWMA excludes outliers so one straggler doesn't mask the next
        if not slow:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return slow


def plan_remesh(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving device count, keeping
    the model axis (weights layout) intact so restore is a pure reshard."""
    assert n_devices >= model_parallel, (n_devices, model_parallel)
    data = n_devices // model_parallel
    return data, model_parallel


def heartbeat(step: int, metrics, log_every: int = 10,
              emit: Callable[[str], None] = print):
    if step % log_every == 0:
        parts = [f"step={step}"]
        for k, v in metrics.items():
            try:
                parts.append(f"{k}={float(np.asarray(v)):.5f}")
            except Exception:
                pass
        emit("  ".join(parts))
