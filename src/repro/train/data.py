"""Deterministic, resumable data pipeline.

Fault-tolerance contract: the pipeline is a pure function of (seed, step),
so restart-from-checkpoint at step N reproduces exactly the batches N+1...
with no reader state to persist.  Two sources:

  * SyntheticLM — structured pseudo-text (Zipfian tokens with short-range
    correlations so a real model can overfit it in a few hundred steps)
  * MemmapCorpus — a token file on disk, sampled by deterministic offsets
    (the production path; per-host slices by process_index for multi-host)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        # zipfian unigrams + markov-ish repetition for learnable structure
        base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
        tokens = (base % (self.vocab - 2)) + 1
        rep = rng.random((B, S)) < 0.3
        shifted = np.roll(tokens, 1, axis=1)
        tokens = np.where(rep, shifted, tokens)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class SyntheticEmbeds:
    """Stub-frontend batches (vlm/audio): precomputed frame/patch embeds."""
    d_model: int
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, 1, step))
        B, S = self.global_batch, self.seq_len
        emb = rng.standard_normal((B, S, self.d_model)).astype(np.float32)
        labels = rng.integers(0, self.vocab, (B, S)).astype(np.int32)
        return {"embeds": emb, "labels": labels}


class MemmapCorpus:
    """Token corpus in a flat .bin (int32); deterministic window sampling."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 seed: int = 0,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.pi = process_index if process_index is not None \
            else jax.process_index()
        self.pc = process_count if process_count is not None \
            else jax.process_count()
        assert global_batch % self.pc == 0
        self.local_batch = global_batch // self.pc

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step, self.pi))
        n = self.tokens.shape[0] - self.seq_len - 1
        starts = rng.integers(0, n, size=self.local_batch)
        toks = np.stack([self.tokens[s:s + self.seq_len] for s in starts])
        labels = np.stack([self.tokens[s + 1:s + self.seq_len + 1]
                           for s in starts])
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}


def write_corpus(path: str, tokens: np.ndarray):
    tokens.astype(np.int32).tofile(path)
