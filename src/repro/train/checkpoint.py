"""Sharded, async, atomic checkpointing with elastic restore.

Fault-tolerance contract (1000+-node posture):
  * atomic commit — writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after every shard file and the metadata manifest are
    fsync'd; a crashed writer leaves no half-checkpoint that restore could
    pick up.
  * sharded layout — every host writes only the addressable shards of its
    devices (single-process here, but the addressable_shards API is used so
    the code is multi-host correct).
  * async — serialization happens on a background thread off the step
    critical path; ``wait()`` joins before the next save or exit.
  * elastic restore — the manifest stores the *global* array shapes +
    dtypes; ``restore`` takes the *target* sharding tree, so a checkpoint
    saved on mesh (4,2) restores onto (2,4) (or a different device count)
    by resharding on load.  This is the restart-after-resize path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return names, vals, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot to host memory synchronously (consistency point), then
        write to disk on a background thread."""
        self.wait()
        names, vals, _ = _flatten_with_names(tree)
        host_vals = [np.asarray(v) for v in vals]   # device->host copy now
        meta = {
            "step": step,
            "arrays": [{"name": n, "shape": list(v.shape),
                        "dtype": str(v.dtype)}
                       for n, v in zip(names, host_vals)],
        }

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for n, v in zip(names, host_vals):
                fname = os.path.join(tmp, n.replace("/", "__") + ".npy")
                with open(fname, "wb") as f:
                    np.save(f, v)
                    f.flush()
                    os.fsync(f.fileno())
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)               # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.available_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def available_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, d,
                                                    "manifest.json")):
                out.append(int(d.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Load into the structure of ``target_tree``; if ``shardings`` is
        given (tree of NamedSharding) the arrays are placed/resharded onto
        it — the elastic-restart path."""
        self.wait()
        d = os.path.join(self.dir, f"step_{step}")
        names, vals, treedef = _flatten_with_names(target_tree)
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(shardings)[0]
        else:
            shard_flat = [None] * len(names)
        out = []
        for n, tmpl, sh in zip(names, vals, shard_flat):
            fname = os.path.join(d, n.replace("/", "__") + ".npy")
            arr = np.load(fname)
            want_dtype = jnp.dtype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr.dtype
            if arr.dtype.kind == "V":
                # ml_dtypes (bfloat16/fp8) round-trip through .npy as raw
                # void records; reinterpret with the target dtype.
                arr = arr.view(want_dtype)
            else:
                arr = arr.astype(want_dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
