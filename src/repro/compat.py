"""Version-guarded shims over jax API drift (sharding / shard_map).

The repo pins jax 0.4.37 (CI) but several sharding APIs moved under it:
``jax.sharding.AxisType``, ``jax.set_mesh``, ``jax.shard_map`` and
``jax.make_mesh(axis_types=...)`` only exist in newer jax, while the old
spellings (``Mesh`` as a context manager, ``jax.experimental.shard_map``
with ``check_rep``) are deprecated or removed there.  Everything in this
repo uses Auto axes — exactly the implicit behavior of the old API — so
these shims select whichever spelling the installed jax understands
instead of hard-failing on either side of the pin.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicitly-Auto axis types when the installed
    jax knows about axis types; on older jax (no ``AxisType``) every mesh
    axis is implicitly Auto, so the plain call is semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` where it exists; the ``Mesh`` object's own context
    manager on older jax (what ``set_mesh`` wraps for Auto meshes)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def _ambient_mesh():
    """The mesh installed by :func:`use_mesh` on old jax (the thread-local
    physical mesh that ``Mesh.__enter__`` sets)."""
    from jax._src import mesh as mesh_lib

    mesh = mesh_lib.thread_resources.env.physical_mesh
    if mesh.empty:
        raise RuntimeError(
            "compat.shard_map needs an ambient mesh on this jax version: "
            "wrap the call in `with compat.use_mesh(mesh):`")
    return mesh


def shard_map(f, *, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` over the ambient mesh.  On older jax this lowers
    to ``jax.experimental.shard_map.shard_map`` with the context-manager
    mesh passed explicitly and ``check_vma`` renamed to ``check_rep``."""
    new = getattr(jax, "shard_map", None)
    if new is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return new(f, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as old_shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return old_shard_map(f, _ambient_mesh(), in_specs=in_specs,
                         out_specs=out_specs, **kw)
