"""Reference sparse ops in pure jnp.

These are the semantic oracles for the Pallas kernels AND the `jnp:*`
harness backends that the LiLAC rewriter can splice in (the "MKL on CPU"
analogue — XLA-native, no hand tiling).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.formats import BCSR, COO, CSR, ELL, JDS


def row_ids_from_row_ptr(row_ptr: jax.Array, nnz: int) -> jax.Array:
    """Expand CSR row_ptr to per-nnz row ids (static nnz for jit)."""
    rows = row_ptr.shape[0] - 1
    return jnp.repeat(
        jnp.arange(rows, dtype=jnp.int32),
        jnp.diff(row_ptr),
        total_repeat_length=nnz,
    )


def spmv_csr_ref(csr: CSR, vec: jax.Array) -> jax.Array:
    """output[i] = sum_{row_ptr[i] <= j < row_ptr[i+1]} val[j] * vec[col[j]]"""
    row = row_ids_from_row_ptr(csr.row_ptr, csr.nnz)
    prod = csr.val * vec[csr.col_ind]
    return jax.ops.segment_sum(prod, row, num_segments=csr.rows)


def spmv_coo_ref(coo: COO, vec: jax.Array) -> jax.Array:
    prod = coo.val * vec[coo.col]
    return jax.ops.segment_sum(prod, coo.row, num_segments=coo.shape[0])


def spmv_ell_ref(ell: ELL, vec: jax.Array) -> jax.Array:
    """Padded-row SpMV; un-permutes at the end."""
    acc = jnp.sum(ell.val * vec[ell.col], axis=1)
    out = jnp.zeros((ell.shape[0],), acc.dtype)
    return out.at[ell.perm].set(acc)


def spmv_jds_ref(jds: JDS, vec: jax.Array) -> jax.Array:
    """Paper Fig. 5 semantics:

    output[perm[i]] = sum(0 <= j < nzcnt[i])
        val[jd_ptr[j] + i] * vector[col_ind[jd_ptr[j] + i]]
    """
    rows = jds.shape[0]
    max_nnz = jds.jd_ptr.shape[0] - 1
    if max_nnz == 0 or jds.val.shape[0] == 0:   # all-zero matrix
        return jnp.zeros((rows,), jds.val.dtype)
    i = jnp.arange(rows, dtype=jnp.int32)

    def body(j, acc):
        idx = jds.jd_ptr[j] + i
        live = jds.nzcnt > j
        idx = jnp.where(live, idx, 0)
        contrib = jnp.where(
            live, jds.val[idx] * vec[jds.col_ind[idx]], 0.0
        ).astype(acc.dtype)
        return acc + contrib

    acc = jax.lax.fori_loop(0, max_nnz, body, jnp.zeros((rows,), jds.val.dtype))
    out = jnp.zeros((rows,), acc.dtype)
    return out.at[jds.perm].set(acc)


def bcsr_spmm_ref(bcsr: BCSR, dense: jax.Array) -> jax.Array:
    """(rows, cols) block-sparse @ (cols, n) dense -> (rows, n)."""
    bm, bn = bcsr.block_shape
    rows, cols = bcsr.shape
    n = dense.shape[1]
    block_rows = rows // bm
    nnzb = bcsr.nblocks
    # block-row id of every stored block
    brow = row_ids_from_row_ptr(bcsr.block_rowptr, nnzb)
    rhs = dense.reshape(cols // bn, bn, n)[bcsr.block_col]       # (nnzb, bn, n)
    prod = jnp.einsum("kij,kjn->kin", bcsr.blocks, rhs)          # (nnzb, bm, n)
    out = jax.ops.segment_sum(prod, brow, num_segments=block_rows)
    return out.reshape(rows, n)


def spmm_csr_ref(csr: CSR, dense: jax.Array) -> jax.Array:
    """CSR @ dense (cols, n) -> (rows, n)."""
    row = row_ids_from_row_ptr(csr.row_ptr, csr.nnz)
    prod = csr.val[:, None] * dense[csr.col_ind]
    return jax.ops.segment_sum(prod, row, num_segments=csr.rows)
