"""Sparse matrix containers (pytrees) and host-side constructors.

These mirror the storage formats of the paper (§3.2 Fig. 4/5):

* CSR  — val / col_ind / row_ptr (paper Fig. 4)
* COO  — val / row / col
* JDS  — perm / nzcnt / jd_ptr / val / col_ind (paper Fig. 5)
* ELL  — row-padded (TPU adaptation of JDS: after the nnz row sort, rows are
         padded to a lane-aligned width so slabs are dense VMEM tiles)
* BCSR — block compressed sparse row with dense (bm, bn) blocks sized for the
         MXU; the TPU-native format for the Pallas matmul kernels.

All containers are registered pytrees so they flow through jit/shard_map.
Static metadata (shape, block size) lives in aux_data.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _register(cls, data_fields, meta_fields):
    def flatten(obj):
        return (
            tuple(getattr(obj, f) for f in data_fields),
            tuple(getattr(obj, f) for f in meta_fields),
        )

    def unflatten(meta, data):
        kwargs = dict(zip(data_fields, data))
        kwargs.update(dict(zip(meta_fields, meta)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row. row_ptr has length rows+1."""

    val: jax.Array      # (nnz,)
    col_ind: jax.Array  # (nnz,) int32
    row_ptr: jax.Array  # (rows+1,) int32
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.val.shape[0]

    @property
    def rows(self) -> int:
        return self.shape[0]

    @property
    def cols(self) -> int:
        return self.shape[1]

    def todense(self) -> jax.Array:
        rows, cols = self.shape
        row_ids = jnp.repeat(
            jnp.arange(rows, dtype=jnp.int32),
            jnp.diff(self.row_ptr),
            total_repeat_length=self.nnz,
        )
        out = jnp.zeros((rows, cols), self.val.dtype)
        return out.at[row_ids, self.col_ind].add(self.val)


_register(CSR, ("val", "col_ind", "row_ptr"), ("shape",))


@dataclasses.dataclass(frozen=True)
class COO:
    val: jax.Array  # (nnz,)
    row: jax.Array  # (nnz,) int32
    col: jax.Array  # (nnz,) int32
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.val.shape[0]


_register(COO, ("val", "row", "col"), ("shape",))


@dataclasses.dataclass(frozen=True)
class JDS:
    """Jagged diagonal storage (paper Fig. 5).

    Rows sorted by decreasing nnz; jagged diagonal j holds the j-th nonzero
    of every row that has one. jd_ptr[j] offsets into val/col_ind.
    """

    perm: jax.Array     # (rows,) int32 — perm[i] = original row of sorted row i
    nzcnt: jax.Array    # (rows,) int32 — nnz of sorted row i
    jd_ptr: jax.Array   # (max_nnz+1,) int32
    val: jax.Array      # (nnz,)
    col_ind: jax.Array  # (nnz,) int32
    shape: Tuple[int, int]


_register(JDS, ("perm", "nzcnt", "jd_ptr", "val", "col_ind"), ("shape",))


@dataclasses.dataclass(frozen=True)
class ELL:
    """Row-padded format (TPU slab adaptation of JDS).

    val/col (rows, width); padding entries have val=0, col=0 (valid gather).
    ``perm`` is the JDS-style row sort (identity if unsorted) so that slabs
    of consecutive rows have similar nnz and padding waste is bounded.
    """

    val: jax.Array   # (rows, width)
    col: jax.Array   # (rows, width) int32
    perm: jax.Array  # (rows,) int32
    shape: Tuple[int, int]

    @property
    def width(self) -> int:
        return self.val.shape[1]


_register(ELL, ("val", "col", "perm"), ("shape",))


@dataclasses.dataclass(frozen=True)
class BCSR:
    """Block CSR with dense (bm, bn) blocks — the MXU-native sparse format.

    blocks:       (nblocks, bm, bn) dense tiles
    block_col:    (nblocks,) int32 — block-column index of each tile
    block_rowptr: (block_rows+1,) int32 — CSR structure over tile rows
    """

    blocks: jax.Array
    block_col: jax.Array
    block_rowptr: jax.Array
    shape: Tuple[int, int]
    block_shape: Tuple[int, int]

    @property
    def nblocks(self) -> int:
        return self.blocks.shape[0]

    @property
    def block_rows(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @functools.cached_property
    def all_block_rows_nonempty(self) -> bool:
        """True when every block-row owns at least one stored tile.  Gates
        in-kernel epilogue fusion (the last-visit trigger fires per
        block-row); computed once per packed matrix — a host sync here
        instead of on every kernel call."""
        return bool(np.all(np.diff(np.asarray(self.block_rowptr)) > 0))

    def todense(self) -> jax.Array:
        bm, bn = self.block_shape
        rows, cols = self.shape
        out = np.zeros((rows, cols), dtype=np.asarray(self.blocks).dtype)
        bp = np.asarray(self.block_rowptr)
        bc = np.asarray(self.block_col)
        blk = np.asarray(self.blocks)
        for br in range(self.block_rows):
            for k in range(int(bp[br]), int(bp[br + 1])):
                out[br * bm:(br + 1) * bm, bc[k] * bn:(bc[k] + 1) * bn] = blk[k]
        return jnp.asarray(out)


_register(BCSR, ("blocks", "block_col", "block_rowptr"), ("shape", "block_shape"))


# ---------------------------------------------------------------------------
# Host-side constructors (numpy; used by data loading and tests).
# ---------------------------------------------------------------------------

def csr_from_dense(dense) -> CSR:
    d = np.asarray(dense)
    rows, cols = d.shape
    r, c = np.nonzero(d)           # row-major order == CSR order
    counts = np.bincount(r, minlength=rows)
    row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    return CSR(
        val=jnp.asarray(d[r, c]),
        col_ind=jnp.asarray(c.astype(np.int32)),
        row_ptr=jnp.asarray(row_ptr),
        shape=(rows, cols),
    )


def coo_from_dense(dense) -> COO:
    d = np.asarray(dense)
    r, c = np.nonzero(d)
    return COO(
        val=jnp.asarray(d[r, c]),
        row=jnp.asarray(r.astype(np.int32)),
        col=jnp.asarray(c.astype(np.int32)),
        shape=d.shape,
    )


def jds_from_csr(csr: CSR) -> JDS:
    """Paper Fig. 5: sort rows by decreasing nnz, store jagged diagonals."""
    row_ptr = np.asarray(csr.row_ptr)
    val = np.asarray(csr.val)
    col = np.asarray(csr.col_ind)
    rows = csr.rows
    nnz_per_row = np.diff(row_ptr)
    perm = np.argsort(-nnz_per_row, kind="stable").astype(np.int32)
    nzcnt = nnz_per_row[perm].astype(np.int32)
    max_nnz = int(nzcnt[0]) if rows else 0
    jd_val, jd_col, jd_ptr = [], [], [0]
    for j in range(max_nnz):
        for i in range(rows):
            if nzcnt[i] > j:
                p = row_ptr[perm[i]] + j
                jd_val.append(val[p])
                jd_col.append(col[p])
            else:
                break  # rows sorted by decreasing nnz
        jd_ptr.append(len(jd_val))
    return JDS(
        perm=jnp.asarray(perm),
        nzcnt=jnp.asarray(nzcnt),
        jd_ptr=jnp.asarray(np.array(jd_ptr, dtype=np.int32)),
        val=jnp.asarray(np.array(jd_val, dtype=val.dtype)),
        col_ind=jnp.asarray(np.array(jd_col, dtype=np.int32)),
        shape=csr.shape,
    )


def ell_from_csr(csr: CSR, width: int | None = None, sort_rows: bool = True,
                 lane: int = 8) -> ELL:
    """TPU slab format: pad each row to ``width`` (lane-aligned).

    ``sort_rows`` applies the JDS permutation so padding waste within a slab
    is bounded; the permutation is part of the format (a marshaled invariant).
    """
    row_ptr = np.asarray(csr.row_ptr)
    valv = np.asarray(csr.val)
    colv = np.asarray(csr.col_ind)
    rows = csr.rows
    nnz_per_row = np.diff(row_ptr)
    if sort_rows:
        perm = np.argsort(-nnz_per_row, kind="stable").astype(np.int32)
    else:
        perm = np.arange(rows, dtype=np.int32)
    w = int(nnz_per_row.max()) if rows and nnz_per_row.size else 0
    if width is not None:
        w = max(w, width)
    w = max(lane, ((w + lane - 1) // lane) * lane)
    val = np.zeros((rows, w), dtype=valv.dtype)
    col = np.zeros((rows, w), dtype=np.int32)
    for i in range(rows):
        src = perm[i]
        n = int(nnz_per_row[src])
        val[i, :n] = valv[row_ptr[src]:row_ptr[src] + n]
        col[i, :n] = colv[row_ptr[src]:row_ptr[src] + n]
    return ELL(val=jnp.asarray(val), col=jnp.asarray(col),
               perm=jnp.asarray(perm), shape=csr.shape)


def bcsr_from_dense(dense, block_shape=(8, 128)) -> BCSR:
    """Tile a dense matrix and keep only nonzero tiles (MXU-native)."""
    d = np.asarray(dense)
    bm, bn = block_shape
    rows, cols = d.shape
    assert rows % bm == 0 and cols % bn == 0, (d.shape, block_shape)
    blocks, block_col, block_rowptr = [], [], [0]
    for br in range(rows // bm):
        row_has_block = False
        for bc in range(cols // bn):
            tile = d[br * bm:(br + 1) * bm, bc * bn:(bc + 1) * bn]
            if np.any(tile != 0):
                blocks.append(tile)
                block_col.append(bc)
                row_has_block = True
        if not row_has_block:
            # keep one explicit zero block per empty block-row so the Pallas
            # kernel's revisiting accumulator always initializes the output
            blocks.append(np.zeros((bm, bn), dtype=d.dtype))
            block_col.append(0)
        block_rowptr.append(len(blocks))
    return BCSR(
        blocks=jnp.asarray(np.stack(blocks)),
        block_col=jnp.asarray(np.array(block_col, dtype=np.int32)),
        block_rowptr=jnp.asarray(np.array(block_rowptr, dtype=np.int32)),
        shape=(rows, cols),
        block_shape=(bm, bn),
    )
