"""Deterministic random sparse matrix generators (host-side numpy).

Used by tests, benchmarks and the graph-analytics examples; stands in for
the UFlorida collection matrices of the paper's evaluation (§5).
"""
from __future__ import annotations

import numpy as np

from repro.sparse.formats import BCSR, CSR, bcsr_from_dense, csr_from_dense


def random_dense_sparse(rows: int, cols: int, density: float, seed: int = 0,
                        dtype=np.float32, skew: float = 0.0) -> np.ndarray:
    """Dense array with ~density nonzeros; ``skew`` > 0 gives power-law rows
    (graph-like degree distribution, the hard case for padded formats)."""
    rng = np.random.default_rng(seed)
    if skew > 0:
        # per-row density drawn from a Pareto-ish distribution
        row_density = density * (1.0 + rng.pareto(1.0 + 1.0 / skew, rows))
        row_density = np.minimum(row_density, 1.0)
        mask = rng.random((rows, cols)) < row_density[:, None]
    else:
        mask = rng.random((rows, cols)) < density
    vals = rng.standard_normal((rows, cols)).astype(dtype)
    return np.where(mask, vals, 0).astype(dtype)


def random_csr(rows: int, cols: int, density: float = 0.05, seed: int = 0,
               dtype=np.float32, skew: float = 0.0) -> CSR:
    return csr_from_dense(random_dense_sparse(rows, cols, density, seed, dtype, skew))


def random_bcsr(rows: int, cols: int, block_shape=(8, 128),
                block_density: float = 0.2, seed: int = 0,
                dtype=np.float32) -> BCSR:
    rng = np.random.default_rng(seed)
    bm, bn = block_shape
    mask = rng.random((rows // bm, cols // bn)) < block_density
    d = rng.standard_normal((rows, cols)).astype(dtype)
    d = d * np.kron(mask, np.ones((bm, bn))).astype(dtype)
    return bcsr_from_dense(d, block_shape)


def random_graph_csr(nodes: int, avg_degree: float = 8.0, seed: int = 0,
                     dtype=np.float32) -> CSR:
    """Erdos-Renyi-ish adjacency in CSR, row-stochastic values (PageRank)."""
    rng = np.random.default_rng(seed)
    density = min(1.0, avg_degree / nodes)
    mask = rng.random((nodes, nodes)) < density
    np.fill_diagonal(mask, False)
    d = mask.astype(dtype)
    deg = d.sum(axis=0, keepdims=True)
    d = np.divide(d, np.maximum(deg, 1.0), dtype=dtype)  # column-stochastic
    return csr_from_dense(d)
