"""Format conversions used as marshaled invariants (LiLAC-How INPUTs).

Each conversion is expensive relative to one SpMV — exactly the paper's
cudaMemcpy / SparseX-tuning situation — so the marshaling cache (core.marshal)
memoizes them keyed on the source arrays' fingerprints.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import (
    BCSR, CSR, ELL, JDS, bcsr_from_dense, ell_from_csr, jds_from_csr,
)


def infer_cols(col_ind, explicit_cols: int | None = None) -> int:
    """The paper's `cols = max(col_ind)+1` invariant (Fig. 7 lines 2-5 /
    Fig. 9 `Maximum` INPUT)."""
    if explicit_cols is not None:
        return int(explicit_cols)
    c = np.asarray(col_ind)
    return int(c.max()) + 1 if c.size else 0


def csr_to_ell(csr: CSR, **kw) -> ELL:
    return ell_from_csr(csr, **kw)


def csr_to_jds(csr: CSR) -> JDS:
    return jds_from_csr(csr)


def csr_to_bcsr(csr: CSR, block_shape=(8, 128)) -> BCSR:
    dense = np.asarray(csr.todense())
    bm, bn = block_shape
    rows, cols = dense.shape
    pr = (-rows) % bm
    pc = (-cols) % bn
    if pr or pc:
        dense = np.pad(dense, ((0, pr), (0, pc)))
    return bcsr_from_dense(dense, block_shape)


def csr_to_dense(csr: CSR):
    return csr.todense()


def pad_vector(vec, to: int):
    v = jnp.asarray(vec)
    if v.shape[0] < to:
        v = jnp.pad(v, (0, to - v.shape[0]))
    return v
