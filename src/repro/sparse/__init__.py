"""Sparse matrix substrate: formats, conversions, reference ops.

Formats mirror the paper's §3.2 (CSR, JDS, COO) plus the TPU-native
adaptations (ELL row-slabs, BCSR 128x128 MXU tiles).
"""
from repro.sparse.formats import (
    CSR,
    COO,
    ELL,
    JDS,
    BCSR,
    bcsr_from_dense,
    coo_from_dense,
    csr_from_dense,
    ell_from_csr,
    jds_from_csr,
)
from repro.sparse.ops import (
    spmv_csr_ref,
    spmv_coo_ref,
    spmv_ell_ref,
    spmv_jds_ref,
    bcsr_spmm_ref,
)
from repro.sparse.random import random_csr, random_bcsr

__all__ = [
    "CSR", "COO", "ELL", "JDS", "BCSR",
    "csr_from_dense", "coo_from_dense", "ell_from_csr", "jds_from_csr",
    "bcsr_from_dense",
    "spmv_csr_ref", "spmv_coo_ref", "spmv_ell_ref", "spmv_jds_ref",
    "bcsr_spmm_ref",
    "random_csr", "random_bcsr",
]
