"""repro.lilac — the LiLAC declarative spec API, in one namespace.

The paper's workflow (§3, Fig. 3): a library implementer writes a one-off
LiLAC description — a What-clause (COMPUTATION) plus a How-clause (HARNESS:
platforms, formats, marshaled inputs with repack clauses, persistent state
with BeforeFirstExecution/AfterLastExecution hooks) — and application code
is accelerated without modification through a single compiler entry point.

Application authors::

    from repro import lilac

    fast = lilac.compile(step, mode="host", policy="autotune")
    fast(val, col, row_ptr, vec)        # detected, rewritten, tuned

Library implementers (spec + function = a new backend)::

    @lilac.harness('''
    HARNESS mylib.spmv implements spmv_csr
      formats CSR;
      host_only;
      marshal packed = my_pack(a, colidx, rowstr|rowidx);
    ''')
    def mylib_spmv(binding, ctx, *, packed):
        return mylib.spmv(packed, binding["iv"])

``lilac_optimize`` / ``lilac_accelerate`` remain as deprecation shims:
``lilac_optimize(fn)`` is ``lilac.compile(fn, mode="trace")`` and
``lilac_accelerate(fn)`` is ``lilac.compile(fn, mode="host")``.
"""
from repro.core import faults
from repro.core.harness import (REGISTRY, CallCtx, DuplicateHarnessError,
                                Harness, HarnessRegistry)
from repro.core.resilience import (Containment, ContainmentStats,
                                   QuarantineStore, ReferenceFallback,
                                   default_quarantine_path, outputs_close,
                                   reset_shared_quarantine,
                                   shared_quarantine)
from repro.core.marshal import (FORMATS, GRAPH, SOURCES, ConversionEdge,
                                ConversionGraph, DataPlane, MarshalingCache,
                                MarshalPolicy, ReadObject, SparseFormat,
                                TrackedArray, edge, register_format,
                                register_source, version_token)
from repro.core.pass_manager import (CompileOptions, LilacDeprecationWarning,
                                     LilacFunction, compile, lilac_accelerate,
                                     lilac_optimize)
from repro.core.plan import (ExecutablePlan, PlanBakeError, PlanCache,
                             PlanDonationError, default_plan_cache_path)
from repro.core.spec import (HOOKS, REPACKS, VJPS, SpecError, build_harnesses,
                             harness, hook, register_builtins, register_spec,
                             repack, vjp)
from repro.core.rewrite import apply_epilogue
from repro.core.what_lang import (BUILTIN_SPECS, BUILTINS, Computation,
                                  Constraint, HarnessDecl, MarshalClause,
                                  ParseError, Spec, TuneClause, VjpClause,
                                  enumerate_schedules, parse, parse_harness,
                                  parse_spec)

__all__ = [
    # entry point
    "compile", "CompileOptions", "LilacFunction",
    # spec surface
    "harness", "repack", "hook", "vjp", "register_spec", "register_builtins",
    "build_harnesses", "SpecError", "REPACKS", "HOOKS", "VJPS",
    # language
    "parse", "parse_spec", "parse_harness", "ParseError", "Spec",
    "Computation", "HarnessDecl", "MarshalClause", "TuneClause", "VjpClause",
    "Constraint", "enumerate_schedules", "BUILTINS", "BUILTIN_SPECS",
    # tunable schedules / epilogues
    "apply_epilogue",
    # executable plans (steady-state dispatch)
    "ExecutablePlan", "PlanCache", "PlanBakeError", "PlanDonationError",
    "default_plan_cache_path",
    # registry / runtime
    "REGISTRY", "Harness", "HarnessRegistry", "DuplicateHarnessError",
    "CallCtx", "MarshalingCache", "ReadObject", "TrackedArray",
    "version_token",
    # data plane
    "DataPlane", "MarshalPolicy", "SparseFormat", "ConversionEdge",
    "ConversionGraph", "FORMATS", "GRAPH", "SOURCES", "edge",
    "register_format", "register_source",
    # resilience (fault containment, quarantine, chaos injection)
    "faults", "Containment", "ContainmentStats", "QuarantineStore",
    "ReferenceFallback", "default_quarantine_path", "outputs_close",
    "shared_quarantine", "reset_shared_quarantine",
    # deprecated shims
    "lilac_optimize", "lilac_accelerate", "LilacDeprecationWarning",
]
