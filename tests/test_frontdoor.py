"""Front-door tests: hashed routing, replica-crash failover with zero
silent drops, telemetry-driven health checks, request-level shadow
verification, the adaptive shadow-rate controller, and the cross-replica
quarantine-sharing (concurrent-writer JsonStore merge) invariant.

All fleet mechanics run on the mock rolling-hash model from
``test_serve`` — the streams are deterministic, so "the survivor
regenerates the identical tokens" is checked exactly, with no
accelerator in the loop.
"""
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.core import faults
from repro.core import resilience as R
from repro.serve import (BucketPolicy, Engine, FrontDoor, Request,
                         ServeConfig, default_replicas)

from test_serve import MockModel, _solo_stream

pytestmark = []


def _mock_fleet(n=3, *, fault_streak=8, request_shadow_rate=None, **kw):
    cfg = ServeConfig(buckets=BucketPolicy(batch=(1, 2, 4), seq=(32, 64)),
                      use_lilac=False, jit_prefill=False,
                      request_shadow_rate=request_shadow_rate, **kw)
    engines = [Engine(MockModel(), params=None, config=cfg)
               for _ in range(n)]
    return FrontDoor(engines, fault_streak=fault_streak)


def _req(prompt, max_new):
    return Request(prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=max_new)


def _submit_many(fd, n, max_new=6, plen=5, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        r = _req(rng.integers(1, 9000, size=plen), max_new)
        assert fd.submit(r)
        reqs.append(r)
    return reqs


# ---------------------------------------------------------------------------
# routing + steady state
# ---------------------------------------------------------------------------

def test_frontdoor_routes_and_streams_match_solo():
    fd = _mock_fleet(3)
    reqs = _submit_many(fd, 12)
    used = {fd.assignment[r.rid] for r in reqs}
    assert len(used) > 1                      # hashing actually spreads load
    fd.run_until_idle()
    assert fd.accounted()
    for r in reqs:
        assert r.failed is None
        assert r.tokens == _solo_stream(list(r.prompt), r.max_new_tokens)
    snap = fd.snapshot()
    assert snap["fleet"]["finished"] == 12
    assert snap["fleet"]["failovers"] == 0
    assert snap["fleet"]["all_requests_accounted_for"]


def test_default_replicas_env(monkeypatch):
    monkeypatch.delenv("LILAC_SERVE_REPLICAS", raising=False)
    assert default_replicas() == 2
    monkeypatch.setenv("LILAC_SERVE_REPLICAS", "5")
    assert default_replicas() == 5
    monkeypatch.setenv("LILAC_SERVE_REPLICAS", "junk")
    assert default_replicas() == 2


# ---------------------------------------------------------------------------
# replica_crash failover (the tentpole acceptance property)
# ---------------------------------------------------------------------------

def test_replica_crash_redistributes_without_loss():
    """Killing 1 of 3 replicas mid-run loses zero requests: drained work
    is replayed on survivors and every stream stays bit-identical to the
    solo reference."""
    fd = _mock_fleet(3)
    reqs = _submit_many(fd, 15, max_new=8)
    victim = fd.assignment[reqs[0].rid]
    for _ in range(2):                    # mid-burst: some tokens exist
        fd.step()
    with faults.inject(f"replica_crash:replica{victim}") as plan:
        fd.step()
    assert plan.fired and plan.fired[0][0] == "replica_crash"
    assert not fd.replicas[victim].healthy
    assert "crash" in fd.replicas[victim].reason
    fd.run_until_idle()
    assert fd.accounted()
    assert fd.failovers == 1
    assert fd.redistributed > 0
    assert fd.lost == 0
    for r in reqs:
        assert r.failed is None
        assert r.tokens == _solo_stream(list(r.prompt), r.max_new_tokens)
    snap = fd.snapshot()
    assert snap["fleet"]["healthy"] == 2
    assert snap["fleet"]["redistributed"] == fd.redistributed


def test_all_replicas_lost_fails_loudly():
    fd = _mock_fleet(2)
    reqs = _submit_many(fd, 6)
    with faults.inject("replica_crash"):      # every site: whole fleet dies
        fd.step()
    assert not fd.healthy_replicas()
    assert fd.accounted()                     # failed loudly, not dropped
    for r in reqs:
        assert r.failed == "replica_lost"
        assert r.finish_t is not None
    snap = fd.snapshot()
    assert snap["fleet"]["replica_lost"] == 6
    assert snap["fleet"]["failed_reasons"] == {"replica_lost": 6}


def test_past_deadline_request_lost_at_failover():
    t = [0.0]
    cfg = ServeConfig(buckets=BucketPolicy(batch=(1, 2), seq=(32,)),
                      use_lilac=False, jit_prefill=False)
    engines = [Engine(MockModel(), params=None, config=cfg,
                      clock=lambda: t[0]) for _ in range(2)]
    fd = FrontDoor(engines, clock=lambda: t[0])
    fresh = _req([1, 2, 3], 4)
    stale = _req([4, 5, 6], 4)
    stale.deadline_s = 0.5
    assert fd.submit(fresh) and fd.submit(stale)
    victim = fd.assignment[stale.rid]
    t[0] = 1.0                              # stale is now past its deadline
    with faults.inject(f"replica_crash:replica{victim}"):
        fd.step()
    assert stale.failed == "replica_lost"   # loud, attributed — not retried
    fd.run_until_idle()
    assert fd.accounted()
    if fd.assignment[fresh.rid] != victim or fresh.done:
        assert fresh.failed is None


def test_health_check_retires_fault_streak_replica():
    """A replica whose every step burns a decode fault is condemned by
    its own ServeMetrics counters and drained before it destroys its
    whole queue."""

    class BrokenModel(MockModel):
        def decode(self, params, cache, tokens, pos):
            raise RuntimeError("hardware gone")

    cfg = ServeConfig(buckets=BucketPolicy(batch=(1, 2, 4), seq=(32,)),
                      use_lilac=False, jit_prefill=False)
    healthy = Engine(MockModel(), params=None, config=cfg)
    broken = Engine(BrokenModel(), params=None, config=cfg)
    fd = FrontDoor([healthy, broken], fault_streak=2)
    reqs = _submit_many(fd, 10, max_new=4)
    fd.run_until_idle()
    assert not fd.replicas[1].healthy
    assert "unhealthy" in fd.replicas[1].reason
    assert fd.accounted()
    # casualties are only the slots poisoned before the streak tripped;
    # everything drained afterwards finished correctly on the survivor
    for r in reqs:
        if r.failed is None:
            assert r.tokens == _solo_stream(list(r.prompt),
                                            r.max_new_tokens)
        else:
            assert r.failed.startswith("decode")
    assert fd.redistributed > 0


# ---------------------------------------------------------------------------
# adaptive shadow rate (unit)
# ---------------------------------------------------------------------------

def test_adaptive_shadow_rate_floor_reread(monkeypatch):
    monkeypatch.delenv("LILAC_SHADOW_RATE", raising=False)
    a = R.AdaptiveShadowRate()
    assert a.floor() == 0.0 and a.effective() == 0.0
    monkeypatch.setenv("LILAC_SHADOW_RATE", "0.25")
    assert a.floor() == 0.25                  # re-read, not compile-cached
    monkeypatch.setenv("LILAC_SHADOW_RATE", "2.5")
    assert a.floor() == 1.0                   # clamped
    b = R.AdaptiveShadowRate(floor=0.125)
    assert b.floor() == 0.125                 # explicit override wins


def test_adaptive_shadow_rate_spike_and_decay(monkeypatch):
    monkeypatch.delenv("LILAC_SHADOW_SPIKE", raising=False)
    monkeypatch.delenv("LILAC_SHADOW_DECAY", raising=False)
    a = R.AdaptiveShadowRate(floor=0.05)
    a.spike("divergence")
    assert a.multiplier == 16.0
    assert a.effective() == pytest.approx(0.8)
    assert a.peak_multiplier == 16.0
    seen = []
    for _ in range(5):
        a.clean()
        seen.append(a.multiplier)
    assert seen == [8.0, 4.0, 2.0, 1.0, 1.0]  # geometric, floored at 1
    assert a.effective() == pytest.approx(0.05)
    assert a.peak_multiplier == 16.0          # peak is sticky for gates
    a.spike("again")
    assert a.clean_streak == 0


def test_adaptive_shadow_rate_env_knobs(monkeypatch):
    monkeypatch.setenv("LILAC_SHADOW_SPIKE", "4")
    monkeypatch.setenv("LILAC_SHADOW_DECAY", "0.25")
    a = R.AdaptiveShadowRate(floor=1.0)
    a.spike("x")
    assert a.multiplier == 4.0
    assert a.effective() == 1.0               # capped at 1
    a.clean()
    assert a.multiplier == 1.0                # 4 * 0.25
    snap = a.snapshot()
    assert snap["spike"] == 4.0 and snap["decay"] == 0.25
    assert snap["incidents"] == 1 and snap["checks"] == 1


# ---------------------------------------------------------------------------
# request-level shadow verification
# ---------------------------------------------------------------------------

def test_request_shadow_clean_streak():
    fd = _mock_fleet(2, request_shadow_rate=1.0)
    _submit_many(fd, 8, max_new=5)
    fd.run_until_idle()
    snap = fd.snapshot()
    assert snap["resilience"]["request_shadow_checks"] == 8
    assert snap["resilience"]["request_shadow_divergences"] == 0
    assert snap["resilience"]["request_shadow_peak_multiplier"] == 1.0


def test_request_shadow_forced_divergence_spikes_then_decays():
    eng = Engine(MockModel(), params=None, config=ServeConfig(
        buckets=BucketPolicy(batch=(1, 2), seq=(32,)),
        use_lilac=False, jit_prefill=False, request_shadow_rate=1.0))
    assert eng.submit(_req([1, 2, 3], 4))
    with faults.inject("shadow_diverge:request"):
        eng.run_until_idle()
    assert eng.metrics.request_shadow_divergences == 1
    shadow = eng._request_shadow
    assert shadow.peak_multiplier >= 8.0
    for i in range(8):                        # clean traffic decays the spike
        assert eng.submit(_req([7 + i, 8, 9], 3))
    eng.run_until_idle()
    assert eng.metrics.request_shadow_divergences == 1
    assert shadow.multiplier < 2.0
    assert shadow.peak_multiplier >= 8.0


def test_request_shadow_sampling_is_stratified():
    eng = Engine(MockModel(), params=None, config=ServeConfig(
        buckets=BucketPolicy(batch=(1, 2), seq=(32,)),
        use_lilac=False, jit_prefill=False, request_shadow_rate=0.25))
    for i in range(8):
        assert eng.submit(_req([i + 1, 2, 3], 3))
    eng.run_until_idle()
    assert eng.metrics.request_shadow_checks == 2     # 8 finishes * 0.25


# ---------------------------------------------------------------------------
# empty-series metrics guard (satellite)
# ---------------------------------------------------------------------------

def test_zero_request_replica_snapshots_cleanly():
    """A replica that served nothing must snapshot (and JSON-serialize)
    without raising — fleet aggregation hits this on every fresh boot."""
    import warnings
    fd = _mock_fleet(3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # numpy empty-slice warnings
        snap = fd.snapshot()
    assert snap["fleet"]["submitted"] == 0
    assert snap["fleet"]["all_requests_accounted_for"]
    rep = snap["replicas"][0]["metrics"]
    assert np.isnan(rep["ttft_s"]["p50"])
    assert rep["decode_step_s"]["histogram"] == {"edges_s": [], "counts": []}
    json.dumps(snap)                          # NaNs allowed, nothing raises


# ---------------------------------------------------------------------------
# cross-replica quarantine sharing: concurrent-writer JsonStore merge
# ---------------------------------------------------------------------------

_WRITER = """
import sys
from repro.core.resilience import QuarantineStore
path, harness = sys.argv[1], sys.argv[2]
q = QuarantineStore(path)
q.load()
q.add("spmv.csr", harness, reason="chaos incident", site=harness)
print("ok")
"""


def test_concurrent_quarantine_writers_both_survive(tmp_path):
    """Two processes quarantine different harnesses into one store file;
    the flock merge-on-save keeps both records — the invariant that lets
    N replicas (or N hosts) share one incident store."""
    import os
    path = tmp_path / "quarantine.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    procs = [subprocess.Popen(
        [sys.executable, "-c", _WRITER, str(path), harness],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env)
        for harness in ("pallas.ell", "jnp.segment")]
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
    from repro.core.resilience import QuarantineStore
    store = QuarantineStore(path)
    store.load()
    keys = set(store.active())
    assert "spmv.csr|pallas.ell|default" in keys
    assert "spmv.csr|jnp.segment|default" in keys
