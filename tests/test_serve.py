"""Serving-tier tests: bucket policy, scheduler invariants, continuous
batching bit-identity vs solo decode (hypothesis-driven over a mock
model), ragged MoE packing, and a real-model parity smoke.

The mock model's decode is a per-slot integer rolling hash over
``(token, position)`` — the next token depends ONLY on that request's own
history, so any slot mix-up (wrong install row, bad eviction move, stale
position) changes the stream and fails the bit-identity property.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # property tests skip; seeded sweeps still run
    HAS_HYPOTHESIS = False
needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")

from repro.serve import (  # noqa: E402
    BucketError, BucketPolicy, Engine, Request, Scheduler, SchedulerFull,
    ServeConfig, SyntheticWorkload, default_buckets, moe_ffn_padded,
    moe_ffn_ragged, pack, padding_waste, parse_buckets, unpack,
)

VOCAB = 10007
_MOD = 9973


# ---------------------------------------------------------------------------
# mock model: integer rolling-hash decode, numpy-only (no accelerator)
# ---------------------------------------------------------------------------

def _fold(h: int, tok: int, pos: int) -> int:
    return (h * 1000003 + int(tok) * 31 + int(pos) + 7) % _MOD


class MockModel:
    """Model-surface stub for engine/scheduler tests.  The cache is
    ``{"state": (B,) int64, "cap": int}``; decode advances each row's
    hash with its (token, pos) pair and emits the hash as the next
    token."""

    def init_cache(self, B, S):
        return {"state": np.zeros((B,), np.int64), "cap": int(S)}

    def prefill(self, params, batch):
        toks = np.asarray(batch["tokens"])
        B, L = toks.shape
        h = np.zeros((B,), np.int64)
        for b in range(B):
            acc = 0
            for p in range(L):
                acc = _fold(acc, toks[b, p], p)
            h[b] = acc
        logits = np.zeros((B, VOCAB), np.float32)
        logits[np.arange(B), h] = 1.0
        return logits, {"state": h}

    def cache_from_prefill(self, caches, L, S):
        return {"state": np.asarray(caches["state"]).copy(),
                "cap": int(S)}

    def cache_set_slot(self, cache, slot, row):
        out = {"state": cache["state"].copy(), "cap": cache["cap"]}
        out["state"][slot] = row["state"][0]
        return out

    def cache_move_slot(self, cache, src, dst):
        out = {"state": cache["state"].copy(), "cap": cache["cap"]}
        out["state"][dst] = out["state"][src]
        return out

    def cache_resize(self, cache, B=None, max_seq=None):
        old = cache["state"]
        B = B if B is not None else old.shape[0]
        state = np.zeros((B,), np.int64)
        state[: min(B, old.shape[0])] = old[: min(B, old.shape[0])]
        return {"state": state,
                "cap": int(max_seq) if max_seq else cache["cap"]}

    def decode(self, params, cache, tokens, pos):
        tokens = np.asarray(tokens)
        pos = np.asarray(pos)
        B = tokens.shape[0]
        state = cache["state"].copy()
        for b in range(B):
            state[b] = _fold(int(state[b]), tokens[b, 0], int(pos[b]))
        logits = np.zeros((B, VOCAB), np.float32)
        logits[np.arange(B), state] = 1.0
        return logits, {"state": state, "cap": cache["cap"]}


def _mock_engine(mode="continuous", batch=(1, 2, 4), seq=(16, 32, 64),
                 **kw):
    cfg = ServeConfig(buckets=BucketPolicy(batch=batch, seq=seq),
                      mode=mode, use_lilac=False, jit_prefill=False, **kw)
    return Engine(MockModel(), params=None, config=cfg)


def _solo_stream(prompt, max_new):
    """Reference stream computed directly from the hash recurrence."""
    h = 0
    for p, t in enumerate(prompt):
        h = _fold(h, t, p)
    out = [h]
    L = len(prompt)
    while len(out) < max_new:
        h = _fold(h, out[-1], L + len(out) - 1)
        out.append(h)
    return out


# ---------------------------------------------------------------------------
# buckets
# ---------------------------------------------------------------------------

def test_bucket_smallest_fit_and_overflow():
    p = BucketPolicy(batch=(1, 2, 4), seq=(128, 512))
    assert p.batch_bucket(1) == 1
    assert p.batch_bucket(3) == 4
    assert p.seq_bucket(128) == 128
    assert p.seq_bucket(129) == 512
    with pytest.raises(BucketError):
        p.batch_bucket(5)
    with pytest.raises(BucketError):
        p.seq_bucket(513)
    assert p.max_batch == 4 and p.max_seq == 512
    assert len(p.grid()) == 6


def test_parse_buckets_and_env(monkeypatch):
    p = parse_buckets("1,2,4x128,256")
    assert p.batch == (1, 2, 4) and p.seq == (128, 256)
    monkeypatch.setenv("LILAC_SERVE_BUCKETS", "2x64")
    assert default_buckets().spec() == "2x64"
    monkeypatch.setenv("LILAC_SERVE_BUCKETS", "nonsense")
    with pytest.raises(BucketError):
        default_buckets()


def test_bucket_policy_sorted_deduped():
    p = BucketPolicy(batch=(4, 1, 4), seq=(256, 64))
    assert p.batch == (1, 4) and p.seq == (64, 256)


# ---------------------------------------------------------------------------
# scheduler invariants + edge cases (ISSUE: empty batch, all-finish-
# same-step, over-capacity queue)
# ---------------------------------------------------------------------------

def _req(plen=4, new=3, **kw):
    return Request(prompt=np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=new, **kw)


def test_scheduler_empty_batch_step():
    s = Scheduler(max_batch=4)
    assert s.idle
    assert s.admissions() == []
    assert s.evict_finished() == ([], [])


def test_scheduler_over_capacity_queue():
    s = Scheduler(max_batch=1, queue_capacity=2)
    s.submit(_req())
    s.submit(_req())
    with pytest.raises(SchedulerFull):
        s.submit(_req())
    assert s.queue_depth == 2


def test_scheduler_all_finish_same_step():
    s = Scheduler(max_batch=4)
    reqs = [_req(new=1) for _ in range(4)]
    for r in reqs:
        s.submit(r)
    assert s.admissions() == reqs
    for r in reqs:
        r.tokens.append(1)          # every request done at once
    finished, moves = s.evict_finished()
    assert finished == reqs and moves == [] and s.idle


def test_scheduler_static_waits_for_drain():
    s = Scheduler(max_batch=2, mode="static")
    a, b, c = _req(new=1), _req(new=2), _req(new=1)
    for r in (a, b, c):
        s.submit(r)
    assert s.admissions() == [a, b]
    a.tokens.append(1)
    s.evict_finished()
    assert s.admissions() == []     # b still running: no refill
    b.tokens += [1, 2]
    s.evict_finished()
    assert s.admissions() == [c]    # batch drained: next wave


def test_scheduler_compaction_moves_preserve_prefix():
    s = Scheduler(max_batch=6)
    reqs = [_req(new=5) for _ in range(6)]
    for r in reqs:
        s.submit(r)
    s.admissions()
    for i in (0, 2, 5):             # finish a head, a middle, and the tail
        reqs[i].tokens += [1] * 5
    finished, moves = s.evict_finished()
    assert {r.rid for r in finished} == {reqs[i].rid for i in (0, 2, 5)}
    # moves fill low holes from tail survivors, src >= n_new > dst
    n_new = 3
    assert all(src >= n_new > dst for src, dst in moves)
    assert s.active == [reqs[4], reqs[1], reqs[3]] or \
        {r.rid for r in s.active} == {reqs[i].rid for i in (1, 3, 4)}
    assert len(s.active) == n_new


def _drive_random_evictions(new_counts, rng):
    """Whatever subset finishes each step, survivors always end up in
    slots [0, n) and no move overwrites another move's source."""
    s = Scheduler(max_batch=8)
    reqs = [_req(new=n) for n in new_counts]
    for r in reqs:
        s.submit(r)
    while not s.idle:
        s.admissions()
        n = len(s.active)
        done = [i for i in range(n) if rng.random() < 0.4]
        before = {r.rid for r in s.active}
        for i in done:
            s.active[i].tokens += [1] * s.active[i].max_new_tokens
        survivors = [r.rid for r in s.active if not r.done]
        _, moves = s.evict_finished()
        seen_src = set()
        for src, dst in moves:
            assert src not in seen_src and dst < len(s.active)
            seen_src.add(src)
        assert sorted(r.rid for r in s.active) == sorted(survivors)
        assert all(r.rid in before for r in s.active)
        for r in s.active:          # undone requests must still make progress
            if not r.done:
                r.tokens.append(1)


def test_scheduler_random_evictions_seeded_sweep():
    for seed in range(25):
        rng = np.random.default_rng(seed)
        counts = list(rng.integers(1, 7, size=rng.integers(1, 11)))
        _drive_random_evictions(counts, rng)


if HAS_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(1, 6), min_size=1, max_size=10),
           st.integers(0, 2**16))
    def test_scheduler_random_evictions_keep_invariant(new_counts, seed):
        _drive_random_evictions(new_counts, np.random.default_rng(seed))


# ---------------------------------------------------------------------------
# engine bit-identity: batched continuous == solo, over random workloads
# ---------------------------------------------------------------------------

def _make_requests(spec):
    out = []
    for plen, new, seed in spec:
        prompt = np.random.default_rng(seed).integers(
            1, VOCAB - 1, size=plen).astype(np.int32)
        out.append(Request(prompt=prompt, max_new_tokens=new))
    return out


def _check_bit_identity(spec, mode):
    eng = _mock_engine(mode=mode)
    reqs = _make_requests(spec)
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_idle()
    for (plen, new, _), r in zip(spec, reqs):
        assert len(r.tokens) == new
        assert r.tokens == _solo_stream(list(r.prompt), new), \
            f"stream diverged for rid={r.rid} mode={mode}"


@pytest.mark.parametrize("mode", ["continuous", "static"])
def test_batched_streams_bit_identical_seeded_sweep(mode):
    for seed in range(12):
        rng = np.random.default_rng((77, seed))
        spec = [(int(rng.integers(1, 11)), int(rng.integers(1, 7)),
                 int(rng.integers(0, 2**16)))
                for _ in range(int(rng.integers(1, 9)))]
        _check_bit_identity(spec, mode)


if HAS_HYPOTHESIS:
    @st.composite
    def request_set(draw):
        n = draw(st.integers(1, 8))
        return [(draw(st.integers(1, 10)), draw(st.integers(1, 6)),
                 draw(st.integers(0, 2**16))) for _ in range(n)]

    @settings(max_examples=30, deadline=None)
    @given(request_set(), st.sampled_from(["continuous", "static"]))
    def test_batched_streams_bit_identical_to_solo(spec, mode):
        _check_bit_identity(spec, mode)


def test_engine_eviction_midstream_does_not_corrupt_neighbors():
    """A short request finishing early triggers a compaction move; the
    surviving long request's stream must be unaffected."""
    eng = _mock_engine(batch=(2,), seq=(32,))
    short = _req(plen=3, new=1)
    long = _req(plen=5, new=8)
    late = _req(plen=4, new=2)      # admitted into the freed slot
    for r in (short, long, late):
        assert eng.submit(r)
    eng.run_until_idle()
    assert long.tokens == _solo_stream(list(long.prompt), 8)
    assert late.tokens == _solo_stream(list(late.prompt), 2)


def test_engine_rejects_unbucketable_and_full_queue():
    eng = _mock_engine(batch=(1,), seq=(16,), queue_capacity=1)
    assert not eng.submit(_req(plen=20, new=4))      # 24 > max seq 16
    assert eng.metrics.snapshot()["requests"]["rejected"] == 1
    assert eng.submit(_req(plen=2, new=2))           # fills the 1-deep queue
    assert not eng.submit(_req(plen=2, new=2))       # queue full
    assert eng.metrics.snapshot()["requests"]["rejected"] == 2
    eng.step()                                       # admits, queue drains
    assert eng.submit(_req(plen=2, new=2))
    eng.run_until_idle()


def test_engine_eos_stops_stream():
    eng = _mock_engine()
    r = _req(plen=4, new=50)
    stream = _solo_stream(list(r.prompt), 50)
    r.eos_id = stream[2]            # third token is "eos"
    assert eng.submit(r)
    eng.run_until_idle()
    assert r.tokens == stream[:3]


def test_engine_run_with_workload_snapshot():
    wl = SyntheticWorkload(n_requests=5, vocab=VOCAB, prompt_len=(2, 6),
                           new_tokens=(1, 4), seed=3)
    eng = _mock_engine()
    snap = eng.run(wl)
    assert snap["requests"]["finished"] == 5
    assert snap["requests"]["rejected"] == 0
    assert snap["steps"] >= 1
    assert 0.0 < snap["batch_occupancy"] <= 1.0
    assert np.isfinite(snap["ttft_s"]["p99"])


def test_workload_deterministic_replay():
    wl = SyntheticWorkload(n_requests=4, vocab=100, seed=9)
    a, b = wl.requests(), wl.requests()
    for (ta, ra), (tb, rb) in zip(a, b):
        assert ta == tb
        assert np.array_equal(ra.prompt, rb.prompt)
        assert ra.max_new_tokens == rb.max_new_tokens


# ---------------------------------------------------------------------------
# ragged packing
# ---------------------------------------------------------------------------

def _check_pack_roundtrip(parts):
    arrs = [np.asarray(p, np.float32).reshape(-1, 1) for p in parts]
    flat, offsets = pack(arrs)
    assert offsets[0] == 0 and offsets[-1] == sum(len(p) for p in parts)
    back = unpack(flat, offsets)
    assert len(back) == len(parts)
    for a, b in zip(arrs, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_unpack_roundtrip_seeded_sweep():
    for seed in range(20):
        rng = np.random.default_rng(seed)
        parts = [list(rng.integers(-5, 6, size=rng.integers(0, 8)))
                 for _ in range(rng.integers(1, 7))]
        _check_pack_roundtrip(parts)


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.lists(st.integers(-5, 5), min_size=0, max_size=7),
                    min_size=1, max_size=6))
    def test_pack_unpack_roundtrip(parts):
        _check_pack_roundtrip(parts)


def test_padding_waste():
    assert padding_waste([4, 4]) == 0.0
    assert padding_waste([1, 3], pad_to=4) == pytest.approx(0.5)


def test_ragged_moe_matches_padded():
    rng = np.random.default_rng(0)
    E, D, F, K = 4, 8, 16, 2
    lengths = [3, 7, 1, 5]
    xs = [rng.standard_normal((t, D)).astype(np.float32) for t in lengths]
    gates = [rng.random((t, K)).astype(np.float32) for t in lengths]
    idxs = [rng.integers(0, E, (t, K)).astype(np.int32) for t in lengths]
    wg, wu = (rng.standard_normal((E, D, F)).astype(np.float32) * 0.1
              for _ in range(2))
    wd = rng.standard_normal((E, F, D)).astype(np.float32) * 0.1
    ragged = moe_ffn_ragged(xs, gates, idxs, wg, wu, wd, backend="naive")
    padded = moe_ffn_padded(xs, gates, idxs, wg, wu, wd)
    for a, b in zip(ragged, padded):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# real model: engine vs solo parity + prewarm
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_lm():
    import jax
    from repro.configs.base import get_arch, smoke_config
    from repro.models.factory import build_model
    cfg = smoke_config(get_arch("olmoe-1b-7b")).replace(
        moe_decode_impl="naive_flat")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_real_model_engine_matches_solo(small_lm):
    cfg, model, params = small_lm
    policy = BucketPolicy(batch=(1, 2), seq=(16,))
    eng = Engine(model, params,
                 ServeConfig(buckets=policy, use_lilac=False,
                             prewarm_on_start=False))
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=p)
                    .astype(np.int32), max_new_tokens=n)
            for p, n in ((5, 4), (3, 6), (7, 3))]
    for r in reqs:
        assert eng.submit(r)
    eng.run_until_idle()
    for r in reqs:
        solo = eng.generate_solo(r.prompt, r.max_new_tokens)
        assert r.tokens == solo, f"batched != solo for rid={r.rid}"


def test_real_model_prewarm_bakes_grid(small_lm):
    cfg, model, params = small_lm
    policy = BucketPolicy(batch=(1, 2), seq=(16,))
    eng = Engine(model, params,
                 ServeConfig(buckets=policy, prefill_lengths=(4,)))
    pw = eng.metrics.prewarm
    assert pw["n_signatures"] == len(policy.grid())
    assert pw["baked"] == len(policy.grid())
    r = Request(prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=3)
    assert eng.submit(r)
    eng.run_until_idle()
    assert len(r.tokens) == 3
    snap = eng.metrics.snapshot()
    assert snap["buckets"]["misses"] == 0    # every decode on a warm bucket


def test_vector_pos_decode_matches_scalar(small_lm):
    """attention_decode_stacked with a (B,)-vector of equal positions is
    byte-identical to the scalar-pos path."""
    import jax.numpy as jnp
    cfg, model, params = small_lm
    B, L, S = 2, 5, 16
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, L)).astype(np.int32))
    _, caches = model.prefill(params, {"tokens": toks})
    cache = model.cache_from_prefill(caches, L, S)
    step = jnp.asarray(rng.integers(1, cfg.vocab, (B, 1)).astype(np.int32))
    lo_s, c_s = model.decode(params, cache, step, jnp.int32(L))
    lo_v, c_v = model.decode(params, cache, step,
                             jnp.full((B,), L, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lo_s), np.asarray(lo_v))
    import jax
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
