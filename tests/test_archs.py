"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting shapes and finiteness. Decode steps for
causal archs. (Full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, all_archs, get_arch, shape_skips, smoke_config
from repro.models import build_model
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

ARCHS = sorted(all_archs())


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "stub":
        return {
            "embeds": jnp.asarray(rng.standard_normal(
                (B, S, cfg.d_model)).astype(np.float32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))
                                  .astype(np.int32)),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))
                              .astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S))
                              .astype(np.int32)),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(get_arch(arch))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), arch
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, arch
    # one optimizer step moves the loss
    ocfg = AdamWConfig(lr=1e-2, total_steps=10, warmup_steps=0)
    state = adamw_init(ocfg, params)
    new_params, state, _ = adamw_update(ocfg, grads, state, params)
    loss2 = model.loss_fn(new_params, batch)
    assert jnp.isfinite(loss2), arch
    assert float(loss2) != float(loss), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = smoke_config(get_arch(arch))
    if not cfg.causal:
        pytest.skip("encoder-only: no decode step")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, cache_len = 2, 24
    cache = model.init_cache(B, cache_len)
    logits = None
    for pos in range(3):
        tok = jnp.full((B, 1), pos + 1, jnp.int32)
        logits, cache = model.decode(params, cache, tok, jnp.int32(pos))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_decode(arch):
    """Teacher-forced decode must agree with a full prefill forward —
    the KV-cache/state path is consistent with the parallel path."""
    cfg = smoke_config(get_arch(arch))
    if not cfg.causal:
        pytest.skip("encoder-only")
    if cfg.frontend == "stub":
        pytest.skip("stub frontends feed embeddings; decode consumes tokens")
    if arch == "jamba-v0.1-52b":
        # Diagnosed (see ROADMAP open items): with the Jamba dt/B/C
        # RMSNorms and reference-style mamba init the ssm states are
        # bounded (~1e2, was ~1e7) and the per-layer paths agree
        # bit-exactly when applied eagerly, but this toolchain's XLA-CPU
        # *fused* elementwise kernels evaluate the logistic with a fast
        # approximation (silu(16.75) -> 16.6875, rel ~4e-3, independent of
        # --xla_cpu_enable_fast_math).  Prefill (one fused scan program)
        # and decode (many small programs) therefore disagree by ~4e-3
        # per silu site, which 16 recurrent layers amplify past tol with
        # occasional argmax flips.  Not a cache/position logic bug.
        pytest.xfail("XLA-CPU fused-kernel logistic approximation; "
                     "prefill/decode program shapes differ")
    if cfg.moe_experts:
        # capacity drops depend on the dispatch group (sequence in prefill,
        # batch in decode); equality holds when nothing is dropped
        cfg = cfg.replace(capacity_factor=float(cfg.moe_experts))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 8
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, S)).astype(np.int32))
    logits_pre, _ = model.prefill(params, {"tokens": tokens})
    cache = model.init_cache(B, S)
    logits_dec = None
    for pos in range(S):
        logits_dec, cache = model.decode(
            params, cache, tokens[:, pos:pos + 1], jnp.int32(pos))
    # fp reassociation differs between the fused prefill path and the
    # unrolled per-token decode path; recurrent state and discrete top-k
    # routing (tie flips) amplify it. A logic bug (wrong position, stale
    # cache) produces O(1..10) differences and disagreeing predictions.
    if cfg.family in ("ssm", "hybrid") or cfg.moe_experts:
        tol = 3e-1
    else:
        tol = 5e-2
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(logits_pre),
                               atol=tol, rtol=tol)
    # prediction agreement is exact regardless of family
    np.testing.assert_array_equal(np.argmax(np.asarray(logits_dec), -1),
                                  np.argmax(np.asarray(logits_pre), -1))


def test_shape_skip_table():
    """The skip matrix matches DESIGN.md §Arch-applicability."""
    skips = {(a, s): shape_skips(get_arch(a), SHAPES[s])
             for a in ARCHS for s in SHAPES}
    n_skipped = sum(1 for v in skips.values() if v)
    assert n_skipped == 9
    assert skips[("rwkv6-1.6b", "long_500k")] is None
    assert skips[("jamba-v0.1-52b", "long_500k")] is None
    assert skips[("granite-8b", "long_500k")] is not None
    assert skips[("hubert-xlarge", "decode_32k")] is not None


def test_param_counts_match_advertised_sizes():
    expected = {
        "rwkv6-1.6b": (1.5e9, 1.9e9),
        "internvl2-2b": (1.7e9, 2.2e9),
        "granite-moe-3b-a800m": (3.0e9, 3.7e9),
        "olmoe-1b-7b": (6.4e9, 7.4e9),
        "granite-8b": (7.5e9, 9.0e9),
        "mistral-large-123b": (118e9, 128e9),
        "granite-34b": (33e9, 50e9),
        "olmo-1b": (1.1e9, 1.5e9),
        "jamba-v0.1-52b": (48e9, 56e9),
        "hubert-xlarge": (0.9e9, 1.4e9),
    }
    for arch, (lo, hi) in expected.items():
        n = build_model(get_arch(arch)).param_count()
        assert lo <= n <= hi, (arch, n)
