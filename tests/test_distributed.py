"""Distributed-path tests (subprocess with forced host devices): the
launcher trains on a real (data, model) mesh with shard_map MoE EP, and
the dry-run machinery lowers/compiles a cell end-to-end."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 4, timeout: int = 900):
    env = {**os.environ, "PYTHONPATH": SRC,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_train_on_2x2_mesh_with_moe_ep():
    code = """
import sys
sys.argv = ["train", "--arch", "olmoe-1b-7b", "--smoke", "--steps", "3",
            "--batch", "4", "--seq", "32", "--mesh-data", "2",
            "--mesh-model", "2", "--moe-impl", "grouped"]
from repro.launch.train import main
main()
print("DIST_TRAIN_OK")
"""
    proc = _run(code)
    assert "DIST_TRAIN_OK" in proc.stdout, proc.stderr[-2000:]


def test_gradient_compression_on_mesh():
    code = """
import sys
sys.argv = ["train", "--arch", "olmo-1b", "--smoke", "--steps", "3",
            "--batch", "4", "--seq", "32", "--mesh-data", "4",
            "--mesh-model", "1", "--compress-grads"]
from repro.launch.train import main
main()
print("COMPRESS_OK")
"""
    proc = _run(code)
    assert "COMPRESS_OK" in proc.stdout, proc.stderr[-2000:]


def test_dryrun_machinery_small():
    """analyze_cell on a reduced arch x tiny mesh — exercises lowering,
    memory analysis and the HLO walker end to end (the production 512-dev
    sweep lives in experiments/dryrun)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax
from repro.configs import get_arch, smoke_config, SHAPES
from repro.launch import dryrun as DR
from repro.launch.mesh import mesh_rules
from repro.models import build_model
from repro.train import optim as O, train_step as TS

cfg = smoke_config(get_arch("olmoe-1b-7b")).replace(
    spmd_constraints=True, mesh_axis_sizes=(("data", 2), ("model", 2)))
model = build_model(cfg)
from repro import compat
mesh = compat.make_mesh((2, 2), ("data", "model"))
rules = mesh_rules(False)
opt_cfg = O.AdamWConfig()
step = TS.make_train_step(model, opt_cfg)
pshard = TS.param_shardings(model, mesh, rules)
oshard = TS.opt_state_shardings(model, opt_cfg, mesh, rules)
abs_params = model.abstract_params()
abs_opt = jax.eval_shape(lambda p: O.adamw_init(opt_cfg, p), abs_params)
import jax.numpy as jnp
abs_batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
with compat.use_mesh(mesh):
    lowered = jax.jit(step, in_shardings=(pshard, oshard, None),
                      donate_argnums=(0, 1)).lower(
        abs_params, abs_opt, abs_batch)
compiled = lowered.compile()
stats = DR.analyze_hlo(compiled.as_text())
assert stats["flops"] > 0
assert compiled.memory_analysis() is not None
print("DRYRUN_OK", int(stats["flops"]),
      stats["collectives"]["total_bytes"])
"""
    proc = _run(code)
    assert "DRYRUN_OK" in proc.stdout, proc.stderr[-2000:]


def test_production_sweep_results_green():
    """The committed 512-device sweep must be all ok/skip (the deliverable:
    multi-pod compile succeeds for every cell)."""
    import glob
    import json
    jobs = glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                  "experiments", "dryrun", "*.json"))
    if len(jobs) < 80:
        pytest.skip("sweep not yet complete")
    statuses = {}
    for f in jobs:
        d = json.load(open(f))
        statuses[os.path.basename(f)] = d["status"]
    bad = {k: v for k, v in statuses.items() if v not in ("ok", "skip")}
    assert not bad, bad
    n_multi_ok = sum(1 for k, v in statuses.items()
                     if v == "ok" and "__multi" in k)
    assert n_multi_ok >= 31   # every runnable cell compiles multi-pod
