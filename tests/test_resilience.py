"""Fail-safe acceleration (the robustness tier): chaos injection,
contained execution, harness quarantine, shadow verification, and
serving-tier fault eviction.

The contract under test: ``lilac.compile(f)`` is *never worse* than
``f`` — under ANY injected fault the user sees reference-correct
numerics and zero exceptions; the failing (harness, variant) is
quarantined and persisted so the next process does not re-trip it.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.core import faults
from repro.core.resilience import (QuarantineStore, outputs_close,
                                   reset_shared_quarantine,
                                   shared_quarantine)
from repro.sparse import random_csr

from test_serve import MockModel, _mock_engine, _solo_stream  # noqa: E402

ROWS, COLS = 64, 48


@pytest.fixture(scope="module")
def problem():
    csr = random_csr(ROWS, COLS, density=0.12, seed=1)
    rng = np.random.default_rng(2)
    vec = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    return csr, vec


def naive_spmv(val, col, row_ptr, vec):
    row = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                     total_repeat_length=val.shape[0])
    return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)


def _args(problem):
    csr, vec = problem
    return csr.val, csr.col_ind, csr.row_ptr, vec


def _reference(problem):
    return np.asarray(naive_spmv(*_args(problem)))


def _assert_oracle(out, problem):
    np.testing.assert_allclose(np.asarray(out), _reference(problem),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# fault harness mechanics
# ---------------------------------------------------------------------------

def test_parse_spec_grammar():
    rules = faults.parse_spec(
        "kernel_raise:pallas.ell:0.5, nan_output:* ,cache_torn_write")
    assert [(r.kind, r.site, r.prob) for r in rules] == [
        ("kernel_raise", "pallas.ell", 0.5),
        ("nan_output", "*", 1.0),
        ("cache_torn_write", "*", 1.0)]
    with pytest.raises(faults.FaultSpecError):
        faults.parse_spec("no_such_kind")
    with pytest.raises(faults.FaultSpecError):
        faults.parse_spec("kernel_raise:*:1.5")
    with pytest.raises(faults.FaultSpecError):
        faults.parse_spec("kernel_raise:*:zero")


def test_fault_plan_is_deterministic():
    """Same (seed, call sequence) -> identical fired log; the firing hash
    has no RNG state to perturb."""
    logs = []
    for _ in range(2):
        plan = faults.FaultPlan(faults.parse_spec("kernel_raise:*:0.5"),
                                seed=7)
        for _ in range(64):
            plan.fires("kernel_raise", "pallas.ell")
        logs.append(list(plan.fired))
    assert logs[0] == logs[1]
    assert 0 < len(logs[0]) < 64          # prob 0.5 actually thins
    other = faults.FaultPlan(faults.parse_spec("kernel_raise:*:0.5"),
                             seed=8)
    for _ in range(64):
        other.fires("kernel_raise", "pallas.ell")
    assert other.fired != logs[0]


def test_inject_restores_previous_plan():
    assert faults.ACTIVE is None
    with faults.inject("nan_output"):
        assert faults.ACTIVE is not None
        with faults.inject("kernel_raise") as inner:
            assert faults.ACTIVE is inner
        assert faults.ACTIVE is not None and faults.ACTIVE is not inner
    assert faults.ACTIVE is None


def test_site_pattern_addressing():
    with faults.inject("kernel_raise:pallas.*") as plan:
        assert not faults.check("kernel_raise", "jnp.segment")
        with pytest.raises(faults.InjectedFault) as ei:
            faults.fail("kernel_raise", "pallas.ell", slot=3)
        assert ei.value.slot == 3 and ei.value.site == "pallas.ell"
    assert plan.fired == [("kernel_raise", "pallas.ell", 0)]


# ---------------------------------------------------------------------------
# chaos sweep: every fault class -> oracle numerics, zero exceptions
# ---------------------------------------------------------------------------

CHAOS_SPECS = [
    "kernel_raise:*",
    "nan_output:*",
    "marshal_raise:*",
    "tune_raise:*",
    "bake_raise:*",
    "cache_torn_write:*",
    ("kernel_raise:*:0.5,nan_output:*:0.3,marshal_raise:*:0.4,"
     "tune_raise:*:0.5,bake_raise:*:0.5,cache_torn_write:*:0.5"),
]


@pytest.mark.parametrize("spec", CHAOS_SPECS)
def test_chaos_sweep_is_oracle_correct(problem, spec):
    """The acceptance gate in miniature: with the fault class active at
    every site, compile + two calls (cold, steady-state) stay correct and
    raise nothing user-visible."""
    with faults.inject(spec, seed=3) as plan:
        # autotune policy so tune-time injection sites are on the path too
        fast = lilac.compile(naive_spmv, mode="host", policy="autotune")
        _assert_oracle(fast(*_args(problem)), problem)
        _assert_oracle(fast(*_args(problem)), problem)
    info = fast.resilience_info()
    if any(k in spec for k in ("kernel_raise", "nan_output")) \
            and plan.fired:
        # call-path faults must leave a containment trail
        c = info["containment"]
        assert c["contained_exceptions"] + c["nonfinite_outputs"] > 0
        assert c["quarantines"] > 0


def test_chaos_seeds_rotate(problem):
    """The CI chaos gate rotates seeds; any seed must satisfy the same
    contract."""
    for seed in (0, 11, 29):
        reset_shared_quarantine()
        with faults.inject("kernel_raise:*:0.6,nan_output:*:0.4",
                           seed=seed):
            fast = lilac.compile(naive_spmv, mode="host")
            _assert_oracle(fast(*_args(problem)), problem)


def test_chaos_hypothesis_sweep(problem):
    """Property form of the sweep: random rule subsets, probabilities and
    seeds never break the containment contract."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    core_kinds = ["kernel_raise", "nan_output", "marshal_raise",
                  "tune_raise", "bake_raise", "cache_torn_write"]

    @settings(max_examples=8, deadline=None)
    @given(kinds=st.sets(st.sampled_from(core_kinds), min_size=1),
           prob=st.floats(0.2, 1.0),
           seed=st.integers(0, 2 ** 16))
    def check(kinds, prob, seed):
        reset_shared_quarantine()
        spec = ",".join(f"{k}:*:{prob:.3f}" for k in sorted(kinds))
        with faults.inject(spec, seed=seed):
            fast = lilac.compile(naive_spmv, mode="host")
            _assert_oracle(fast(*_args(problem)), problem)

    check()


def test_all_candidates_quarantined_still_correct(problem):
    """Even a quarantine store that already bans every harness leaves the
    reference path: the floor is the un-rewritten program, not an error."""
    q = shared_quarantine()
    for comp in ("spmv_csr",):
        for h in lilac.REGISTRY.harnesses_for(comp):
            q.add(comp, h.name, reason="test: pre-banned")
    fast = lilac.compile(naive_spmv, mode="host")
    _assert_oracle(fast(*_args(problem)), problem)


# ---------------------------------------------------------------------------
# quarantine store
# ---------------------------------------------------------------------------

def test_quarantine_persistence_roundtrip(tmp_path):
    path = tmp_path / "q.json"
    q1 = QuarantineStore(path)
    key = q1.add("spmv_csr", "pallas.ell", "r64|fused",
                 reason="exception: boom", site="pallas.ell")
    assert q1.is_quarantined("spmv_csr", "pallas.ell", "r64|fused")
    assert not q1.is_quarantined("spmv_csr", "pallas.ell")   # other variant
    # a fresh store (fresh process) sees the persisted record
    q2 = QuarantineStore(path)
    assert q2.is_quarantined("spmv_csr", "pallas.ell", "r64|fused")
    rec = q2.active()[key]
    assert rec["reason"].startswith("exception: boom")
    assert rec["site"] == "pallas.ell" and rec["ttl"] > 0


def test_quarantine_ttl_expiry(tmp_path):
    q = QuarantineStore(tmp_path / "q.json")
    q.add("c", "h", reason="transient", ttl=1e-9)
    q.add("c", "h2", reason="permanent", ttl=-1.0)     # <= 0: never expires
    assert not q.is_quarantined("c", "h")              # lazily purged
    assert q.stats.expired == 1
    assert q.is_quarantined("c", "h2")
    assert list(q.active()) == [q.key_of("c", "h2")]


def test_quarantine_survives_torn_write(tmp_path):
    """cache_torn_write at the quarantine store itself: the truncated file
    is sidecar-quarantined and the next reader starts fresh — corrupt
    persistence degrades, never crashes."""
    path = tmp_path / "quarantine.json"
    with faults.inject("cache_torn_write:quarantine"):
        QuarantineStore(path).add("c", "h", reason="x")
    with pytest.raises(json.JSONDecodeError):
        json.loads(path.read_text())                   # really torn
    q2 = QuarantineStore(path)
    assert not q2.is_quarantined("c", "h")
    assert q2.stats.corrupt_recoveries == 1
    assert path.with_suffix(".json.corrupt").exists()
    q2.add("c", "h2", reason="y")                      # store writable again
    assert QuarantineStore(path).is_quarantined("c", "h2")


def test_autotune_cache_torn_write_recovery(tmp_path, problem):
    """Satellite: the autotune cache recovers from a torn JSON file and
    counts the recovery."""
    from repro.core.autotune import AutotuneCache
    path = os.environ["LILAC_AUTOTUNE_CACHE"]
    with faults.inject("cache_torn_write:autotune"):
        fast = lilac.compile(naive_spmv, mode="host", policy="autotune")
        _assert_oracle(fast(*_args(problem)), problem)
    assert os.path.exists(path)
    store = AutotuneCache(path, registry_fingerprint="")
    store.load()
    assert store.stats.corrupt_recoveries == 1
    assert os.path.exists(path + ".corrupt")


def test_plan_cache_torn_write_recovery(problem):
    from repro.core.plan import PlanCache
    path = os.environ["LILAC_PLAN_CACHE"]
    with faults.inject("cache_torn_write:plans"):
        fast = lilac.compile(naive_spmv, mode="host")
        _assert_oracle(fast(*_args(problem)), problem)
    if os.path.exists(path):                  # plan persistence happened
        store = PlanCache(path, registry_fingerprint="")
        store.load()
        assert store.stats.corrupt_recoveries == 1


# ---------------------------------------------------------------------------
# shadow verification
# ---------------------------------------------------------------------------

def test_outputs_close():
    a = np.arange(4.0, dtype=np.float32)
    assert outputs_close(a, a + 1e-7)
    assert not outputs_close(a, a + 1.0)
    assert not outputs_close((a, a), (a,))
    bad = a.copy()
    bad[1] = np.nan
    assert not outputs_close(bad, a)          # NaN only in accelerated out
    assert outputs_close(bad, bad)            # NaN matching reference is ok
    assert outputs_close(np.array([1, 2]), np.array([1, 2]))
    assert not outputs_close(np.array([1, 2]), np.array([1, 3]))


def test_shadow_rate_zero_never_checks(problem):
    fast = lilac.compile(naive_spmv, mode="host")
    for _ in range(4):
        fast(*_args(problem))
    info = fast.resilience_info()
    assert info["shadow_rate"] == 0.0
    assert info["containment"]["shadow_checks"] == 0


def test_shadow_sampling_rate(problem, monkeypatch):
    monkeypatch.setenv("LILAC_SHADOW_RATE", "0.25")
    fast = lilac.compile(naive_spmv, mode="host")
    fast(*_args(problem))                     # cold call tunes + bakes
    for _ in range(8):                        # 8 plan dispatches
        _assert_oracle(fast(*_args(problem)), problem)
    assert fast.resilience_info()["containment"]["shadow_checks"] == 2


def test_shadow_divergence_quarantines_and_retunes(problem, monkeypatch):
    """A plan whose output drifts from the reference is caught by the
    sampled shadow, its selections are quarantined, and the function
    re-tunes onto a correct configuration — the divergent answer is never
    served."""
    monkeypatch.setenv("LILAC_SHADOW_RATE", "1.0")
    fast = lilac.compile(naive_spmv, mode="host")
    _assert_oracle(fast(*_args(problem)), problem)     # tune + bake
    sane = fast._dispatch_plan

    def drifted(plan, leaves):
        return jax.tree.map(lambda x: x + 1.0, sane(plan, leaves))

    monkeypatch.setattr(fast, "_dispatch_plan", drifted)
    out = fast(*_args(problem))               # divergence caught here
    _assert_oracle(out, problem)              # the REFERENCE is served
    info = fast.resilience_info()
    assert info["containment"]["shadow_divergences"] == 1
    assert info["quarantine_active"] >= 1
    monkeypatch.setattr(fast, "_dispatch_plan", sane)
    _assert_oracle(fast(*_args(problem)), problem)     # re-tuned + correct
    assert fast.resilience_info()["containment"]["shadow_divergences"] == 1


def test_shadow_rate_spikes_on_divergence_then_decays(problem, monkeypatch):
    """LILAC_SHADOW_RATE is a floor: a caught divergence spikes the
    effective rate by LILAC_SHADOW_SPIKE, and a clean streak decays it
    geometrically back toward the floor."""
    monkeypatch.setenv("LILAC_SHADOW_RATE", "1.0")
    fast = lilac.compile(naive_spmv, mode="host")
    _assert_oracle(fast(*_args(problem)), problem)     # tune + bake
    sane = fast._dispatch_plan
    monkeypatch.setattr(
        fast, "_dispatch_plan",
        lambda plan, leaves: jax.tree.map(lambda x: x + 1.0,
                                          sane(plan, leaves)))
    _assert_oracle(fast(*_args(problem)), problem)     # divergence caught
    shadow = fast.resilience_info()["shadow"]
    assert shadow["multiplier"] >= 8.0
    assert shadow["peak_multiplier"] >= 8.0
    assert shadow["incidents"] >= 1
    monkeypatch.setattr(fast, "_dispatch_plan", sane)
    for _ in range(8):                                 # clean streak decays
        _assert_oracle(fast(*_args(problem)), problem)
    shadow = fast.resilience_info()["shadow"]
    assert shadow["multiplier"] < 2.0
    assert shadow["peak_multiplier"] >= 8.0            # sticky for gates
    assert shadow["floor"] == 1.0


def test_shadow_rate_spikes_on_quarantine(problem, monkeypatch):
    """A containment quarantine (not just a shadow divergence) is an
    incident: the adaptive controller densifies checking after one."""
    monkeypatch.setenv("LILAC_SHADOW_RATE", "0.05")
    fast = lilac.compile(naive_spmv, mode="host")
    with faults.inject("kernel_raise"):
        _assert_oracle(fast(*_args(problem)), problem)  # contained + correct
    info = fast.resilience_info()
    assert info["containment"]["quarantines"] >= 1
    assert info["shadow"]["multiplier"] >= 8.0
    assert info["shadow_rate"] == pytest.approx(
        min(1.0, 0.05 * info["shadow"]["multiplier"]))


def test_report_divergence_quarantines_and_retunes(problem, monkeypatch):
    """The serving tier's out-of-band verifier feeds the same response
    path as an in-band shadow divergence: quarantine the live plan's
    selections, drop the plan, spike the rate, re-tune on next call."""
    fast = lilac.compile(naive_spmv, mode="host")
    _assert_oracle(fast(*_args(problem)), problem)     # tune + bake
    assert fast._last_plan is not None
    fast.report_divergence(reason="request-shadow divergence (rid 7)")
    assert fast._last_plan is None
    info = fast.resilience_info()
    assert info["containment"]["shadow_divergences"] == 1
    assert info["quarantine_active"] >= 1
    assert info["shadow"]["multiplier"] >= 8.0
    _assert_oracle(fast(*_args(problem)), problem)     # re-tunes, stays right


# ---------------------------------------------------------------------------
# serving tier
# ---------------------------------------------------------------------------

def test_try_admit_backoff_and_deadline():
    from repro.serve import Request, Scheduler
    s = Scheduler(2, queue_capacity=1)
    s.submit(Request(prompt=np.array([1]), max_new_tokens=1))
    sleeps = []
    ok = s.try_admit(Request(prompt=np.array([1]), max_new_tokens=1),
                     deadline=10.0, retries=4, backoff_s=0.01,
                     sleep=sleeps.append, clock=lambda: 0.0)
    assert not ok and sleeps == [0.01, 0.02, 0.04]     # bounded, doubling
    # deadline cuts the retry budget short
    t = iter([0.0, 0.0, 5.0]).__next__
    sleeps2 = []
    ok = s.try_admit(Request(prompt=np.array([1]), max_new_tokens=1),
                     deadline=1.0, retries=8, backoff_s=0.01,
                     sleep=sleeps2.append, clock=t)
    assert not ok and len(sleeps2) <= 1
    # a slot freeing mid-backoff lets the admit succeed
    calls = {"n": 0}

    def freeing_sleep(dt):
        calls["n"] += 1
        if calls["n"] == 2:
            s.waiting.popleft()

    ok = s.try_admit(Request(prompt=np.array([1]), max_new_tokens=1),
                     retries=8, backoff_s=0.001, sleep=freeing_sleep,
                     clock=lambda: 0.0)
    assert ok and calls["n"] == 2


def test_poisoned_request_evicted_survivors_bit_identical():
    """A decode fault evicts ONLY the poisoned request; every surviving
    stream matches its solo reference bit for bit (seed 0 of the chaos
    plan fails some requests and spares others — both sets non-empty)."""
    from repro.serve import Request
    eng = _mock_engine(batch=(4,), seq=(64,))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, 50, size=4).astype(np.int32),
                    max_new_tokens=6) for _ in range(4)]
    with faults.inject("decode_raise:decode:0.15,decode_nan:decode:0.1",
                       seed=0):
        for r in reqs:
            assert eng.submit(r)
        finished = eng.run_until_idle()
    assert len(finished) == len(reqs)         # everyone terminates
    failed = [r for r in reqs if r.failed]
    survived = [r for r in reqs if r.failed is None]
    assert failed and survived
    for r in survived:
        assert list(r.tokens) == _solo_stream(list(r.prompt),
                                              r.max_new_tokens)
    snap = eng.metrics.snapshot()
    res = snap["resilience"]
    assert res["decode_faults"] >= len(failed)
    assert res["fault_evictions"] == len(failed)


def test_decode_fault_reasons_are_recorded():
    eng = _mock_engine(batch=(2,), seq=(64,))
    from repro.serve import Request
    r1 = Request(prompt=np.array([3, 4], np.int32), max_new_tokens=4)
    with faults.inject("decode_raise:decode"):
        eng.submit(r1)
        eng.run_until_idle()
    assert r1.failed is not None and r1.failed.startswith("decode:")
    eng2 = _mock_engine(batch=(2,), seq=(64,))
    r2 = Request(prompt=np.array([3, 4], np.int32), max_new_tokens=4)
    with faults.inject("decode_nan:decode"):
        eng2.submit(r2)
        eng2.run_until_idle()
    assert r2.failed == "non-finite decode logits"


def test_deadline_evicts_active_and_waiting():
    """Requests past their deadline are evicted (active: via compaction;
    waiting: dropped from the queue) and counted separately."""
    now = {"t": 1.0}
    cfg_clock = lambda: now["t"]                              # noqa: E731
    from repro.serve import BucketPolicy, Request, ServeConfig
    from repro.serve.engine import Engine
    cfg = ServeConfig(buckets=BucketPolicy(batch=(1,), seq=(64,)),
                      use_lilac=False, jit_prefill=False, deadline_s=5.0)
    eng = Engine(MockModel(), params=None, config=cfg, clock=cfg_clock)
    r_active = Request(prompt=np.array([1, 2], np.int32),
                       max_new_tokens=50)
    r_waiting = Request(prompt=np.array([3], np.int32), max_new_tokens=50)
    assert eng.submit(r_active) and eng.submit(r_waiting)
    assert r_active.deadline_s == 5.0                  # config default
    eng.step()                                         # admits r_active only
    assert eng.scheduler.n_active == 1
    now["t"] = 7.0                                     # both past deadline
    eng.run_until_idle()
    assert r_active.failed == "deadline"
    assert r_waiting.failed == "deadline"
    assert not eng.scheduler.waiting
    res = eng.metrics.snapshot()["resilience"]
    assert res["deadline_evictions"] == 2
    assert res["fault_evictions"] == 2


def test_engine_admit_deadline_uses_try_admit():
    """config.admit_deadline_s routes submission through bounded
    retry-with-backoff and records timeouts instead of raising."""
    eng = _mock_engine(batch=(1,), seq=(64,), queue_capacity=1,
                       admit_deadline_s=0.02)
    import time
    from repro.serve import Request
    assert eng.submit(Request(prompt=np.array([1], np.int32),
                              max_new_tokens=2))
    t0 = time.perf_counter()
    ok = eng.submit(Request(prompt=np.array([2], np.int32),
                            max_new_tokens=2))
    dt = time.perf_counter() - t0
    assert not ok and dt < 5.0                         # bounded, not a spin
    res = eng.metrics.snapshot()["resilience"]
    assert res["admission_timeouts"] == 1
    assert res["admission_retries"] >= 1
    assert eng.metrics.rejected == 1


def test_serving_chaos_hypothesis():
    """Property: under random decode-fault plans, batching terminates,
    nothing escapes, and every survivor matches its solo stream."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           p_raise=st.floats(0.0, 0.4), p_nan=st.floats(0.0, 0.4))
    def check(seed, p_raise, p_nan):
        from repro.serve import Request
        eng = _mock_engine(batch=(2, 4), seq=(64,))
        rng = np.random.default_rng(seed)
        reqs = [Request(prompt=rng.integers(1, 50, size=3).astype(np.int32),
                        max_new_tokens=5) for _ in range(5)]
        spec = (f"decode_raise:decode:{p_raise:.3f},"
                f"decode_nan:decode:{p_nan:.3f}")
        with faults.inject(spec, seed=seed):
            for r in reqs:
                assert eng.submit(r)
            eng.run_until_idle()
        for r in reqs:
            assert r.done
            if r.failed is None:
                assert list(r.tokens) == _solo_stream(list(r.prompt),
                                                      r.max_new_tokens)

    check()
