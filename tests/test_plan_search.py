"""Joint whole-program plan search (repro.core.plan_search):

  * a two-match coupled program where the jointly-optimal assignment
    beats independent per-match winners (the shared-repack flip)
  * beam width 1 is exactly the sequential greedy baseline
  * property: the search never returns an assignment costlier than
    greedy's (hypothesis-tested over random cost tables)
  * end-to-end: the pass manager's joint pass flips per-match pins on a
    rigged timer, re-persists them, and a warm plan-cache process serves
    the joint assignment with ZERO re-search
  * schema 3 -> 4 migration: old records serve verbatim at non-epilogue
    sites (zero re-timing) and demote to sweep priors only where the new
    fuse dimension actually exists
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.core import plan_search as PS
from repro.core.autotune import Autotuner, AutotuneCache, signature_of
from repro.core.harness import CallCtx, HarnessRegistry
from repro.core.marshal import MarshalPolicy, MarshalingCache
from repro.core.plan_search import (Candidate, MarshalReq,
                                    cost_of_assignment, greedy_assignment,
                                    independent_assignment, search)
from repro.core.spec import register_spec
from repro.sparse import csr_from_dense
from repro.sparse.random import random_dense_sparse

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # pragma: no cover - baked into the CI image
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# pure search: rigged cost tables
# ---------------------------------------------------------------------------

class _StubGraph:
    """Exact-hit-only conversion graph: a format already built rides free,
    anything else pays the measured full path."""

    def plan_cost(self, starts, dst):
        if dst in starts:
            return starts[dst], (dst,)
        return None


# kernel seconds: 'seg' marshal-free, 'ell' faster kernel + one repack.
# With reuse=30 and repack M=0.03: per-match amortized ell = 1e-3 +
# 0.03/30 = 2e-3 > seg's 1.8e-3, so greedy picks seg at every match —
# but two matches SHARING the repack cost 2e-3 + 1e-3 = 3e-3 jointly
# versus 3.6e-3 for seg/seg.  (Flip window: M/(2*delta) < reuse <
# M/delta with delta = 0.8e-3, i.e. 18.75 < 30 < 37.5.)
_REQ = MarshalReq(matrix="A", src="csr_binding", dst="ELL8", full_s=0.03)


def _coupled_table():
    return [Candidate("seg", 1.8e-3),
            Candidate("ell", 1.0e-3, reqs=(_REQ,))]


def test_joint_beats_independent_on_coupled_program():
    tables = [_coupled_table(), _coupled_table()]
    res = search(tables, graph=_StubGraph(), sources={}, reuse=30.0, width=8)
    assert [c.harness for c in res.assignment] == ["ell", "ell"]
    assert res.cost == pytest.approx(3.0e-3)
    assert res.independent_cost == pytest.approx(3.6e-3)
    assert res.joint_vs_independent > 1.0
    # the sharing-blind baseline picks ell at both sites too (each pays
    # its own repack), and its reported cost is the assignment's true
    # shared-plane cost — the same arithmetic search() minimizes
    ind = independent_assignment(tables, _StubGraph(), {}, 30.0)
    assert ind[1] == pytest.approx(res.independent_cost)
    assert ind[1] == pytest.approx(
        cost_of_assignment(ind[0], _StubGraph(), {}, 30.0))
    # the frontier surfaces the runner-up states for plan_info()
    assert res.frontier and res.frontier[0]["cost_s"] == pytest.approx(res.cost)


def test_single_match_search_is_the_per_match_winner():
    tables = [_coupled_table()]
    res = search(tables, graph=_StubGraph(), sources={}, reuse=30.0, width=8)
    # one match cannot share anything: amortized argmin = seg
    assert [c.harness for c in res.assignment] == ["seg"]
    assert res.cost == res.greedy_cost == res.independent_cost


def test_beam_width_one_equals_greedy():
    tables = [_coupled_table(), _coupled_table(), _coupled_table()]
    g_picks, g_cost = greedy_assignment(tables, _StubGraph(), {}, 30.0)
    res = search(tables, graph=_StubGraph(), sources={}, reuse=30.0, width=1)
    # width 1 explores exactly the greedy chain; the never-worse clamp can
    # only substitute a baseline, so cost matches greedy (or independent
    # when that happens to be cheaper — not here)
    assert res.cost == pytest.approx(min(g_cost, res.independent_cost))
    assert res.beam_width == 1


def test_prior_ranks_first_and_wins_ties():
    # identical costs: the stable sort must keep the prior (table head)
    tables = [[Candidate("prior", 1e-3), Candidate("other", 1e-3)]]
    res = search(tables, reuse=1.0, width=4)
    assert res.assignment[0].harness == "prior"


def test_beam_width_env(monkeypatch):
    monkeypatch.setenv(PS.ENV_BEAM, "3")
    assert PS.beam_width() == 3
    monkeypatch.setenv(PS.ENV_BEAM, "junk")
    assert PS.beam_width() == PS.DEFAULT_BEAM


if HAVE_HYPOTHESIS:
    @st.composite
    def _tables(draw):
        n_matches = draw(st.integers(1, 4))
        fmts = ["F1", "F2"]
        tables = []
        for _ in range(n_matches):
            n_c = draw(st.integers(1, 4))
            cands = []
            for j in range(n_c):
                kernel = draw(st.floats(1e-5, 1e-2, allow_nan=False))
                reqs = ()
                if draw(st.booleans()):
                    full = draw(st.floats(0.0, 0.1, allow_nan=False))
                    fmt = fmts[draw(st.integers(0, 1))]
                    reqs = (MarshalReq("M", "src", fmt, full_s=full),)
                cands.append(Candidate(f"h{j}", kernel, reqs=reqs))
            tables.append(cands)
        return tables

    @settings(max_examples=60, deadline=None)
    @given(tables=_tables(), reuse=st.floats(1.0, 200.0),
           width=st.integers(1, 6))
    def test_search_never_costlier_than_greedy(tables, reuse, width):
        g = _StubGraph()
        _, g_cost = greedy_assignment(tables, g, {}, reuse)
        res = search(tables, graph=g, sources={}, reuse=reuse, width=width)
        assert res.cost <= g_cost + 1e-12
        assert res.cost <= res.independent_cost + 1e-12
        # the reported cost is reproducible from the assignment itself
        assert res.cost == pytest.approx(
            cost_of_assignment(res.assignment, g, {}, reuse))


# ---------------------------------------------------------------------------
# end-to-end: the pass manager's joint pass on a rigged timer
# ---------------------------------------------------------------------------

def _seg_body(b, ctx):
    row = jnp.repeat(jnp.arange(b["rows"], dtype=jnp.int32),
                     jnp.diff(b["rowstr"]), total_repeat_length=b["nnz"])
    return jax.ops.segment_sum(b["a"] * b["iv"][b["colidx"]], row,
                               num_segments=b["rows"])


def _ell_body(b, ctx, *, ell=None):
    # the marshaled ELL8 pack arrives as the ``ell`` kwarg; numerics here
    # reuse the CSR arrays (identical result) — the repack cost and its
    # sharing across matches is what's under test
    return _seg_body(b, ctx)


def _coupled_registry():
    reg = HarnessRegistry()
    register_spec("""
HARNESS toy.seg implements spmv_csr
  formats CSR;
""", {"toy.seg": _seg_body}, registry=reg)
    register_spec("""
HARNESS toy.ell implements spmv_csr
  formats CSR;
  marshal ell = ell_pack(a, colidx, rowstr|rowidx) from csr_binding to ELL8;
""", {"toy.ell": _ell_body}, registry=reg)
    reg._defaults[("spmv_csr", jax.default_backend())] = "toy.seg"
    return reg


def _rig(monkeypatch, kernel_s, marshal_s):
    """Deterministic timer + marshal estimate, keyed by harness name."""
    def fake_time(self, h, binding, ctx, mode, operands, schedule, reps):
        return kernel_s[h.name]

    monkeypatch.setattr(Autotuner, "_time_variant", fake_time)
    monkeypatch.setattr(
        Autotuner, "_marshal_cost",
        staticmethod(lambda h, ctx: marshal_s.get(h.name, 0.0)))


def _coupled_problem(n=64):
    csr = csr_from_dense(random_dense_sparse(n, n, 0.2, 0))
    vec = jnp.asarray(np.random.default_rng(1)
                      .standard_normal(n).astype(np.float32))

    def naive(val, col, row_ptr, v):
        def spmv(x):
            row = jnp.repeat(jnp.arange(n, dtype=jnp.int32),
                             jnp.diff(row_ptr),
                             total_repeat_length=csr.nnz)
            return jax.ops.segment_sum(val * x[col], row, num_segments=n)
        return spmv(spmv(v))            # A @ (A @ v): two coupled matches

    return csr, vec, naive


def test_joint_pass_flips_coupled_pins(monkeypatch, tmp_path):
    """Two spmv matches on the SAME matrix: greedy pins the marshal-free
    backend twice; the joint pass flips both to the faster kernel sharing
    one repack, drops nothing, and re-persists the joint pins."""
    reg = _coupled_registry()
    _rig(monkeypatch, {"toy.seg": 1.8e-3, "toy.ell": 1.0e-3},
         {"toy.ell": 0.03})
    csr, vec, naive = _coupled_problem()
    acc = lilac.compile(naive, mode="host", policy="autotune", registry=reg,
                        marshal_policy=MarshalPolicy(reuse=30.0))
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    entry = next(iter(acc._compiled.values()))
    assert len(entry.report.matches) == 2
    # the first call tuned per-match: greedy winners ran...
    assert [n for _, n in acc.last_selections] == ["toy.seg", "toy.seg"]
    # ...then the joint pass flipped the pins and reported the win
    assert entry.joint_done
    assert entry.pins == {0: ("toy.ell", None, None),
                          1: ("toy.ell", None, None)}
    assert entry.joint["joint_vs_independent"] > 1.0
    assert entry.joint["cost_s"] < entry.joint["independent_cost_s"]

    # second call serves the joint assignment; the shared repack rides
    out2 = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)
    assert [n for _, n in acc.last_selections] == ["toy.ell", "toy.ell"]
    # same (matrix, src, dst) for both matches: the second is an exact
    # cache hit — the cost-0 sharing the joint search priced in
    stats = acc.cache.plan_stats()
    assert sum(s["hits"] for s in stats.values()) >= 1


def test_plan_stats_ride_counters():
    """A partial-prefix ride (another entry's cached intermediate entering
    the path at cost 0) is counted per plan entry: ``rides`` and the bytes
    of intermediate it avoided rebuilding (``shared_prefix_bytes``)."""
    from repro.core.marshal import DataPlane

    csr = csr_from_dense(random_dense_sparse(32, 32, 0.3, 0))
    binding = {"a": np.asarray(csr.val), "colidx": np.asarray(csr.col_ind),
               "rowstr": np.asarray(csr.row_ptr),
               "iv": np.ones(32, np.float32),
               "rows": csr.rows, "nnz": csr.nnz}
    keys = (binding["a"], binding["colidx"], binding["rowstr"])
    dp = DataPlane()
    dp.ensure("csr_binding", "DENSE", keys, binding)
    # BCSR8x128 routes CSR -> DENSE -> BCSR8x128: the cached DENSE is a
    # strict prefix, so this ensure RIDES it and only runs the last edge
    dp.ensure("csr_binding", "BCSR8x128", keys, binding)
    stats = dp.plan_stats()
    ride_entry = stats["csr_binding->BCSR8x128"]
    assert ride_entry["rides"] == 1
    assert ride_entry["shared_prefix_bytes"] > 0
    assert dp.stats.loader_runs == 1    # the binding was loaded ONCE


def test_joint_disabled_by_beam_zero(monkeypatch):
    monkeypatch.setenv(PS.ENV_BEAM, "0")
    reg = _coupled_registry()
    _rig(monkeypatch, {"toy.seg": 1.8e-3, "toy.ell": 1.0e-3},
         {"toy.ell": 0.03})
    csr, vec, naive = _coupled_problem()
    acc = lilac.compile(naive, mode="host", policy="autotune", registry=reg,
                        marshal_policy=MarshalPolicy(reuse=30.0))
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    entry = next(iter(acc._compiled.values()))
    # pure per-match greedy: pins stand, search skipped but marked done
    assert entry.joint_done and entry.joint is None
    assert entry.pins == {0: ("toy.seg", None, None),
                          1: ("toy.seg", None, None)}


def test_warm_plan_cache_serves_joint_pins_with_zero_research(
        monkeypatch, tmp_path):
    """A second LilacFunction over the same jaxpr rehydrates the JOINT
    pins from the plan cache and never re-runs the search (the acceptance
    property: warm processes pay nothing for joint optimality)."""
    reg = _coupled_registry()
    _rig(monkeypatch, {"toy.seg": 1.8e-3, "toy.ell": 1.0e-3},
         {"toy.ell": 0.03})
    csr, vec, naive = _coupled_problem()
    pc = str(tmp_path / "joint_plans.json")
    acc = lilac.compile(naive, mode="host", policy="autotune", registry=reg,
                        marshal_policy=MarshalPolicy(reuse=30.0),
                        plan_cache=pc)
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    entry = next(iter(acc._compiled.values()))
    assert entry.pins[0][0] == "toy.ell"

    def boom(*a, **k):          # any re-search in the warm path is a bug
        raise AssertionError("joint search re-ran on a warm entry")

    monkeypatch.setattr(PS, "optimize_entry", boom)
    acc2 = lilac.compile(naive, mode="host", policy="autotune", registry=reg,
                         marshal_policy=MarshalPolicy(reuse=30.0),
                         plan_cache=pc)
    out = acc2(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    entry2 = next(iter(acc2._compiled.values()))
    assert entry2.joint_done
    assert entry2.pins == entry.pins
    assert [n for _, n in acc2.last_selections] == ["toy.ell", "toy.ell"]
    # the persisted joint report rides along for observability
    assert entry2.joint is not None
    assert entry2.joint["joint_vs_independent"] > 1.0


# ---------------------------------------------------------------------------
# schema 3 -> 4 migration
# ---------------------------------------------------------------------------

def _v3_record(winner, timings):
    return {"harness": winner, "best_s": timings[winner],
            "timings": dict(timings), "marshal_s": {},
            "amortized_s": dict(timings), "cost_model": "amortized",
            "schedule": None, "schedules": {}, "variant_s": {},
            "schedule_swept": True}


def test_v3_migration_serves_verbatim_without_fuse_dimension(
        tmp_path, monkeypatch):
    """No epilogue at the site and/or no fuse-capable candidate: the
    schema-3 winner is authoritative — served with zero re-timing."""
    reg = HarnessRegistry()
    for name in ("toy.a", "toy.b"):
        register_spec(f"""
HARNESS {name} implements spmv_csr
  formats CSR;
""", {name: lambda b, ctx: np.zeros(b["rows"], np.float32)}, registry=reg)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    binding = {"a": np.ones(8, np.float32),
               "colidx": np.zeros(8, np.int32),
               "rowstr": np.linspace(0, 8, 9).astype(np.int32),
               "iv": np.ones(8, np.float32), "rows": 8, "nnz": 8}
    sig = signature_of("spmv_csr", "CSR", "cpu", binding)
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({
        "schema": 3, "registry": "fp", "entries": {
            sig: {"host": _v3_record("toy.b",
                                     {"toy.a": 2e-3, "toy.b": 1e-3})}}}))
    cache = AutotuneCache(path, registry_fingerprint="fp")
    tuner = Autotuner(registry_fingerprint="fp", cache=cache, budget=4)
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    w = tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx,
                     default_name="toy.a")
    assert w.name == "toy.b"
    assert tuner.stats.timing_calls == 0
    assert cache.stats.migrations == 1


def test_v3_migration_demotes_to_prior_when_fuse_dimension_exists(
        tmp_path, monkeypatch):
    """Epilogue site + fuse-capable candidate: the unswept fuse dimension
    makes the old winner a PRIOR — re-swept once, prior measured first."""
    reg = HarnessRegistry()
    register_spec("""
HARNESS toy.fusing implements spmv_csr
  formats CSR;
  fuse epilogue;
""", {"toy.fusing": lambda b, ctx: np.zeros(b["rows"], np.float32)},
        registry=reg)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    binding = {"a": np.ones(8, np.float32),
               "colidx": np.zeros(8, np.int32),
               "rowstr": np.linspace(0, 8, 9).astype(np.int32),
               "iv": np.ones(8, np.float32), "rows": 8, "nnz": 8,
               "bias": np.zeros(8, np.float32)}
    sig = signature_of("spmv_csr", "CSR", "cpu", binding, epilogue="relu")
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({
        "schema": 3, "registry": "fp", "entries": {
            sig: {"host": _v3_record("toy.fusing",
                                     {"toy.fusing": 1e-3})}}}))
    cache = AutotuneCache(path, registry_fingerprint="fp")
    tuner = Autotuner(registry_fingerprint="fp", cache=cache, budget=4)

    timed = []

    def fake_time(self, h, binding, ctx, mode, operands, schedule, reps):
        timed.append((h.name, getattr(ctx, "fuse", None)))
        return 1e-3 if getattr(ctx, "fuse", None) else 2e-3

    monkeypatch.setattr(Autotuner, "_time_variant", fake_time)
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR",
                  epilogue="relu")
    w = tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx,
                     default_name="toy.fusing")
    assert w.name == "toy.fusing"
    assert timed, "fuse dimension must be re-swept"
    # both realizations were measured; the fused one won and is recorded
    assert {f for _, f in timed} == {True, False}
    rec = cache.get(sig, "host")
    assert rec["fuse_swept"] is True
    assert rec["fuse"] is True
    # second lookup: served, no further timing
    timed.clear()
    tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx,
                 default_name="toy.fusing")
    assert timed == []
