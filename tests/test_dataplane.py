"""The format-aware data plane (ISSUE 3): conversion-graph planning,
plan-level sharing, cost-aware LRU eviction, fingerprint semantics, and
marshal-cost-aware autotuning.

Property tests run under hypothesis when it is installed (CI extras) and
fall back to seeded parametrized sweeps otherwise, so the equivalence
guarantees are exercised in every environment.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.core import harness as H
from repro.core import marshal as M
from repro.core import spec as SP
from repro.sparse import random_csr


def _csr_binding(csr, vec):
    return {"a": csr.val, "colidx": csr.col_ind, "rowstr": csr.row_ptr,
            "iv": vec, "rows": csr.rows, "nnz": csr.nnz}


def _tree_equal(a, b) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb:
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# direct (single-hop) repack oracle per target format, as registered in the
# builtin REPACKS table
_ORACLES = {
    "ELL8": "ell_pack",
    "ELL128": "ell_pack128",
    "DENSE": "densify",
    "BCSR8x128": "bcsr_pack",
    "BCSR128x128": "bcsr_pack128",
}


def _check_planned_equals_direct(rows, cols, density, seed, dst):
    csr = random_csr(rows, cols, density=density, seed=seed)
    vec = jnp.ones(cols)
    binding = _csr_binding(csr, vec)
    keys = (binding["a"], binding["colidx"], binding["rowstr"])
    plane = M.DataPlane()
    planned = plane.ensure("csr_binding", dst, keys, binding)
    direct = SP.REPACKS[_ORACLES[dst]](binding)
    assert _tree_equal(planned, direct), (dst, rows, cols, density, seed)


@pytest.mark.parametrize("dst", sorted(_ORACLES))
@pytest.mark.parametrize("rows,cols,density,seed", [
    (16, 16, 0.3, 0), (32, 24, 0.1, 1), (64, 48, 0.05, 2), (8, 40, 0.5, 3),
])
def test_planned_path_bit_identical_to_direct_repack(rows, cols, density,
                                                     seed, dst):
    """Any path the planner picks (including multi-hop CSR->DENSE->BCSR)
    produces bit-identical output to the legacy single-hop repack."""
    _check_planned_equals_direct(rows, cols, density, seed, dst)


def test_planned_path_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(rows=st.integers(4, 48), cols=st.integers(4, 48),
               density=st.floats(0.02, 0.6), seed=st.integers(0, 999),
               dst=st.sampled_from(sorted(_ORACLES)))
    @hyp.settings(max_examples=25, deadline=None)
    def prop(rows, cols, density, seed, dst):
        _check_planned_equals_direct(rows, cols, density, seed, dst)

    prop()


def test_plan_rides_cached_intermediate_bit_identical():
    """Priming DENSE then planning BCSR must reuse the cached DENSE buffer
    (shared prefix) and still equal the direct repack bit-for-bit."""
    csr = random_csr(32, 24, density=0.2, seed=0)
    binding = _csr_binding(csr, jnp.ones(24))
    keys = (binding["a"], binding["colidx"], binding["rowstr"])
    plane = M.DataPlane()
    plane.ensure("csr_binding", "DENSE", keys, binding)
    runs_before = plane.stats.loader_runs
    bcsr = plane.ensure("csr_binding", "BCSR8x128", keys, binding)
    assert plane.stats.loader_runs == runs_before      # no second load
    assert plane.stats.shared_edge_hits >= 1
    ps = plane.plans[("csr_binding", "BCSR8x128")]
    assert ps.last_path[0] == "DENSE"                  # started at the cache
    direct = SP.REPACKS["bcsr_pack"](binding)
    assert _tree_equal(bcsr, direct)


def test_plan_cache_shared_across_two_harnesses():
    """Two harnesses targeting overlapping formats on ONE DataPlane share
    buffers: jnp.bcsr's CSR->DENSE->BCSR path rides the DENSE intermediate
    jnp.dense cached, and a repeat call is a pure plan-cache hit."""
    csr = random_csr(32, 24, density=0.2, seed=0)
    vec = jnp.ones(24)

    def naive(val, col, row_ptr, vec):
        row = jnp.repeat(jnp.arange(32, dtype=jnp.int32), jnp.diff(row_ptr),
                         total_repeat_length=val.shape[0])
        return jax.ops.segment_sum(val * vec[col], row, num_segments=32)

    plane = lilac.DataPlane()
    # bake=False: this test asserts the INTERPRETER path's per-call cache
    # accounting; a baked plan hoists the buffers and never consults the
    # plane again (that fast path is covered in test_dispatch.py)
    dense_f = lilac.compile(naive, mode="host", policy="jnp.dense",
                            cache=plane, bake=False)
    bcsr_f = lilac.compile(naive, mode="host", policy="jnp.bcsr",
                           cache=plane, bake=False)
    out_d = dense_f(csr.val, csr.col_ind, csr.row_ptr, vec)
    loader_runs = plane.stats.loader_runs
    out_b = bcsr_f(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_b),
                               rtol=1e-5, atol=1e-5)
    assert plane.stats.loader_runs == loader_runs       # binding loaded once
    ps = plane.plans[("csr_binding", "BCSR8x128")]
    assert ps.shared_prefix_hits == 1 and ps.last_path[0] == "DENSE"
    # steady state: repeat calls hit the plan cache, zero edge executions
    edges = plane.stats.edge_runs
    bcsr_f(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert plane.stats.edge_runs == edges
    assert ps.hits == 1 and ps.bytes_avoided > 0


def test_sampled_fingerprint_collision_vs_exact():
    """Above the full-hash threshold the fingerprint samples: a change in
    an unsampled position collides under the default mode but is caught by
    exact=True (the documented trade-off apps opt into)."""
    n = (1 << 16) // 4 + 4096            # > _SMALL bytes of f32
    a = np.zeros(n, np.float32)
    step = max(1, n // 1024)
    # find an index the strided sample and the 64-element edges never read
    idx = next(i for i in range(65, n - 65) if i % step)
    b = a.copy()
    b[idx] = 42.0
    assert M.fingerprint(a)[0] == "sampled"
    assert M.fingerprint(a) == M.fingerprint(b)                  # collision
    assert M.fingerprint(a, exact=True) != M.fingerprint(b, exact=True)
    # and a DataPlane with exact=True keys distinguishes them
    plane = M.DataPlane(policy=M.MarshalPolicy(exact=True))
    assert plane._key("x", (a,)) != plane._key("x", (b,))


def test_tracked_array_versioning_keys_cache():
    """TrackedArray versions replace hashing: same buffer, bumped version
    -> different key; cache keyed on it recomputes exactly once."""
    cache = M.MarshalingCache()
    t = M.TrackedArray(np.ones(8))
    calls = []
    cache.get("p", (t,), lambda: calls.append(1) or "v0")
    cache.get("p", (t,), lambda: calls.append(1) or "v0")
    assert len(calls) == 1
    t2 = t.replace(np.ones(8))           # same CONTENT, new version
    cache.get("p", (t2,), lambda: calls.append(1) or "v1")
    assert len(calls) == 2


def test_cost_aware_lru_keeps_hot_entry_under_churn():
    """The seed cache popped next(iter(store)) — insertion order — so the
    hottest entry died under churn.  Cost-aware LRU keeps it alive."""
    cache = M.MarshalingCache(max_entries=4)
    hot = np.arange(16, dtype=np.float32)
    cache.get("hot", (hot,), lambda: "HOT")
    for i in range(16):
        cache.get("hot", (hot,), lambda: "HOT")     # refresh recency
        cold = np.full(16, float(i), np.float32)
        cache.get(f"cold{i}", (cold,), lambda: i)    # churn
    misses = cache.stats.misses
    cache.get("hot", (hot,), lambda: "HOT")
    assert cache.stats.misses == misses, "hot entry was evicted"


def test_eviction_prefers_cheap_to_recompute():
    """Among the LRU tail, the cheapest-to-recompute entry is evicted
    first, so an expensive repack outlives same-age cheap ones."""
    cache = M.MarshalingCache(max_entries=2)
    cache.EVICT_WINDOW = 2

    def expensive():
        import time
        time.sleep(0.02)
        return "exp"

    a, b, c = (np.full(8, v, np.float32) for v in (1.0, 2.0, 3.0))
    cache.get("exp", (a,), expensive)
    cache.get("cheap", (b,), lambda: "cheap")
    cache.get("new", (c,), lambda: "new")            # forces one eviction
    m = cache.stats.misses
    cache.get("exp", (a,), expensive)                # still cached
    assert cache.stats.misses == m
    cache.get("cheap", (b,), lambda: "cheap")        # this one was evicted
    assert cache.stats.misses == m + 1


class _NoMaterialize:
    """Array stand-in whose data can never be pulled to host."""
    shape = (128, 128)
    dtype = np.dtype(np.float32)
    nbytes = 128 * 128 * 4

    def __array__(self, *a, **k):
        raise AssertionError("cache hit materialized a device array")


def test_bytes_avoided_reads_metadata_only():
    """Satellite: CacheStats.bytes_avoided must come from nbytes/shape
    metadata, not np.asarray(...) (which forces a device->host sync)."""
    cache = M.MarshalingCache()
    t = M.TrackedArray(_NoMaterialize())     # O(1) fingerprint, no hashing
    cache.get("p", (t,), lambda: "packed")
    cache.get("p", (t,), lambda: "packed")   # hit: must NOT materialize
    assert cache.stats.hits == 1
    assert cache.stats.bytes_avoided == _NoMaterialize.nbytes
    assert M.nbytes_of(t) == _NoMaterialize.nbytes


def test_marshal_policy_parse_and_off():
    assert M.MarshalPolicy.parse(None) == M.MarshalPolicy()
    assert M.MarshalPolicy.parse("off").enabled is False
    assert M.MarshalPolicy.parse("exact").exact is True
    p = M.MarshalPolicy(reuse=7.0)
    assert M.MarshalPolicy.parse(p) is p
    with pytest.raises(ValueError):
        M.MarshalPolicy.parse("bogus")

    csr = random_csr(16, 16, density=0.3, seed=0)
    vec = jnp.ones(16)

    def naive(val, col, row_ptr, vec):
        row = jnp.repeat(jnp.arange(16, dtype=jnp.int32), jnp.diff(row_ptr),
                         total_repeat_length=val.shape[0])
        return jax.ops.segment_sum(val * vec[col], row, num_segments=16)

    acc = lilac.compile(naive, mode="host", policy="jnp.ell",
                        marshal_policy="off")
    assert acc.cache is None
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    shared = lilac.compile(naive, mode="host", policy="jnp.ell",
                           marshal_policy=M.MarshalPolicy(reuse=5.0))
    assert isinstance(shared.cache, M.DataPlane)
    assert shared.cache.policy.reuse == 5.0


def test_unknown_marshal_formats_rejected_at_registration():
    with pytest.raises(SP.SpecError, match="unknown marshal source"):
        SP.register_spec(
            "HARNESS bad.src implements dotproduct\n"
            "  marshal x = ell_pack(a) from nowhere to ELL8;\n",
            {"bad.src": lambda b, c, **kw: 0.0},
            registry=H.HarnessRegistry())
    with pytest.raises(SP.SpecError, match="unknown marshal target"):
        SP.register_spec(
            "HARNESS bad.dst implements dotproduct\n"
            "  marshal x = ell_pack(a) from csr_binding to NOPE;\n",
            {"bad.dst": lambda b, c, **kw: 0.0},
            registry=H.HarnessRegistry())


def test_clause_without_formats_uses_legacy_cache_path():
    """Format-less marshal clauses (out-of-repo specs) keep the exact
    legacy MarshalingCache.get semantics on a DataPlane."""
    reg = H.HarnessRegistry()

    @SP.repack("plain_pack", override=True)
    def plain_pack(b):
        return float(np.asarray(b["a"]).sum())

    SP.register_spec(
        "HARNESS plain.h implements dotproduct\n"
        "  marshal s = plain_pack(a);\n",
        {"plain.h": lambda b, c, *, s: s},
        registry=reg)
    h = reg.get("dotproduct", "plain.h")
    plane = M.DataPlane()
    ctx = H.CallCtx(mode="host", cache=plane, format="DOT")
    a = np.arange(8, dtype=np.float32)
    assert h({"a": a, "b": a}, ctx) == a.sum()
    assert h({"a": a, "b": a}, ctx) == a.sum()
    assert plane.stats.hits == 1 and plane.stats.misses == 1
    assert plane.stats.edge_runs == 0


def test_format_and_edge_registries():
    assert "CSR" in M.FORMATS and "BCSR128x128" in M.FORMATS
    with pytest.raises(ValueError):
        M.register_format(M.SparseFormat("CSR", "different"))
    # planner: CSR reaches every builtin target
    for dst in _ORACLES:
        assert M.GRAPH.full_path_cost("CSR", dst) is not None
    # and an unknown start has no path
    assert M.GRAPH.plan({"NOPE": 0.0}, "DENSE") is None


# ---------------------------------------------------------------------------
# Marshal-aware autotuning + schema migration
# ---------------------------------------------------------------------------

def _mk_harness(name, fn, marshal=()):
    return H.Harness(name, "spmv_csr", fn, jit_safe=False, marshal=marshal)


def test_autotune_amortized_winner_folds_marshal_cost(tmp_path):
    """A harness with a fast kernel but a ruinous repack loses to a
    marshal-free harness once the repack is amortized at the declared call
    frequency — and wins when reuse is high enough to amortize it."""
    from repro.core.autotune import Autotuner

    timings = {"fastkernel": 1e-4, "nofuss": 5e-4}
    marshal_s = {"fastkernel": 1.0}
    low = Autotuner.amortized(timings, marshal_s, reuse=10.0)
    high = Autotuner.amortized(timings, marshal_s, reuse=1e7)
    assert min(low, key=low.get) == "nofuss"
    assert min(high, key=high.get) == "fastkernel"


def test_autotune_schema1_migration_no_stale_winners(tmp_path):
    """A schema-1 cache file is migrated (not discarded): its measurements
    survive as kernel_only records, served verbatim for marshal-free
    candidate sets but re-measured when a marshaling candidate is in play."""
    import json

    from repro.core.autotune import Autotuner, AutotuneCache

    path = tmp_path / "autotune.json"
    fp = "fp-test"
    sig_args = ("spmv_csr", "CSR", "cpu",
                {"rows": 64, "nnz": 256, "iv": np.ones(64, np.float32)})
    from repro.core.autotune import signature_of
    sig = signature_of(*sig_args)
    with open(path, "w") as f:
        json.dump({"schema": 1, "registry": fp,
                   "entries": {sig: {"host": {
                       "harness": "legacy.winner",
                       "best_s": 1e-4,
                       "timings": {"legacy.winner": 1e-4}}}}}, f)

    cache = AutotuneCache(path, registry_fingerprint=fp).load()
    assert cache.stats.migrations == 1
    rec = cache.get(sig, "host")
    assert rec["cost_model"] == "kernel_only"
    assert rec["harness"] == "legacy.winner"

    tuner = Autotuner(registry_fingerprint=fp, cache=cache, budget=4)
    plane = M.DataPlane()
    ctx = H.CallCtx(mode="host", cache=plane, format="CSR")
    binding = {"rows": 64, "nnz": 256, "iv": jnp.ones(64)}

    # marshal-free candidates: migrated record is served with zero re-timing
    free = [_mk_harness("legacy.winner", lambda b, c: jnp.zeros(64)),
            _mk_harness("other", lambda b, c: jnp.zeros(64))]
    chosen = tuner.select("spmv_csr", "CSR", "cpu", "host", free,
                          binding, ctx)
    assert chosen.name == "legacy.winner"
    assert tuner.stats.timing_calls == 0

    # a marshaling candidate appears: the kernel-only winner is NOT served
    # stale — the tuner re-measures and stores an amortized record
    clause = lilac.MarshalClause("x", "ell_pack", (("a",),),
                                 src="csr_binding", dst="ELL8")
    cands = free + [_mk_harness("marshaled", lambda b, c: jnp.zeros(64),
                                marshal=(clause,))]
    tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx)
    assert tuner.stats.remeasures == 1
    assert tuner.stats.timing_calls > 0
    rec2 = cache.get(sig, "host")
    assert rec2["cost_model"] == "amortized"


def test_autotune_schema_mismatch_invalidates(tmp_path):
    import json

    from repro.core.autotune import AutotuneCache

    path = tmp_path / "autotune.json"
    with open(path, "w") as f:
        json.dump({"schema": 99, "registry": "fp", "entries": {"x": {}}}, f)
    cache = AutotuneCache(path, registry_fingerprint="fp").load()
    assert cache.entries == {}
    assert cache.stats.invalidations == 1


def test_tiny_cache_never_evicts_fresh_insert():
    """max_entries < EVICT_WINDOW must not evict the value being inserted
    (and ensure's fallback path must return it, not re-read the store)."""
    cache = M.MarshalingCache(max_entries=2)
    import time as _t
    for i in range(6):
        a = np.full(8, float(i), np.float32)
        got = cache.get(f"k{i}", (a,), lambda i=i: (_t.sleep(0.001), i)[1])
        assert got == i
    plane = M.DataPlane(policy=M.MarshalPolicy(max_entries=2))
    for i in range(4):
        a = np.full(8, float(i), np.float32)
        slow = lambda i=i: (_t.sleep(0.002), f"fb{i}")[1]
        got = plane.ensure("csr_binding", "COO", (a,), {}, fallback=slow)
        assert got == f"fb{i}"       # COO unreachable -> fallback path


def test_reuse_change_rederives_winner_without_retiming(tmp_path):
    """A persisted amortized record tuned at one call frequency serves the
    CORRECT winner for a different declared frequency, arithmetically."""
    from repro.core.autotune import Autotuner, AutotuneCache, signature_of

    fp = "fp-reuse"
    binding = {"rows": 64, "nnz": 256, "iv": jnp.ones(64)}
    sig = signature_of("spmv_csr", "CSR", "cpu", binding)
    cache = AutotuneCache(tmp_path / "a.json", registry_fingerprint=fp)
    cache.loaded = True
    cache.put(sig, "host", {
        "harness": "fastkernel", "best_s": 1e-4,
        "timings": {"fastkernel": 1e-4, "nofuss": 5e-4},
        "marshal_s": {"fastkernel": 1.0}, "reuse": 1e7,
        "amortized_s": {}, "cost_model": "amortized",
    }, persist=False)
    tuner = Autotuner(registry_fingerprint=fp, cache=cache, budget=4)
    cands = [_mk_harness("fastkernel", lambda b, c: 0),
             _mk_harness("nofuss", lambda b, c: 0)]
    # declared frequency 10: the 1s repack no longer amortizes
    plane = M.DataPlane(policy=M.MarshalPolicy(reuse=10.0))
    ctx = H.CallCtx(mode="host", cache=plane, format="CSR")
    chosen = tuner.select("spmv_csr", "CSR", "cpu", "host", cands,
                          binding, ctx)
    assert chosen.name == "nofuss"
    assert tuner.stats.timing_calls == 0          # no re-timing
    # matching frequency: recorded winner served as-is
    plane7 = M.DataPlane(policy=M.MarshalPolicy(reuse=1e7))
    ctx7 = H.CallCtx(mode="host", cache=plane7, format="CSR")
    assert tuner.select("spmv_csr", "CSR", "cpu", "host", cands,
                        binding, ctx7).name == "fastkernel"


def test_fallback_repack_cost_visible_to_estimator():
    """A format clause served by its fallback (no graph path) still
    reports its measured cost to the autotuner's amortized model."""
    import time as _t
    empty = M.ConversionGraph()
    plane = M.DataPlane(graph=empty)
    a = np.arange(8, dtype=np.float32)
    plane.ensure("csr_binding", "ELL8", (a,), {},
                 fallback=lambda: (_t.sleep(0.005), "packed")[1])
    clause = lilac.MarshalClause("x", "ell_pack", (("a",),),
                                 src="csr_binding", dst="ELL8")
    assert plane.estimate_marshal_seconds([clause]) >= 0.005


def test_datapane_marshal_seconds_estimate():
    """After one ensure, the plane can price a harness's marshal clauses
    from measured edge costs (what the tuner amortizes)."""
    csr = random_csr(32, 24, density=0.2, seed=0)
    binding = _csr_binding(csr, jnp.ones(24))
    keys = (binding["a"], binding["colidx"], binding["rowstr"])
    plane = M.DataPlane()
    plane.ensure("csr_binding", "ELL8", keys, binding)
    clause = lilac.MarshalClause("ell", "ell_pack", (("a",),),
                                 src="csr_binding", dst="ELL8")
    est = plane.estimate_marshal_seconds([clause])
    assert est > 0.0
    # unknown formats fall back to last measured repack cost (0 here)
    legacy = dataclasses.replace(clause, src=None, dst=None)
    assert plane.estimate_marshal_seconds([legacy]) == 0.0
