"""Rewrite + harness correctness (paper §4.1.2): the optimized program
must compute the same values as the original, for every backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.core import REGISTRY
from repro.sparse import random_csr


ROWS, COLS = 64, 48


@pytest.fixture(scope="module")
def problem():
    csr = random_csr(ROWS, COLS, density=0.12, seed=1)
    rng = np.random.default_rng(2)
    vec = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    return csr, vec


def naive_spmv(val, col, row_ptr, vec):
    row = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                     total_repeat_length=val.shape[0])
    return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)


def test_trace_mode_equivalence(problem):
    csr, vec = problem
    ref = naive_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    opt = lilac.compile(naive_spmv)
    out = opt(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert len(opt.last_report.matches) == 1
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_trace_mode_is_jittable(problem):
    csr, vec = problem
    ref = naive_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    opt = lilac.compile(naive_spmv)
    out = jax.jit(lambda *a: opt(*a))(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(out, ref, atol=1e-5)


@pytest.mark.parametrize("backend", ["jnp.segment", "jnp.ell", "jnp.bcsr",
                                     "jnp.dense", "pallas.ell", "pallas.bcsr"])
def test_every_backend_equivalent(problem, backend):
    """Table 2's premise: all harnesses compute the same function."""
    csr, vec = problem
    ref = naive_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    acc = lilac.compile(naive_spmv, mode="host", policy=backend)
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_unmatched_code_passes_through(problem):
    csr, vec = problem

    def f(val, col, row_ptr, vec):
        y = naive_spmv(val, col, row_ptr, vec)
        return jnp.tanh(y) + 1.0, y.sum()

    opt = lilac.compile(f)
    out, s = opt(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref_y = naive_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(out, jnp.tanh(ref_y) + 1.0, atol=1e-5)
    np.testing.assert_allclose(s, ref_y.sum(), rtol=1e-5)


def test_disabled_pass_is_identity(problem):
    csr, vec = problem
    opt = lilac.compile(naive_spmv, enabled=False)
    out = opt(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(out, ref, atol=0)


def test_loop_form_rewrite():
    rng = np.random.default_rng(3)
    val = jnp.asarray(rng.standard_normal(40).astype(np.float32))
    row = jnp.asarray(rng.integers(0, 16, 40).astype(np.int32))
    col = jnp.asarray(rng.integers(0, 8, 40).astype(np.int32))
    vec = jnp.asarray(rng.standard_normal(8).astype(np.float32))

    def f(val, row, col, vec):
        def body(j, out):
            return out.at[row[j]].add(val[j] * vec[col[j]])
        return jax.lax.fori_loop(0, 40, body, jnp.zeros(16))

    ref = f(val, row, col, vec)
    opt = lilac.compile(f)
    out = opt(val, row, col, vec)
    assert opt.last_report.matches[0].variant == "loop"
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_moe_rewrite_flop_reduction():
    """The rewritten MoE must be numerically equal AND compile to fewer
    FLOPs (the paper's speedup, visible in cost_analysis)."""
    from repro.models.layers import _moe_naive_2d
    rng = np.random.default_rng(0)
    T, D, F, E, K = 64, 32, 64, 8, 2
    args = (jnp.asarray(rng.standard_normal((T, D)).astype(np.float32)),
            jnp.asarray(rng.random((T, K)).astype(np.float32)),
            jnp.asarray(rng.integers(0, E, (T, K)).astype(np.int32)),
            jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1),
            jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1),
            jnp.asarray(rng.standard_normal((E, F, D)).astype(np.float32) * .1))
    ref = _moe_naive_2d(*args)
    opt = lilac.compile(_moe_naive_2d)
    out = opt(*args)
    np.testing.assert_allclose(out, ref, atol=1e-4)
    c0 = jax.jit(_moe_naive_2d).lower(*args).compile().cost_analysis()
    c1 = jax.jit(lambda *a: opt(*a)).lower(*args).compile().cost_analysis()
    # older jaxlibs return a per-device list, newer ones a flat dict
    c0 = c0[0] if isinstance(c0, (list, tuple)) else c0
    c1 = c1[0] if isinstance(c1, (list, tuple)) else c1
    assert c1["flops"] < 0.7 * c0["flops"]


def test_autotune_policy(problem):
    csr, vec = problem
    acc = lilac.compile(naive_spmv, mode="host", policy="autotune")
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)
    # winner is cached per signature
    assert len(REGISTRY._autotune_cache) >= 1
