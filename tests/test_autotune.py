"""Persistent autotuning subsystem (repro.core.autotune):

  * signature bucketing is stable and shape/sparsity-aware
  * cache round-trips through its on-disk JSON form
  * atomic merge-on-save keeps concurrent tuners' entries
  * entries invalidate when the harness set / registry version changes
  * trace-mode winners are pinned deterministically into the rewrite
  * a fresh process warm-starts from disk with ZERO candidate re-timing
"""
import json
import os
import subprocess
import sys
import textwrap
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.core.autotune import (AutotuneCache, Autotuner, pow2_bucket,
                                 signature_of, sparsity_bucket,
                                 synthesize_operands)
from repro.core.harness import REGISTRY, CallCtx, Harness, HarnessRegistry
from repro.core.marshal import MarshalingCache
from repro.sparse import csr_from_dense
from repro.sparse.random import random_dense_sparse

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# problem helpers
# ---------------------------------------------------------------------------

def _problem(n=96, density=0.1, seed=0):
    csr = csr_from_dense(random_dense_sparse(n, n, density, seed))
    vec = jnp.asarray(np.random.default_rng(seed + 1)
                      .standard_normal(n).astype(np.float32))
    return csr, vec


def _naive_fn(rows, nnz):
    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)
    return naive


def _toy_registry(delays):
    """Registry with named dummy harnesses whose runtime we control."""
    reg = HarnessRegistry()

    def make(delay):
        def fn(b, ctx):
            time.sleep(delay)
            return np.zeros(b["rows"], np.float32)
        return fn

    for name, delay in delays.items():
        reg.register(Harness(name, "spmv_csr", make(delay),
                             formats=("CSR",)))
    reg._defaults[("spmv_csr", "cpu")] = next(iter(delays))
    return reg


def _toy_binding(rows=64, nnz=512, cols=64):
    return {"a": np.ones(nnz, np.float32),
            "colidx": np.zeros(nnz, np.int32),
            "rowstr": np.linspace(0, nnz, rows + 1).astype(np.int32),
            "iv": np.ones(cols, np.float32),
            "rows": rows, "nnz": nnz}


# ---------------------------------------------------------------------------
# signatures
# ---------------------------------------------------------------------------

def test_buckets():
    assert pow2_bucket(0) == 0
    assert pow2_bucket(1) == 1
    assert pow2_bucket(5) == 8
    assert pow2_bucket(4096) == 4096
    assert pow2_bucket(4097) == 8192
    assert sparsity_bucket(0.05) == "d-2"
    assert sparsity_bucket(1.0) == "d0"
    assert sparsity_bucket(0.0) == "d?"


def test_signature_buckets_similar_problems_together():
    a = signature_of("spmv_csr", "CSR", "cpu", _toy_binding(64, 500))
    b = signature_of("spmv_csr", "CSR", "cpu", _toy_binding(64, 512))
    c = signature_of("spmv_csr", "CSR", "cpu", _toy_binding(128, 4096))
    assert a == b
    assert a != c
    assert "spmv_csr|CSR|cpu" in a


def test_signature_agrees_between_tracers_and_values():
    """Trace-mode lowering (avals) and host-mode execution (arrays) must
    compute the same key, or warm-starts would never hit."""
    binding = _toy_binding()
    sig_concrete = signature_of("spmv_csr", "CSR", "cpu", binding)
    captured = {}

    def probe(a, colidx, rowstr, iv):
        captured["sig"] = signature_of(
            "spmv_csr", "CSR", "cpu",
            {"a": a, "colidx": colidx, "rowstr": rowstr, "iv": iv,
             "rows": binding["rows"], "nnz": binding["nnz"]})
        return a

    jax.make_jaxpr(probe)(binding["a"], binding["colidx"],
                          binding["rowstr"], binding["iv"])
    assert captured["sig"] == sig_concrete


def test_synthesize_operands_valid_indices():
    binding = _toy_binding(rows=32, nnz=100, cols=48)
    ops = synthesize_operands(binding)
    assert np.asarray(ops["colidx"]).max() < 48
    ptr = np.asarray(ops["rowstr"])
    assert ptr[0] == 0 and ptr[-1] == 100
    assert (np.diff(ptr) >= 0).all()


# ---------------------------------------------------------------------------
# cache persistence
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    path = tmp_path / "autotune.json"
    c1 = AutotuneCache(path, registry_fingerprint="fp1")
    rec = {"harness": "jnp.ell", "best_s": 1e-4, "timings": {"jnp.ell": 1e-4}}
    c1.put("sig-a", "host", rec)
    assert path.exists()
    c2 = AutotuneCache(path, registry_fingerprint="fp1").load()
    assert c2.entries["sig-a"]["host"] == rec
    # and the file itself is well-formed, versioned JSON
    doc = json.loads(path.read_text())
    assert doc["schema"] >= 1 and doc["registry"] == "fp1"


def test_cache_atomic_under_concurrent_tuners(tmp_path):
    """N writers with independent cache instances: the merged file must be
    valid JSON containing every writer's entry (merge-on-save + flock)."""
    path = tmp_path / "autotune.json"
    n = 8
    errors = []

    def writer(i):
        try:
            c = AutotuneCache(path, registry_fingerprint="fp")
            c.put(f"sig-{i}", "host", {"harness": f"h{i}", "best_s": 1.0,
                                       "timings": {}})
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    merged = AutotuneCache(path, registry_fingerprint="fp").load()
    assert set(merged.entries) == {f"sig-{i}" for i in range(n)}
    json.loads(path.read_text())  # parses cleanly


def test_cache_invalidation_on_fingerprint_change(tmp_path):
    path = tmp_path / "autotune.json"
    c1 = AutotuneCache(path, registry_fingerprint="fp-old")
    c1.put("sig-a", "host", {"harness": "x", "best_s": 1.0, "timings": {}})
    c2 = AutotuneCache(path, registry_fingerprint="fp-new").load()
    assert c2.entries == {}
    assert c2.stats.invalidations == 1


def test_registry_version_bump_invalidates(tmp_path):
    """The registry fingerprint folds in ``version``: bumping it yields a
    fresh tuner whose warm-start drops stale winners."""
    reg = _toy_registry({"slow": 0.01, "fast": 0.0})
    fp0 = reg.fingerprint()
    tuner0 = reg.autotuner
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    h = tuner0.select("spmv_csr", "CSR", "cpu", "host", cands,
                      _toy_binding(), ctx, default_name="slow")
    assert h.name == "fast" and tuner0.stats.timing_calls == 2

    reg.version += 1
    assert reg.fingerprint() != fp0
    tuner1 = reg.autotuner
    assert tuner1 is not tuner0
    h = tuner1.select("spmv_csr", "CSR", "cpu", "host", cands,
                      _toy_binding(), ctx, default_name="slow")
    assert h.name == "fast"
    # stale entry was NOT trusted: candidates were re-measured
    assert tuner1.stats.timing_calls == 2
    assert tuner1.stats.disk_hits == 0


def test_budget_zero_falls_back_to_default():
    reg = _toy_registry({"slow": 0.01, "fast": 0.0})
    tuner = Autotuner(registry_fingerprint=reg.fingerprint(), budget=0)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    h = tuner.select("spmv_csr", "CSR", "cpu", "host", cands,
                     _toy_binding(), ctx, default_name="slow")
    assert h is None                      # registry falls back to default
    assert tuner.stats.timing_calls == 0
    assert tuner.stats.fallbacks == 1


def test_budget_limits_explored_candidates():
    reg = _toy_registry({"deflt": 0.002, "b": 0.01, "c": 0.01, "d": 0.01})
    tuner = Autotuner(registry_fingerprint=reg.fingerprint(), budget=2)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    h = tuner.select("spmv_csr", "CSR", "cpu", "host", cands,
                     _toy_binding(), ctx, default_name="deflt")
    assert tuner.stats.timing_calls == 2  # top-k only
    assert h.name == "deflt"              # default ranked first, and fastest


# ---------------------------------------------------------------------------
# end-to-end: host mode, trace mode, cross-process
# ---------------------------------------------------------------------------

def test_host_autotune_persists_and_warm_starts_in_process():
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    acc = lilac.compile(naive, mode="host", policy="autotune")
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    tuner = REGISTRY.autotuner
    assert tuner.stats.timing_calls > 0
    winner = acc.last_selections[0][1]
    assert tuner.cache.path.exists()

    # a SECOND LilacFunction over the same signature: no re-timing
    timed = tuner.stats.timing_calls
    acc2 = lilac.compile(naive, mode="host", policy="autotune")
    acc2(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert acc2.last_selections[0][1] == winner
    assert tuner.stats.timing_calls == timed


def test_trace_mode_winner_pinning_determinism():
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    opt = lilac.compile(naive, policy="autotune")
    out = opt(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    winner = opt.last_selections[0][1]
    entry = next(iter(opt._compiled.values()))
    # pinned into the rewrite as a (harness, schedule, fuse) triple; the
    # jnp.* winners declare no tune space and the site has no epilogue,
    # so both variant dimensions are None
    assert entry.pins == {0: (winner, None, None)}

    # repeat calls and re-traces reuse the pin: deterministic, no timing
    tuner = REGISTRY.autotuner
    timed = tuner.stats.timing_calls
    for _ in range(3):
        opt(csr.val, csr.col_ind, csr.row_ptr, vec)
        assert opt.last_selections[0][1] == winner
    jitted = jax.jit(lambda *a: opt(*a))
    out = jitted(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    assert opt.last_selections[0][1] == winner
    assert tuner.stats.timing_calls == timed

    # a fresh LilacFunction over the same signature selects the same winner
    opt2 = lilac.compile(naive, policy="autotune")
    opt2(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert opt2.last_selections[0][1] == winner
    assert tuner.stats.timing_calls == timed


_SUBPROC = textwrap.dedent("""
    import json, sys
    import numpy as np, jax, jax.numpy as jnp
    from repro import lilac
    from repro.core import REGISTRY
    from repro.sparse import csr_from_dense
    from repro.sparse.random import random_dense_sparse

    csr = csr_from_dense(random_dense_sparse(96, 96, 0.1, 0))
    rows, nnz = csr.rows, csr.nnz
    vec = jnp.asarray(np.random.default_rng(1)
                      .standard_normal(96).astype(np.float32))

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)

    acc = lilac.compile(naive, mode="host", policy="autotune")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    print(json.dumps({
        "selected": acc.last_selections[0][1],
        "stats": REGISTRY.autotuner.stats.as_dict(),
    }))
""")


def test_autotune_persists_across_processes(tmp_path):
    """The acceptance criterion: run the same problem in two FRESH
    processes.  The second must read the cache file and skip candidate
    timing entirely, selecting the identical harness."""
    cache = tmp_path / "autotune.json"
    env = dict(os.environ,
               LILAC_AUTOTUNE_CACHE=str(cache),
               # this test exercises the TUNER's own persistence: disable
               # the executable-plan cache, whose rehydrated pins would
               # otherwise skip the tuner in the second process entirely
               # (that path has its own test in test_dispatch.py)
               LILAC_PLAN_CACHE_DISABLE="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(ROOT, "src"),
                    os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))

    def run_once():
        p = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                           capture_output=True, text=True, timeout=600)
        assert p.returncode == 0, p.stderr
        return json.loads(p.stdout.strip().splitlines()[-1])

    first = run_once()
    assert first["stats"]["timing_calls"] > 0      # cold: measured
    assert cache.exists()
    mtime = cache.stat().st_mtime

    second = run_once()
    assert second["selected"] == first["selected"]  # same harness
    assert second["stats"]["timing_calls"] == 0     # zero re-timing
    assert second["stats"]["disk_hits"] >= 1        # cache file was read
    assert cache.stat().st_mtime == mtime           # and not re-written


def test_autotune_disable_env(monkeypatch):
    monkeypatch.setenv("LILAC_AUTOTUNE_DISABLE", "1")
    REGISTRY.reset_autotuner()
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    acc = lilac.compile(naive, mode="host", policy="autotune")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    tuner = REGISTRY.autotuner
    assert tuner.stats.timing_calls == 0
    assert tuner.stats.fallbacks >= 1
    assert not tuner.cache.path.exists()
