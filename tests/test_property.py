"""Hypothesis property tests on the system's invariants:

  * rewrite soundness — for ANY random sparse problem, every backend
    agrees with the naive formulation (float-reassociation tolerance)
  * detection is syntax-insensitive and false-positive-safe
  * format conversions are semantic identities
  * marshaling fingerprints are sound (no stale-cache results)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import lilac
from repro.core.marshal import fingerprint
from repro.sparse import (
    csr_from_dense, ell_from_csr, jds_from_csr,
    spmv_csr_ref, spmv_ell_ref, spmv_jds_ref,
)
from repro.sparse.random import random_dense_sparse


@st.composite
def sparse_problem(draw):
    rows = draw(st.integers(4, 48))
    cols = draw(st.integers(4, 48))
    density = draw(st.floats(0.02, 0.5))
    seed = draw(st.integers(0, 2**16))
    d = random_dense_sparse(rows, cols, density, seed)
    vec = np.random.default_rng(seed + 1).standard_normal(cols).astype(np.float32)
    return d, vec


@settings(max_examples=25, deadline=None)
@given(sparse_problem())
def test_formats_are_semantic_identities(prob):
    d, vec = prob
    csr = csr_from_dense(d)
    expect = d @ vec
    np.testing.assert_allclose(spmv_csr_ref(csr, jnp.asarray(vec)), expect,
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(
        spmv_jds_ref(jds_from_csr(csr), jnp.asarray(vec)), expect,
        atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(
        spmv_ell_ref(ell_from_csr(csr), jnp.asarray(vec)), expect,
        atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(sparse_problem())
def test_rewrite_soundness_any_problem(prob):
    d, vec = prob
    csr = csr_from_dense(d)
    rows = csr.rows
    nnz = csr.nnz
    if nnz == 0:
        return

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)

    ref = naive(csr.val, csr.col_ind, csr.row_ptr, jnp.asarray(vec))
    opt = lilac.compile(naive)
    out = opt(csr.val, csr.col_ind, csr.row_ptr, jnp.asarray(vec))
    assert len(opt.last_report.matches) == 1
    assert opt.last_report.matches[0].format == "CSR"
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(sparse_problem(), st.sampled_from(["jnp.ell", "jnp.bcsr", "jnp.dense"]))
def test_host_backends_any_problem(prob, backend):
    d, vec = prob
    csr = csr_from_dense(d)
    rows, nnz = csr.rows, csr.nnz
    if nnz == 0:
        return

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)

    ref = naive(csr.val, csr.col_ind, csr.row_ptr, jnp.asarray(vec))
    acc = lilac.compile(naive, mode="host", policy=backend)
    out = acc(csr.val, csr.col_ind, csr.row_ptr, jnp.asarray(vec))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                min_size=1, max_size=64),
       st.integers(0, 63))
def test_fingerprint_soundness(xs, flip):
    """Any single-element change must change the fingerprint (full-hash
    regime below the sampling threshold)."""
    a = np.asarray(xs, dtype=np.float32)
    b = a.copy()
    i = flip % a.shape[0]
    b[i] = b[i] + 1.0
    assert fingerprint(a) != fingerprint(b)
    assert fingerprint(a) == fingerprint(a.copy())


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(2, 8), st.integers(1, 4),
       st.integers(0, 1000))
def test_moe_grouped_equals_dense_dispatch(e_log, t_pow, k, seed):
    """Grouped (capacity) dispatch == naive dense dispatch whenever no
    token is dropped (cf chosen to guarantee it)."""
    E = 2 ** e_log
    T = 2 ** t_pow
    K = min(k, E)
    rng = np.random.default_rng(seed)
    D, F = 16, 32
    x = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    gate = jnp.asarray(rng.random((T, K)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, E, (T, K)).astype(np.int32))
    w = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32) * .1)  # noqa: E731
    wg, wu, wd = w(E, D, F), w(E, D, F), w(E, F, D)
    from repro.models.layers import _moe_grouped_2d, _moe_naive_2d
    ref = _moe_naive_2d(x, gate, idx, wg, wu, wd)
    out = _moe_grouped_2d(x, gate, idx, wg, wu, wd,
                          capacity_factor=float(E))   # C >= T*K -> no drops
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)
