"""End-to-end behaviour tests for the paper's system: unmodified solver
apps get accelerated by the LiLAC pass and still converge to the right
answers (the paper's Fig. 1 user experience)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.sparse import csr_from_dense
from repro.sparse.random import random_graph_csr


def _sym_pd_csr(n=48, seed=0):
    """Symmetric positive-definite sparse matrix (for CG)."""
    from repro.sparse.random import random_dense_sparse
    a = random_dense_sparse(n, n, 0.1, seed)
    a = (a + a.T) / 2
    a = a + n * np.eye(n, dtype=np.float32)
    return csr_from_dense(a), a


def _naive_spmv_fn(rows, nnz):
    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)
    return naive


def test_cg_solver_accelerated_converges():
    """NPB-CG analogue: the CG loop's SpMV is written naively; the LiLAC
    host pass rewrites it; the solution still satisfies Ax=b."""
    csr, a = _sym_pd_csr()
    n = a.shape[0]
    b = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    spmv = lilac.compile(_naive_spmv_fn(n, csr.nnz), mode="host")

    x = jnp.zeros(n)
    r = jnp.asarray(b) - spmv(csr.val, csr.col_ind, csr.row_ptr, x)
    p = r
    rs = jnp.dot(r, r)
    for _ in range(60):
        ap = spmv(csr.val, csr.col_ind, csr.row_ptr, p)
        alpha = rs / jnp.dot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        if float(rs_new) < 1e-10:
            break
        p = r + (rs_new / rs) * p
        rs = rs_new
    np.testing.assert_allclose(a @ np.asarray(x), b, atol=1e-3)
    assert len(spmv.last_report.matches) == 1


def test_pagerank_accelerated():
    """PageRank: repeated SpMV with the SAME matrix — the marshaling cache
    must convert once and hit on every subsequent iteration (Fig. 18).
    ``bake=False`` pins the interpreter path whose per-call cache hits the
    assertions count; with baking on (the default) the repeat calls skip
    the cache entirely via the baked plan, asserted alongside."""
    g = random_graph_csr(64, avg_degree=6, seed=3)
    n = g.rows
    spmv = lilac.compile(_naive_spmv_fn(n, g.nnz), mode="host",
                         policy="jnp.ell", bake=False)
    x = jnp.ones(n) / n
    for _ in range(20):
        x = 0.85 * spmv(g.val, g.col_ind, g.row_ptr, x) + 0.15 / n
    assert abs(float(x.sum()) - 1.0) < 0.2
    st = spmv.cache.stats
    assert st.misses == 1 and st.hits == 19

    # the baked path reaches the same fixed point with ONE cache miss and
    # zero further marshal-cache traffic (the repack is hoisted)
    fast = lilac.compile(_naive_spmv_fn(n, g.nnz), mode="host",
                         policy="jnp.ell")
    y = jnp.ones(n) / n
    for _ in range(20):
        y = 0.85 * fast(g.val, g.col_ind, g.row_ptr, y) + 0.15 / n
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5,
                               atol=1e-6)
    assert fast.cache.stats.misses == 1
    assert fast.plan_info()["plan_hits"] == 19


def test_bfs_accelerated():
    """BFS as boolean-semiring SpMV over the graph."""
    g = random_graph_csr(32, avg_degree=4, seed=5)
    n = g.rows
    val01 = jnp.asarray((np.asarray(g.val) > 0).astype(np.float32))
    spmv = lilac.compile(_naive_spmv_fn(n, g.nnz), mode="host")
    frontier = jnp.zeros(n).at[0].set(1.0)
    visited = frontier
    for _ in range(8):
        nxt = spmv(val01, g.col_ind, g.row_ptr, frontier)
        frontier = jnp.where((nxt > 0) & (visited == 0), 1.0, 0.0)
        visited = jnp.maximum(visited, frontier)
    # reference BFS on dense adjacency
    dense = np.asarray(g.todense()) > 0
    ref_visited = np.zeros(n, bool)
    ref_visited[0] = True
    fr = ref_visited.copy()
    for _ in range(8):
        nxt = dense @ fr
        fr = nxt & ~ref_visited
        ref_visited |= fr
    np.testing.assert_array_equal(np.asarray(visited) > 0, ref_visited)


def test_training_with_lilac_moe_matches_naive():
    """The LM framework path: a model with moe_impl='lilac' (detection +
    rewrite inside the layer) computes the same loss as moe_impl='naive'
    when the capacity factor guarantees no drops."""
    from repro.configs import get_arch, smoke_config
    from repro.models import build_model

    base = smoke_config(get_arch("olmoe-1b-7b")).replace(capacity_factor=8.0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (2, 16))
                                   .astype(np.int32)),
             "labels": jnp.asarray(rng.integers(0, 256, (2, 16))
                                   .astype(np.int32))}
    losses = {}
    params = None
    for impl in ("naive", "lilac"):
        cfg = base.replace(moe_impl=impl)
        model = build_model(cfg)
        if params is None:
            params = model.init(jax.random.key(0))
        losses[impl] = float(model.loss_fn(params, batch))
    assert abs(losses["naive"] - losses["lilac"]) < 1e-2, losses


def test_quickstart_example_runs():
    import os
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "..",
                                      "examples", "quickstart.py")],
        capture_output=True, text=True, timeout=600,
        env={**os.environ,
             "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src")})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "speedup" in proc.stdout.lower()


def test_train_sparse_moe_example_runs():
    """Transform-composition flow: lilac.compile(value_and_grad) detects,
    rewrites the gradient jaxpr, bakes, and the loss goes down."""
    import os
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "..",
                                      "examples", "train_sparse_moe.py"),
         "--steps", "6"],
        capture_output=True, text=True, timeout=600,
        env={**os.environ,
             "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src")})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "baked=1" in proc.stdout, proc.stdout[-2000:]
    assert "bake_errors=[]" in proc.stdout, proc.stdout[-2000:]


def test_serve_example_runs():
    """Full serving flow through the repro.serve client: prewarm ->
    continuous batching -> metrics."""
    import os
    import subprocess
    import sys
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "..",
                                      "examples", "serve.py"),
         "--requests", "3", "--tokens", "6"],
        capture_output=True, text=True, timeout=600,
        env={**os.environ,
             "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src")})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "bucket plans baked" in proc.stdout
    assert "finished=3" in proc.stdout
