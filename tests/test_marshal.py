"""Marshaling (paper §3.3.2, Fig. 8/9/14, §6.3): derived invariants are
recomputed only when the underlying data changes."""
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.core import MarshalingCache, ReadObject, TrackedArray, fingerprint
import jax


def test_fingerprint_stable_and_sensitive():
    a = np.arange(100, dtype=np.float32)
    assert fingerprint(a) == fingerprint(a.copy())
    b = a.copy()
    b[50] = -1
    assert fingerprint(a) != fingerprint(b)


def test_cache_hit_on_unchanged_miss_on_changed():
    cache = MarshalingCache()
    calls = []

    def compute():
        calls.append(1)
        return "converted"

    a = np.arange(64, dtype=np.float32)
    cache.get("pack", (a,), compute)
    cache.get("pack", (a,), compute)           # unchanged -> hit
    assert len(calls) == 1
    assert cache.stats.hits == 1
    a2 = a.copy()
    a2[0] = 99
    cache.get("pack", (a2,), compute)          # changed -> recompute
    assert len(calls) == 2


def test_tracked_array_versioning():
    t = TrackedArray(np.ones(8))
    f1 = fingerprint(t)
    t2 = t.replace(np.zeros(8))
    assert fingerprint(t2) != f1
    assert fingerprint(t) == f1                # original unchanged


def test_read_object_construct_update_destruct():
    """Fig. 14 contract: construct before first use / on shape change;
    update on content change; destruct between constructs."""
    log = []
    ro = ReadObject(
        construct=lambda a: log.append("construct") or a.sum(),
        update=lambda a, s: log.append("update") or a.sum(),
        destruct=lambda s: log.append("destruct"),
    )
    a = np.ones(8, np.float32)
    ro.read(a)
    ro.read(a)                      # no change -> nothing
    ro.read(a * 2)                  # content change -> update
    ro.read(np.ones(16, np.float32))  # shape change -> destruct+construct
    ro.release()
    assert log == ["construct", "update", "destruct", "construct", "destruct"]


def test_marshaling_cols_invariant():
    """Fig. 9: `cols = max(colidx)+1` recomputed only when colidx changes —
    exercised through the ELL harness cache keys."""
    from repro.sparse import random_csr

    csr = random_csr(32, 24, density=0.2, seed=0)
    vec = jnp.ones(24)

    def naive(val, col, row_ptr, vec):
        row = jnp.repeat(jnp.arange(32, dtype=jnp.int32), jnp.diff(row_ptr),
                         total_repeat_length=val.shape[0])
        return jax.ops.segment_sum(val * vec[col], row, num_segments=32)

    acc = lilac.compile(naive, mode="host", policy="jnp.ell")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    m0 = acc.cache.stats.misses
    acc(csr.val, csr.col_ind, csr.row_ptr, vec * 3)   # vec changed, matrix not
    assert acc.cache.stats.misses == m0               # pack reused
    acc(csr.val * 2, csr.col_ind, csr.row_ptr, vec)   # matrix changed
    assert acc.cache.stats.misses == m0 + 1
