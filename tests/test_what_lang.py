"""LiLAC-What language: grammar, parser, AST helpers (paper Fig. 3)."""
import pytest

from repro.core import what_lang as W


def test_parse_spmv_csr_roundtrip():
    comp = W.BUILTINS["spmv_csr"]
    assert comp.name == "spmv_csr"
    foralls = comp.foralls()
    assert len(foralls) == 1
    assert foralls[0].range.var == "i"
    stmt = comp.stmt()
    assert isinstance(stmt.target, W.Load)
    assert stmt.target.array == "output"
    # ragged range: rowstr[i] <= j < rowstr[i+1]
    assert isinstance(stmt.range.lo, W.Load)
    assert stmt.range.lo.array == "rowstr"


def test_free_arrays_defines_harness_interface():
    comp = W.BUILTINS["spmv_csr"]
    # paper §3.1: What identifies the variables that become harness args
    assert set(comp.free_arrays()) == {"output", "rowstr", "a", "iv", "colidx"}
    assert "rows" in comp.free_scalars()


def test_parse_dot():
    comp = W.parse("""
    COMPUTATION dotp
    result = sum(0 <= i < n) a[i] * b[i];
    """)
    assert comp.name == "dotp"
    assert isinstance(comp.stmt().target, W.Var)
    assert set(comp.free_arrays()) == {"a", "b"}


def test_parse_jds_nested_index():
    comp = W.BUILTINS["spmv_jds"]
    stmt = comp.stmt()
    assert isinstance(stmt.target.index, W.Load)   # output[perm[i]]
    assert stmt.target.index.array == "perm"


def test_parse_errors():
    with pytest.raises(W.ParseError):
        W.parse("COMPUTATION broken forall(0 <= i < n) {")
    with pytest.raises(W.ParseError):
        W.parse("NOTACOMPUTATION x")
    with pytest.raises(W.ParseError):
        W.parse("COMPUTATION x result = sum(0 <= i < n) a[i] * ;")


def test_expression_precedence():
    comp = W.parse("COMPUTATION p r = sum(0 <= i < n) a[i] * b[i] + c[i];")
    expr = comp.stmt().expr
    # * binds tighter than +
    assert isinstance(expr, W.Add)
    assert isinstance(expr.lhs, W.Mul)
