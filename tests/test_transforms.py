"""Transform composition (docs/transforms.md, measured as invariants):

  * ``lilac.compile(jax.grad(f))`` — the *gradient jaxpr* is detected and
    rewritten; grads are bit-comparable to the dense ``jax.grad`` oracle
  * ``jax.grad(lilac.compile(f))`` — differentiating *through* a rewrite:
    natively-differentiable harnesses transpose as-is, opaque kernels ride
    their declared ``vjp`` clause (custom_vjp)
  * ``jax.vmap`` — per-element detection parity with the unbatched rewrite
  * ``lax.scan`` — a sparse step inside the body is detected once and the
    selected kernels are reused every iteration
  * plans bake under a transform trace (a function only ever called from
    inside ``jax.jit``/``jax.grad`` still reaches steady-state dispatch)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.sparse import csr_from_dense, random_csr
from repro.sparse.random import random_dense_sparse

ROWS, COLS = 64, 48


@pytest.fixture(scope="module")
def problem():
    csr = random_csr(ROWS, COLS, density=0.12, seed=7)
    rng = np.random.default_rng(8)
    vec = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    return csr, vec


def naive_spmv(val, col, row_ptr, vec):
    row = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                     total_repeat_length=val.shape[0])
    return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)


def _spy_detect():
    """Count Detector.detect invocations (restored by the caller)."""
    from repro.core import detect as D

    calls = {"n": 0}
    real = D.Detector.detect

    def spy(self, *a, **kw):
        calls["n"] += 1
        return real(self, *a, **kw)

    D.Detector.detect = spy
    return calls, lambda: setattr(D.Detector, "detect", real)


# ---------------------------------------------------------------------------
# grad
# ---------------------------------------------------------------------------

def test_grad_of_compiled_matches_dense_oracle(problem):
    """compile(grad(f)): the backward SpMVᵀ in the gradient jaxpr is itself
    a sparse computation — detection must fire on it, and the result must
    equal the untouched jax.grad."""
    csr, vec = problem

    def loss(val, col, row_ptr, vec):
        return jnp.sum(naive_spmv(val, col, row_ptr, vec) ** 2)

    grad = jax.grad(loss, argnums=(0, 3))
    fast = lilac.compile(grad)
    g_fast = fast(csr.val, csr.col_ind, csr.row_ptr, vec)
    g_ref = grad(csr.val, csr.col_ind, csr.row_ptr, vec)
    for a, b in zip(g_fast, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
    assert fast.last_report is not None and fast.last_report.matches, \
        "the gradient jaxpr must re-detect as sparse"


def test_grad_through_compiled_matches_dense_oracle(problem):
    """grad(compile(f)): the rewrite sits inside the differentiated
    region; jnp-level harnesses transpose natively."""
    csr, vec = problem
    fast = lilac.compile(naive_spmv)

    def loss_fast(val, vec):
        return jnp.sum(fast(val, csr.col_ind, csr.row_ptr, vec) ** 2)

    def loss_ref(val, vec):
        return jnp.sum(naive_spmv(val, csr.col_ind, csr.row_ptr, vec) ** 2)

    g_fast = jax.grad(loss_fast, argnums=(0, 1))(csr.val, vec)
    g_ref = jax.grad(loss_ref, argnums=(0, 1))(csr.val, vec)
    for a, b in zip(g_fast, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_custom_vjp_clause_grad_exact():
    """An opaque Pallas kernel (interpreted off-TPU) is differentiable via
    its HARNESS ``vjp`` clause: grads equal the padded-dense oracle."""
    rng = np.random.default_rng(3)
    width = 6
    val = jnp.asarray(rng.standard_normal((ROWS, width)).astype(np.float32))
    col = jnp.asarray(rng.integers(0, COLS, (ROWS, width)).astype(np.int32))
    vec = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))

    def naive_ell(val, col, vec):
        return jnp.sum(val * vec[col], axis=1)

    fast = lilac.compile(naive_ell, policy="pallas.ell")

    def loss(f):
        return lambda val, vec: jnp.sum(f(val, col, vec) ** 2)

    g_fast = jax.grad(loss(fast), argnums=(0, 1))(val, vec)
    g_ref = jax.grad(loss(naive_ell), argnums=(0, 1))(val, vec)
    assert [n for _, n in fast.last_selections] == ["pallas.ell"]
    for a, b in zip(g_fast, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_grad_matches_oracle_property():
    """Hypothesis: for ANY random sparse problem, grad-through-compiled
    equals the dense oracle."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def prob(draw):
        rows = draw(st.integers(4, 32))
        cols = draw(st.integers(4, 32))
        density = draw(st.floats(0.05, 0.5))
        seed = draw(st.integers(0, 2 ** 16))
        return rows, cols, density, seed

    @settings(max_examples=10, deadline=None)
    @given(prob())
    def check(p):
        rows, cols, density, seed = p
        csr = csr_from_dense(random_dense_sparse(rows, cols, density, seed))
        if csr.nnz == 0:
            return
        vec = jnp.asarray(np.random.default_rng(seed + 1)
                          .standard_normal(cols).astype(np.float32))

        def f(val, col, row_ptr, vec):
            row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                             jnp.diff(row_ptr),
                             total_repeat_length=val.shape[0])
            return jax.ops.segment_sum(val * vec[col], row,
                                       num_segments=rows)

        fast = lilac.compile(f)
        gf = jax.grad(lambda v, x: jnp.sum(fast(v, csr.col_ind, csr.row_ptr,
                                                x) ** 2),
                      argnums=(0, 1))(csr.val, vec)
        gr = jax.grad(lambda v, x: jnp.sum(f(v, csr.col_ind, csr.row_ptr,
                                             x) ** 2),
                      argnums=(0, 1))(csr.val, vec)
        for a, b in zip(gf, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-4)

    check()


# ---------------------------------------------------------------------------
# vmap
# ---------------------------------------------------------------------------

def test_vmap_batched_detection_parity(problem):
    """Detection fires under vmap (batch tracers strip the mapped axis) and
    the batched rewrite equals the batched original."""
    csr, _ = problem
    rng = np.random.default_rng(9)
    vecs = jnp.asarray(rng.standard_normal((5, COLS)).astype(np.float32))
    fast = lilac.compile(naive_spmv)
    out = jax.vmap(lambda v: fast(csr.val, csr.col_ind, csr.row_ptr, v))(vecs)
    ref = jax.vmap(lambda v: naive_spmv(csr.val, csr.col_ind, csr.row_ptr,
                                        v))(vecs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert fast.last_report is not None and fast.last_report.matches, \
        "detection must fire on the per-element jaxpr under vmap"


# ---------------------------------------------------------------------------
# scan
# ---------------------------------------------------------------------------

def test_scan_body_detected_once_and_reused(problem):
    """A sparse step inside lax.scan: the body is detected once (one
    top-level detect + one recursive body detect), the scan is rebuilt
    around the rewritten body, and steady-state calls re-run zero
    detection."""
    csr, vec = problem

    def power_iter(val, col, row_ptr, v0):
        def step(v, _):
            w = naive_spmv(val, col, row_ptr, v)
            w = jnp.pad(w, (0, COLS - ROWS)) if COLS > ROWS else w[:COLS]
            return w / (jnp.linalg.norm(w) + 1e-6), None

        out, _ = jax.lax.scan(step, v0, None, length=4)
        return out

    ref = power_iter(csr.val, csr.col_ind, csr.row_ptr, vec)
    calls, restore = _spy_detect()
    try:
        fast = lilac.compile(power_iter)
        out = fast(csr.val, csr.col_ind, csr.row_ptr, vec)
        first = calls["n"]
        fast(csr.val, csr.col_ind, csr.row_ptr, vec)   # steady state
        steady = calls["n"] - first
    finally:
        restore()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    assert first == 2, "one top-level detect + one scan-body descent"
    assert steady == 0, "iteration reuse: no re-detection on later calls"
    assert any(m.variant == "scan_body" for m in fast.last_report.matches)


# ---------------------------------------------------------------------------
# plans under transform traces
# ---------------------------------------------------------------------------

def test_plan_bakes_under_user_jit_and_serves_concrete(problem):
    """A function only ever called under jax.jit still bakes: the first
    (traced) call records and bakes with warm-up deferred; a later
    concrete call guard-checks into the plan."""
    csr, vec = problem
    fast = lilac.compile(naive_spmv)

    @jax.jit
    def wrapped(val, col, row_ptr, vec):
        return fast(val, col, row_ptr, vec)

    out = wrapped(csr.val, csr.col_ind, csr.row_ptr, vec)
    info = fast.plan_info()
    assert info["baked"] >= 1 and not info["bake_errors"]
    # concrete call: same signature, must serve the baked plan
    out2 = fast(csr.val, csr.col_ind, csr.row_ptr, vec)
    info2 = fast.plan_info()
    assert info2["plan_hits"] >= 1
    assert info2["rebakes"] == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=1e-6, rtol=1e-6)


def test_detection_under_ambient_grad_trace():
    """Regression: semantic validation (eval_subgraph) must evaluate
    concretely even when detection runs under an outer make_jaxpr/JVP
    trace — the MoE one-hot validator used to be swept into the ambient
    trace and silently reject."""
    from repro.models.layers import _moe_naive_2d

    T, D, F, E, K = 32, 8, 16, 4, 1
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    gate = jnp.asarray(rng.random((T, K)).astype(np.float32))
    idx = jnp.asarray((np.arange(T * K).reshape(T, K) % E).astype(np.int32))
    wg = jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1)
    wu = jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1)
    wd = jnp.asarray(rng.standard_normal((E, F, D)).astype(np.float32) * .1)

    inner = lilac.compile(_moe_naive_2d)

    def loss(wg, wu, wd):
        return jnp.mean(inner(x, gate, idx, wg, wu, wd) ** 2)

    jax.make_jaxpr(jax.value_and_grad(loss))(wg, wu, wd)
    assert [m.computation for m in inner.last_report.matches] == ["moe_ffn"]
