"""The declarative spec API (paper §3, Fig. 3 + §3.3): HARNESS-block
parsing with error positions, descriptor->Harness compilation with
generated marshaling, decorator registration, duplicate-registration
safety, the `lilac.compile` entry point, and parity of the spec-registered
builtin registry with the hand-wired layout it replaced."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.core import what_lang as W
from repro.core.harness import HarnessRegistry


# -- parsing ------------------------------------------------------------------

FULL_HARNESS = """
HARNESS mylib.spmv implements spmv_csr, spmv_coo
  platforms cpu;
  formats CSR, COO;
  host_only;
  default_for cpu;
  marshal packed = ell_pack(a, colidx, rowstr|rowidx);
  persistent handle, workspace;
  BeforeFirstExecution init_handle;
  AfterLastExecution free_handle;
"""


def test_parse_harness_block_full():
    decl = lilac.parse_harness(FULL_HARNESS)
    assert decl.name == "mylib.spmv"
    assert decl.implements == ("spmv_csr", "spmv_coo")
    assert decl.platforms == ("cpu",)
    assert decl.formats == ("CSR", "COO")
    assert not decl.jit_safe
    assert decl.default_for == ("cpu",)
    assert decl.marshal == (W.MarshalClause(
        "packed", "ell_pack", (("a",), ("colidx",), ("rowstr", "rowidx"))),)
    assert decl.persistent == ("handle", "workspace")
    assert decl.before_first == "init_handle"
    assert decl.after_last == "free_handle"


def test_parse_spec_roundtrip_builtins():
    """str(parse(text)) reparses to an equal AST for every builtin spec —
    the CI drift gate relies on this."""
    assert lilac.BUILTIN_SPECS
    for family, text in lilac.BUILTIN_SPECS.items():
        spec = lilac.parse_spec(text)
        assert lilac.parse_spec(str(spec)) == spec, family
    # and for a harness carrying every clause kind
    decl = lilac.parse_harness(FULL_HARNESS)
    assert lilac.parse_harness(str(decl)) == decl


def test_parse_error_positions():
    with pytest.raises(lilac.ParseError) as ei:
        lilac.parse_spec("COMPUTATION x\nresult = sum(0 <= i < n) a[i] * ;")
    assert ei.value.line == 2 and ei.value.col == 33
    assert "line 2" in str(ei.value)

    with pytest.raises(lilac.ParseError) as ei:
        lilac.parse_spec("HARNESS h implements dotproduct\n  bogus foo;")
    assert ei.value.line == 2 and ei.value.col == 3
    assert "bogus" in str(ei.value)

    with pytest.raises(lilac.ParseError) as ei:
        lilac.parse_spec("HARNESS h implements dotproduct\n  platforms cpu")
    assert ei.value.line == 2  # missing ';' reported at end of input

    with pytest.raises(lilac.ParseError):
        lilac.parse_spec("")


def test_comments_are_skipped():
    decl = lilac.parse_harness("""
    HARNESS c.mt implements dotproduct   -- trailing comment
      -- a whole-line comment
      formats DOT;
    """)
    assert decl.formats == ("DOT",)


def test_parse_keeps_computation_back_compat():
    comp = lilac.parse("COMPUTATION p r = sum(0 <= i < n) a[i] * b[i];")
    assert comp.name == "p"
    with pytest.raises(lilac.ParseError):
        lilac.parse(FULL_HARNESS)  # no COMPUTATION


# -- duplicate registration ---------------------------------------------------

def test_duplicate_registration_is_an_error():
    reg = HarnessRegistry()
    h1 = lilac.Harness("b.x", "dotproduct", lambda b, c: 1.0)
    h2 = lilac.Harness("b.x", "dotproduct", lambda b, c: 2.0)
    reg.register(h1)
    with pytest.raises(lilac.DuplicateHarnessError):
        reg.register(h2)
    # override replaces in place (same candidate-order slot)
    reg.register(lilac.Harness("b.y", "dotproduct", lambda b, c: 3.0))
    reg.register(h2, override=True)
    assert [h.name for h in reg.harnesses_for("dotproduct")] == ["b.x", "b.y"]
    assert reg.get("dotproduct", "b.x") is h2


def test_spec_reload_is_safe_with_override():
    reg = HarnessRegistry()
    text = """
    HARNESS t.dot implements dotproduct
      formats DOT;
    """
    lilac.register_spec(text, {"t.dot": lambda b, c: 1.0}, registry=reg)
    with pytest.raises(lilac.DuplicateHarnessError):
        lilac.register_spec(text, {"t.dot": lambda b, c: 1.0}, registry=reg)
    lilac.register_spec(text, {"t.dot": lambda b, c: 2.0}, registry=reg,
                        override=True)
    assert len(reg.harnesses_for("dotproduct")) == 1


# -- descriptor -> Harness compilation ---------------------------------------

def test_generated_marshaling_wrapper_uses_cache():
    """The marshal clause must route the repack through MarshalingCache:
    one miss on first call, hits afterwards, keyed on declared arrays."""
    reg = HarnessRegistry()
    packs = []

    @lilac.repack("t_double_pack", override=True)
    def _pack(b):
        packs.append(1)
        return np.asarray(b["a"]) * 2.0

    @lilac.harness("""
    HARNESS t.double implements dotproduct
      host_only;
      marshal doubled = t_double_pack(a);
    """, registry=reg)
    def t_double(b, ctx, *, doubled):
        return float(np.sum(doubled * np.asarray(b["b"])))

    h = reg.get("dotproduct", "t.double")
    cache = lilac.MarshalingCache()
    ctx = lilac.CallCtx(mode="host", cache=cache, format="DOT")
    binding = {"a": np.ones(4, np.float32), "b": np.ones(4, np.float32),
               "length": 4}
    assert h(binding, ctx) == pytest.approx(8.0)
    assert h(binding, ctx) == pytest.approx(8.0)
    assert len(packs) == 1 and cache.stats.hits == 1
    # changed key array -> repack reruns
    binding2 = dict(binding, a=np.full(4, 2.0, np.float32))
    assert h(binding2, ctx) == pytest.approx(16.0)
    assert len(packs) == 2
    # no cache available (ctx.cache None) -> direct computation still works
    assert h(binding, lilac.CallCtx(mode="host", cache=None, format="DOT")) \
        == pytest.approx(8.0)
    assert len(packs) == 3


def test_persistent_state_hooks():
    """BeforeFirstExecution runs once before the first call; AfterLastExecution
    runs on release — the paper's persistence template (Fig. 14)."""
    reg = HarnessRegistry()
    events = []

    @lilac.harness("""
    HARNESS t.persist implements dotproduct
      persistent handle;
      BeforeFirstExecution t_init;
      AfterLastExecution t_fini;
    """, registry=reg, hooks={
        "t_init": lambda state: (events.append("init"),
                                 state.__setitem__("handle", 42)),
        "t_fini": lambda state: events.append("fini"),
    })
    def t_persist(b, ctx):
        return b["a"] * 0 + ctx_handle(ctx)

    # the body can read the persistent dict through the harness object
    h = reg.get("dotproduct", "t.persist")

    def ctx_handle(ctx):
        return h.persistent["handle"]

    ctx = lilac.CallCtx(mode="host", cache=None, format="DOT")
    assert h.persistent == {"handle": None}
    np.testing.assert_array_equal(h({"a": np.zeros(2)}, ctx), [42, 42])
    h({"a": np.zeros(2)}, ctx)
    assert events == ["init"]
    h.release()
    assert events == ["init", "fini"]


def test_unknown_repack_and_hook_are_spec_errors():
    """Both misconfigurations fail eagerly at registration — a typo'd
    repack must not be silently disqualified by the autotuner later."""
    reg = HarnessRegistry()
    with pytest.raises(lilac.SpecError):
        @lilac.harness("""
        HARNESS t.nohook implements dotproduct
          BeforeFirstExecution missing_hook;
        """, registry=reg)
        def _a(b, ctx):
            return 0
    with pytest.raises(lilac.SpecError, match="unknown repack"):
        @lilac.harness("""
        HARNESS t.nopack implements dotproduct
          host_only;
          marshal x = missing_pack(a);
        """, registry=reg)
        def _b(b, ctx, *, x):
            return x
    assert not reg.harnesses_for("dotproduct")   # nothing half-registered


def test_harness_implements_unknown_computation():
    with pytest.raises(lilac.SpecError):
        lilac.register_spec("HARNESS t.x implements no_such_comp",
                            {"t.x": lambda b, c: 0},
                            registry=HarnessRegistry())


_CLONE_SPEC = """
COMPUTATION {name}
forall(0 <= i < r2) {{
  out2[i] = sum(ptr2[i] <= j < ptr2[i+1]) v2[j] * x2[c2[j]];
}}

HARNESS t.clone implements {name}
  formats CSR, COO;
  default_for cpu;
"""


def _cleanup_global(name):
    from repro.core import spec as S
    from repro.core.detect import reset_default_detector
    W.BUILTINS.pop(name, None)
    lilac.REGISTRY._by_comp.pop(name, None)
    lilac.REGISTRY._defaults.pop((name, "cpu"), None)
    lilac.REGISTRY.reset_autotuner()
    S._GLOBAL_SPEC_LOG[:] = [e for e in S._GLOBAL_SPEC_LOG
                             if not any(name in d.implements
                                        for d in e[0].harnesses)]
    reset_default_detector()


def test_spec_with_new_computation_extends_builtins_and_detector():
    """'Add a backend' = spec + function: registering against the global
    REGISTRY makes a new COMPUTATION detectable and its harness
    selectable, no compiler changes."""
    name = "spmv_csr_clone"
    assert name not in W.BUILTINS
    try:
        lilac.register_spec(_CLONE_SPEC.format(name=name),
                            {"t.clone": lambda b, c: 0})
        assert name in W.BUILTINS
        assert lilac.REGISTRY.default_name(name, "cpu") == "t.clone"
        from repro.core.detect import Detector, default_detector
        det = default_detector()
        assert any(m.computation == name for m in det.matchers)
        # explicit-computation detectors still work
        assert Detector([W.BUILTINS[name]]).matchers
    finally:
        _cleanup_global(name)


def test_failed_registration_leaves_no_trace():
    """register_spec is atomic: a spec that fails validation (missing
    body, unknown hook, duplicate) must not publish its computations,
    rebuild the detector, or register a prefix of its harnesses."""
    name = "spmv_atomic_clone"
    before = len(lilac.REGISTRY.harnesses_for("dotproduct"))
    with pytest.raises(lilac.SpecError):
        lilac.register_spec(f"""
        COMPUTATION {name}
        forall(0 <= i < r3) {{
          out3[i] = sum(p3[i] <= j < p3[i+1]) v3[j] * x3[c3[j]];
        }}

        HARNESS t.ok implements dotproduct
          formats DOT;

        HARNESS t.missing_body implements {name}
        """, {"t.ok": lambda b, c: 0})          # no body for t.missing_body
    assert name not in W.BUILTINS
    assert len(lilac.REGISTRY.harnesses_for("dotproduct")) == before
    # within-spec duplicates are caught before anything commits
    reg = HarnessRegistry()
    with pytest.raises(lilac.DuplicateHarnessError):
        lilac.register_spec("""
        HARNESS t.dup implements dotproduct
        HARNESS t.dup implements dotproduct
        """, {"t.dup": lambda b, c: 0}, registry=reg)
    assert not reg.harnesses_for("dotproduct")


def test_private_registry_stays_isolated():
    """A caller-supplied registry must not leak computations into the
    process-global builtins or rebuild the shared detector."""
    name = "spmv_private_clone"
    reg = HarnessRegistry()
    lilac.register_spec(_CLONE_SPEC.format(name=name),
                        {"t.clone": lambda b, c: 0}, registry=reg)
    assert name not in W.BUILTINS          # no global leak
    assert reg.default_name(name, "cpu") == "t.clone"
    from repro.core.detect import default_detector
    assert not any(m.computation == name
                   for m in default_detector().matchers)


def test_fresh_registry_replay_survives_global_override_reload():
    """Re-loading a spec globally with override=True must not break later
    register_builtins(fresh) replays (the log holds both entries; the
    later one wins, as it did globally)."""
    text = """
    HARNESS t.replay implements dotproduct
      formats DOT;
    """
    try:
        lilac.register_spec(text, {"t.replay": lambda b, c: 1.0})
        lilac.register_spec(text, {"t.replay": lambda b, c: 2.0},
                            override=True)
        fresh = lilac.register_builtins(HarnessRegistry())
        names = [h.name for h in fresh.harnesses_for("dotproduct")]
        assert names.count("t.replay") == 1
        assert fresh.get("dotproduct", "t.replay").fn({}, None) == 2.0
    finally:
        from repro.core import spec as S
        lilac.REGISTRY._by_comp["dotproduct"] = [
            h for h in lilac.REGISTRY._by_comp["dotproduct"]
            if h.name != "t.replay"]
        lilac.REGISTRY.reset_autotuner()
        S._GLOBAL_SPEC_LOG[:] = [e for e in S._GLOBAL_SPEC_LOG
                                 if not any(d.name == "t.replay"
                                            for d in e[0].harnesses)]


def test_multi_computation_harness_shares_persistent_state():
    """One HARNESS block implementing several computations is ONE backend:
    a single persistent dict, setup once on first call anywhere, teardown
    once on first release."""
    reg = HarnessRegistry()
    events = []

    @lilac.harness("""
    HARNESS t.shared implements spmv_csr, spmv_coo
      persistent handle;
      BeforeFirstExecution s_init;
      AfterLastExecution s_fini;
    """, registry=reg, hooks={
        "s_init": lambda state: events.append("init"),
        "s_fini": lambda state: events.append("fini"),
    })
    def t_shared(b, ctx):
        return 0

    h_csr = reg.get("spmv_csr", "t.shared")
    h_coo = reg.get("spmv_coo", "t.shared")
    assert h_csr.persistent is h_coo.persistent
    ctx = lilac.CallCtx(mode="host", cache=None, format="CSR")
    h_csr({}, ctx)
    h_coo({}, ctx)
    assert events == ["init"]              # once per backend, not per comp
    # release through a sibling that never ran still tears down the backend
    h_coo.release()
    h_csr.release()                        # already down -> no double fini
    assert events == ["init", "fini"]
    # after teardown, the next call through ANY sibling sets up again
    h_csr({}, ctx)
    assert events == ["init", "fini", "init"]
    h_csr.release()
    assert events == ["init", "fini", "init", "fini"]


def test_override_replacement_tears_down_live_harness():
    """register(..., override=True) on a live harness must run its
    AfterLastExecution hook before dropping it — no leaked handles."""
    reg = HarnessRegistry()
    events = []
    h1 = lilac.Harness("t.live", "dotproduct", lambda b, c: 1.0,
                       setup=lambda s: events.append("init"),
                       teardown=lambda s: events.append("fini"))
    reg.register(h1)
    h1({}, lilac.CallCtx(mode="host", cache=None, format="DOT"))
    assert events == ["init"]
    reg.register(lilac.Harness("t.live", "dotproduct", lambda b, c: 2.0),
                 override=True)
    assert events == ["init", "fini"]
    # replacing a never-started harness runs no hook
    reg.register(lilac.Harness("t.live", "dotproduct", lambda b, c: 3.0),
                 override=True)
    assert events == ["init", "fini"]


# -- entry point --------------------------------------------------------------

def _dot(a, b):
    return jnp.sum(a * b)


def test_compile_options_and_decorator_form():
    f = lilac.compile(_dot)
    assert isinstance(f, lilac.LilacFunction) and f.mode == "trace"
    f = lilac.compile(_dot, options=lilac.CompileOptions(mode="host"))
    assert f.mode == "host"
    # explicit kwargs override option fields
    f = lilac.compile(_dot, options=lilac.CompileOptions(mode="host"),
                      mode="trace", policy="jnp.dot")
    assert f.mode == "trace" and f.policy == "jnp.dot"

    @lilac.compile(mode="host")
    def g(a, b):
        return jnp.sum(a * b)

    assert isinstance(g, lilac.LilacFunction) and g.mode == "host"
    a = jnp.arange(4.0)
    np.testing.assert_allclose(g(a, a), _dot(a, a))

    with pytest.raises(TypeError):
        lilac.compile(_dot, bogus_option=1)
    with pytest.raises(ValueError):
        lilac.compile(_dot, mode="neither")


def test_deprecation_shims_still_work():
    a = jnp.arange(8.0)
    with pytest.warns(lilac.LilacDeprecationWarning):
        opt = lilac.lilac_optimize(_dot)
    assert opt.mode == "trace"
    np.testing.assert_allclose(opt(a, a), _dot(a, a))
    with pytest.warns(lilac.LilacDeprecationWarning):
        acc = lilac.lilac_accelerate(_dot, policy="jnp.dot")
    assert acc.mode == "host" and acc.policy == "jnp.dot"
    np.testing.assert_allclose(acc(a, a), _dot(a, a))
    # the old import path still resolves
    from repro.core import lilac_accelerate, lilac_optimize  # noqa: F401


# -- builtin parity -----------------------------------------------------------

# The hand-wired registry layout this redesign replaced (PR 1 state of
# harness._register_builtins), as (name, platforms, formats, jit_safe)
# per computation plus the per-platform defaults.  Spec-driven
# registration must reproduce it exactly — same fingerprint, same
# autotune cache keys.
_EXPECTED = {
    "spmv_csr": [
        ("jnp.segment", ("cpu", "tpu"), ("CSR", "COO"), True),
        ("jnp.ell", ("cpu", "tpu"), ("CSR", "COO"), False),
        ("jnp.bcsr", ("cpu", "tpu"), ("CSR", "COO"), False),
        ("jnp.dense", ("cpu", "tpu"), ("CSR", "COO"), False),
        ("pallas.ell", ("tpu",), ("CSR", "COO"), False),
        ("pallas.bcsr", ("tpu",), ("CSR", "COO"), False),
    ],
    "spmv_ell": [
        ("jnp.ell", ("cpu", "tpu"), ("ELL", "JDS"), True),
        ("pallas.ell", ("cpu", "tpu"), ("ELL", "JDS"), True),
    ],
    "spmm_csr": [
        ("jnp.segment", ("cpu", "tpu"), ("CSR", "COO"), True),
        ("jnp.bcsr", ("cpu", "tpu"), ("CSR", "COO"), False),
        ("pallas.bcsr", ("tpu",), ("CSR", "COO"), False),
    ],
    "dotproduct": [("jnp.dot", ("cpu", "tpu"), (), True)],
    "gemv": [("jnp.dot", ("cpu", "tpu"), (), True)],
    # order matters: the autotuner's exploration budget truncates in
    # registration order, so this must match the old hand-wiring exactly
    "moe_ffn": [
        ("jnp.capacity", ("cpu", "tpu"), (), True),
        ("pallas.gmm", ("cpu", "tpu"), (), True),
        ("dense", ("cpu", "tpu"), (), True),
    ],
}
_EXPECTED["spmv_coo"] = _EXPECTED["spmv_csr"]
_EXPECTED["spmv_jds"] = _EXPECTED["spmv_ell"]

_EXPECTED_DEFAULTS = {
    ("spmv_csr", "cpu"): "jnp.segment", ("spmv_csr", "tpu"): "jnp.segment",
    ("spmv_coo", "cpu"): "jnp.segment", ("spmv_coo", "tpu"): "jnp.segment",
    ("spmv_ell", "cpu"): "jnp.ell", ("spmv_ell", "tpu"): "pallas.ell",
    ("spmv_jds", "cpu"): "jnp.ell", ("spmv_jds", "tpu"): "pallas.ell",
    ("spmm_csr", "cpu"): "jnp.segment", ("spmm_csr", "tpu"): "pallas.bcsr",
    ("dotproduct", "cpu"): "jnp.dot", ("dotproduct", "tpu"): "jnp.dot",
    ("gemv", "cpu"): "jnp.dot", ("gemv", "tpu"): "jnp.dot",
    ("moe_ffn", "cpu"): "jnp.capacity", ("moe_ffn", "tpu"): "pallas.gmm",
}


def _layout(reg):
    return {comp: [(h.name, h.platforms, h.formats, h.jit_safe)
                   for h in reg.harnesses_for(comp)]
            for comp in _EXPECTED}


def test_spec_registered_builtins_match_hand_wired_layout():
    assert _layout(lilac.REGISTRY) == _EXPECTED
    assert dict(lilac.REGISTRY._defaults) == _EXPECTED_DEFAULTS
    # a fresh registry built from the same specs is fingerprint-identical,
    # so persisted autotune decisions remain valid across the redesign
    fresh = lilac.register_builtins(HarnessRegistry())
    assert _layout(fresh) == _layout(lilac.REGISTRY)
    assert fresh.fingerprint() == lilac.REGISTRY.fingerprint()


def test_selection_parity_spot_checks():
    r = lilac.REGISTRY
    assert r.select("spmv_csr", "CSR", "cpu", "trace").name == "jnp.segment"
    assert r.select("spmv_csr", "CSR", "cpu", "host",
                    policy="jnp.ell").name == "jnp.ell"
    assert r.select("spmv_ell", "ELL", "tpu", "trace").name == "pallas.ell"
    assert r.select("spmm_csr", "CSR", "tpu", "host").name == "pallas.bcsr"
    assert r.select("moe_ffn", "MOE", "cpu", "trace").name == "jnp.capacity"
    # trace mode still filters host-only harnesses
    assert all(h.jit_safe for h in r.candidates("spmv_csr", "CSR", "cpu",
                                                "trace"))


def test_tab2_quick_sweep_selection_parity():
    """The acceptance gate: the --quick sweep must run every backend under
    the spec-registered registry and report the same default selection as
    the hand-wired one did (jnp.segment on cpu)."""
    from benchmarks.tab2_backends import BACKENDS, run
    table = run(reps=2, quick=True, out=None)
    assert table
    for prob, row in table.items():
        for backend in BACKENDS:
            s = row[(backend, "steady")]
            assert s == s, (prob, backend, "backend failed under spec registry")
    from benchmarks.tab2_backends import _default_backend
    assert _default_backend("cpu") == "jnp.segment"
