import os
import sys

# tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 placeholder devices in its own subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
