import os
import sys

import pytest

# tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 placeholder devices in its own subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can drive the benchmarks package (selection parity)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path, monkeypatch):
    """Keep autotune persistence out of ~/.cache during tests: every test
    gets a private cache file and a fresh tuner on the global registry."""
    monkeypatch.setenv("LILAC_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    from repro.core.harness import REGISTRY

    REGISTRY.reset_autotuner()
    yield
    REGISTRY.reset_autotuner()
