import os
import sys

import pytest

# tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 placeholder devices in its own subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can drive the benchmarks package (selection parity)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path, monkeypatch):
    """Keep autotune, executable-plan AND quarantine persistence out of
    ~/.cache during tests: every test gets private cache files, a fresh
    tuner on the global registry, and no ambient chaos plan (tests opt in
    via ``faults.inject``)."""
    monkeypatch.setenv("LILAC_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    monkeypatch.setenv("LILAC_PLAN_CACHE", str(tmp_path / "plans.json"))
    monkeypatch.setenv("LILAC_QUARANTINE_CACHE",
                       str(tmp_path / "quarantine.json"))
    monkeypatch.delenv("LILAC_FAULTS", raising=False)
    monkeypatch.delenv("LILAC_FAULTS_SEED", raising=False)
    monkeypatch.delenv("LILAC_SHADOW_RATE", raising=False)
    from repro.core import faults
    from repro.core.harness import REGISTRY
    from repro.core.plan import reset_shared_plan_caches
    from repro.core.resilience import reset_shared_quarantine

    faults.load_env()          # LILAC_FAULTS just cleared -> ACTIVE = None
    REGISTRY.reset_autotuner()
    reset_shared_plan_caches()
    reset_shared_quarantine()
    yield
    faults.load_env()
    REGISTRY.reset_autotuner()
    reset_shared_plan_caches()
    reset_shared_quarantine()
