import os
import sys

import pytest

# tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 placeholder devices in its own subprocess.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root, so tests can drive the benchmarks package (selection parity)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path, monkeypatch):
    """Keep autotune AND executable-plan persistence out of ~/.cache during
    tests: every test gets private cache files and a fresh tuner on the
    global registry."""
    monkeypatch.setenv("LILAC_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    monkeypatch.setenv("LILAC_PLAN_CACHE", str(tmp_path / "plans.json"))
    from repro.core.harness import REGISTRY
    from repro.core.plan import reset_shared_plan_caches

    REGISTRY.reset_autotuner()
    reset_shared_plan_caches()
    yield
    REGISTRY.reset_autotuner()
    reset_shared_plan_caches()
