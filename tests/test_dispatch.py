"""Zero-overhead steady-state dispatch (repro.core.plan):

  * a resolved rewrite bakes into ONE jitted ExecutablePlan and repeat
    calls take the guard-check fast path (no eqn interpretation)
  * baked and interpreted dispatch are bit-identical (fixed seed +
    hypothesis sweep)
  * guards: changing the vector keeps the fast path (it is data, not a
    marshal source); a TrackedArray matrix mutation busts the plan
  * match serialization round-trips through the persistent plan cache;
    registry-fingerprint or schema drift invalidates it
  * a warm SECOND process rehydrates detection + pins from disk with ZERO
    Detector.detect calls and goes straight to plan baking
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.dispatch_overhead import _spy_detect
from repro import lilac
from repro.core import plan as P
from repro.core.marshal import TrackedArray, version_token
from repro.core.rewrite import needed_eqn_ids
from repro.sparse import csr_from_dense
from repro.sparse.random import random_dense_sparse

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _problem(n=96, density=0.1, seed=0):
    csr = csr_from_dense(random_dense_sparse(n, n, density, seed))
    vec = jnp.asarray(np.random.default_rng(seed + 1)
                      .standard_normal(n).astype(np.float32))
    return csr, vec


def _naive_fn(rows, nnz):
    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)
    return naive


# ---------------------------------------------------------------------------
# baking + the fast path
# ---------------------------------------------------------------------------

def test_bakes_plan_and_hits_fast_path():
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        policy="jnp.ell")
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = _naive_fn(csr.rows, csr.nnz)(*a)
    out1 = acc(*a)                      # interpreted + recorded + baked
    info = acc.plan_info()
    assert info["baked"] == 1 and not info["bake_errors"]
    out2 = acc(*a)                      # fast path
    out3 = acc(*a)
    assert acc.plan_info()["plan_hits"] == 2
    assert acc.last_selections[0][1] == "jnp.ell"
    for out in (out1, out2, out3):
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=1e-3)


def test_vector_churn_keeps_fast_path():
    """The dense vector is runtime data, not a marshal source: new array
    objects every call must NOT bust the plan."""
    csr, _ = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        policy="jnp.ell")
    rng = np.random.default_rng(7)
    vecs = [jnp.asarray(rng.standard_normal(csr.shape[1]).astype(np.float32))
            for _ in range(4)]
    acc(csr.val, csr.col_ind, csr.row_ptr, vecs[0])
    for v in vecs[1:]:
        out = acc(csr.val, csr.col_ind, csr.row_ptr, v)
        ref = _naive_fn(csr.rows, csr.nnz)(csr.val, csr.col_ind,
                                           csr.row_ptr, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=1e-3)
    info = acc.plan_info()
    assert info["plan_hits"] == 3 and info["rebakes"] == 0


def test_no_match_program_bakes_plain_jit():
    def fn(x):
        return x * 2.0 + 1.0

    acc = lilac.compile(fn, mode="host")
    x = jnp.arange(8.0)
    out1 = acc(x)
    assert acc.plan_info()["baked"] == 1
    assert acc.last_selections == []
    out2 = acc(x)
    assert acc.plan_info()["plan_hits"] == 1
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(fn(x)))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(fn(x)))


def test_bake_false_keeps_interpreter():
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        bake=False)
    for _ in range(3):
        acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    info = acc.plan_info()
    assert info["baked"] == 0 and info["plan_hits"] == 0


def test_trace_mode_baked_function_still_jittable():
    """Under a user's jit the guard sees tracers and falls back to the
    traced interpreter — baking must not break re-tracing."""
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    opt = lilac.compile(naive, policy="autotune")
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    out_eager = opt(*a)                 # concrete call: tunes, pins, bakes
    assert opt.plan_info()["baked"] == 1
    jitted = jax.jit(lambda *xs: opt(*xs))
    out_jit = jitted(*a)
    np.testing.assert_allclose(np.asarray(out_jit), np.asarray(out_eager),
                               atol=2e-3, rtol=1e-3)
    out_fast = opt(*a)                  # fast path still live afterwards
    assert opt.plan_info()["plan_hits"] >= 1
    np.testing.assert_allclose(np.asarray(out_fast), np.asarray(out_eager),
                               atol=2e-3, rtol=1e-3)


def test_signature_change_uses_separate_plans():
    acc = lilac.compile(lambda x: x * 1.5 + 1.0, mode="host")
    x1, x2 = jnp.arange(8.0), jnp.arange(16.0)
    acc(x1), acc(x1)
    acc(x2), acc(x2)
    info = acc.plan_info()
    assert info["entries"] == 2 and info["baked"] == 2
    # alternating signatures: the per-entry second-chance path finds each
    # entry's own plan even though the hot-plan slot points elsewhere
    np.testing.assert_array_equal(np.asarray(acc(x1)),
                                  np.asarray(x1 * 1.5 + 1.0))
    np.testing.assert_array_equal(np.asarray(acc(x2)),
                                  np.asarray(x2 * 1.5 + 1.0))
    assert acc.plan_info()["plan_hits"] == 4


# ---------------------------------------------------------------------------
# bit-identical dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["jnp.ell", "default"])
def test_baked_vs_interpreted_bit_identical(policy):
    csr, vec = _problem(n=128, density=0.08, seed=42)
    naive = _naive_fn(csr.rows, csr.nnz)
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    interp = lilac.compile(naive, mode="host", policy=policy, bake=False)
    baked = lilac.compile(naive, mode="host", policy=policy)
    ref = np.asarray(interp(*a))
    baked(*a)
    assert baked.plan_info()["baked"] == 1
    out = np.asarray(baked(*a))
    np.testing.assert_array_equal(out, ref)


def test_baked_vs_interpreted_bit_identical_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None)
    @given(n=st.sampled_from([32, 48, 64]), seed=st.integers(0, 100))
    def check(n, seed):
        csr, vec = _problem(n=n, density=0.15, seed=seed)
        if csr.nnz == 0:
            return
        naive = _naive_fn(csr.rows, csr.nnz)
        a = (csr.val, csr.col_ind, csr.row_ptr, vec)
        interp = lilac.compile(naive, mode="host", policy="jnp.ell",
                               bake=False, plan_cache=False)
        baked = lilac.compile(naive, mode="host", policy="jnp.ell",
                              plan_cache=False)
        ref = np.asarray(interp(*a))
        baked(*a)
        np.testing.assert_array_equal(np.asarray(baked(*a)), ref)

    check()


# ---------------------------------------------------------------------------
# guards
# ---------------------------------------------------------------------------

def test_tracked_array_mutation_busts_plan():
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    acc = lilac.compile(naive, mode="host", policy="jnp.ell")
    ta = TrackedArray(csr.val)
    a = (ta, csr.col_ind, csr.row_ptr, vec)
    out1 = acc(*a)
    assert acc.plan_info()["baked"] == 1
    acc(*a)
    assert acc.plan_info()["plan_hits"] == 1          # fast path works
    np.testing.assert_allclose(np.asarray(out1),
                               np.asarray(naive(csr.val, csr.col_ind,
                                                csr.row_ptr, vec)),
                               atol=2e-3, rtol=1e-3)
    # functional update: same base token, bumped version
    ta2 = ta.replace(csr.val * 2.0)
    assert version_token(ta2) != version_token(ta)
    out2 = acc(ta2, csr.col_ind, csr.row_ptr, vec)
    ref2 = naive(csr.val * 2.0, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2),
                               atol=2e-3, rtol=1e-3)
    info = acc.plan_info()
    assert info["rebakes"] == 1                       # plan was re-baked
    acc(ta2, csr.col_ind, csr.row_ptr, vec)           # and is hot again
    assert acc.plan_info()["plan_hits"] == 1          # (new plan's counter)


def test_numpy_inplace_mutation_busts_plan():
    """Writable numpy operands can mutate under an unchanged object
    identity, so their guards carry a content fingerprint — an in-place
    write must bust the plan exactly as it would have missed the
    interpreter's marshaling cache."""
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    val_np = np.array(np.asarray(csr.val))            # writable host buffer
    acc = lilac.compile(naive, mode="host", policy="jnp.ell")
    acc(val_np, csr.col_ind, csr.row_ptr, vec)
    assert acc.plan_info()["baked"] == 1
    acc(val_np, csr.col_ind, csr.row_ptr, vec)
    assert acc.plan_info()["plan_hits"] == 1
    val_np *= 2.0                                     # same object, new bytes
    out = acc(val_np, csr.col_ind, csr.row_ptr, vec)
    ref = naive(jnp.asarray(val_np), csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)
    assert acc.plan_info()["rebakes"] == 1


def test_non_marshal_numpy_capture_mutation_busts_plan():
    """EVERY writable numpy closure capture is const-guarded, not just
    marshal sources: the interpreter reads the live reference each call
    (e.g. a captured bias), so the plan must see the mutation too."""
    bias = np.zeros(8, np.float32)

    def fn(x):
        return x * 2.0 + jnp.asarray(bias)

    acc = lilac.compile(fn, mode="host")
    x = jnp.arange(8.0, dtype=jnp.float32)
    out1 = np.asarray(acc(x))
    assert acc.plan_info()["baked"] == 1
    acc(x)
    assert acc.plan_info()["plan_hits"] == 1
    bias += 1.0                                       # in-place capture edit
    out2 = np.asarray(acc(x))
    np.testing.assert_allclose(out2, out1 + 1.0, rtol=1e-6)
    assert acc.plan_info()["rebakes"] == 1


def test_large_numpy_capture_single_element_edit_busts_plan():
    """Const guards fingerprint EXACTLY: a one-element edit of a capture
    above the 64KB sampled-hash threshold — invisible to the sampled
    fingerprint — must still bust the plan, because the interpreter
    re-reads the capture exactly every call."""
    n = (1 << 16) // 4 + 4096                         # > _SMALL bytes of f32
    bias = np.zeros(n, np.float32)

    def fn(x):
        return x + jnp.asarray(bias)

    acc = lilac.compile(fn, mode="host")
    x = jnp.ones(n, dtype=jnp.float32)
    out1 = np.asarray(acc(x))
    acc(x)
    assert acc.plan_info()["plan_hits"] == 1
    bias[100] += 5.0          # off the strided sample and the 64-edge runs
    out2 = np.asarray(acc(x))
    assert out2[100] == out1[100] + 5.0
    assert acc.plan_info()["rebakes"] == 1


def test_closure_captured_numpy_mutation_busts_plan():
    """jax keeps closure-captured numpy operands as live references in
    ``consts``, so the interpreter path sees in-place mutation through
    the marshal fingerprint — a baked plan must too, via its const
    guards."""
    csr, vec = _problem()
    rows, nnz = csr.rows, csr.nnz
    val_np = np.array(np.asarray(csr.val))            # writable capture
    col_np = np.array(np.asarray(csr.col_ind))
    ptr_np = np.array(np.asarray(csr.row_ptr))

    def naive(v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(jnp.asarray(ptr_np)),
                         total_repeat_length=nnz)
        return jax.ops.segment_sum(
            jnp.asarray(val_np) * v[jnp.asarray(col_np)],
            row, num_segments=rows)

    acc = lilac.compile(naive, mode="host", policy="jnp.ell")
    out1 = np.asarray(acc(vec))
    assert acc.plan_info()["baked"] == 1
    acc(vec)
    assert acc.plan_info()["plan_hits"] == 1
    val_np *= 2.0                                     # mutate the capture
    out2 = np.asarray(acc(vec))
    np.testing.assert_allclose(out2, out1 * 2.0, rtol=1e-5, atol=1e-5)
    assert acc.plan_info()["rebakes"] == 1


def test_numpy_scalar_arg_keys_like_compile_dict():
    """np.float64 is a ``float`` instance but carries an aval: the plan's
    leaf specs must key it exactly like ``_leaf_templates`` does (as a
    0-d array), so the fast path serves it instead of silently falling
    back to the interpreter forever."""
    acc = lilac.compile(lambda x, s: x * s + 1.0, mode="host")
    x = jnp.arange(8.0)
    s = np.float64(0.85)
    acc(x, s)
    assert acc.plan_info()["baked"] == 1
    out = acc(x, s)
    assert acc.plan_info()["plan_hits"] == 1          # plan DID serve it
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(x) * 0.85 + 1.0, rtol=1e-6)
    assert len(acc._compiled) == 1                    # one entry, one plan


def test_huge_writable_capture_refuses_to_bake():
    """Exact-guarding a capture is O(bytes) per dispatch: past the bound
    the entry stays on the interpreter (visible in plan_info) instead of
    silently hashing the whole matrix every call."""
    big = np.zeros(P.CONST_GUARD_MAX_BYTES // 4 + 1024, np.float32)

    def fn(x):
        return x + jnp.asarray(big)[: x.shape[0]]

    acc = lilac.compile(fn, mode="host")
    x = jnp.ones(16, dtype=jnp.float32)
    acc(x)
    acc(x)
    info = acc.plan_info()
    assert info["baked"] == 0 and info["no_bake"] == 1
    assert "exact-guard bound" in info["bake_errors"][0]
    big[3] = 7.0                                      # interpreter stays live
    assert float(np.asarray(acc(x))[3]) == 8.0


def test_content_identical_reupload_refreshes_guards_without_rebake():
    """New array objects with identical content: the data plane returns
    the same cached buffers, so the plan re-anchors its identity guards
    instead of paying a re-trace + re-compile."""
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        policy="jnp.ell")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert acc.plan_info()["baked"] == 1
    val2 = jnp.array(np.asarray(csr.val))             # equal, new identity
    acc(val2, csr.col_ind, csr.row_ptr, vec)          # guard miss -> refresh
    assert acc.plan_info()["rebakes"] == 0
    acc(val2, csr.col_ind, csr.row_ptr, vec)
    assert acc.plan_info()["plan_hits"] >= 1


def test_marshal_policy_off_never_bakes_marshal_harnesses():
    """marshal_policy='off' documents 'every call repacks': hoisting the
    recorded repack into a plan would silently reinstate caching, so
    marshal-bearing selections must stay on the interpreter."""
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        policy="jnp.ell", marshal_policy="off")
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    out1 = acc(*a)
    acc(*a)
    info = acc.plan_info()
    assert info["baked"] == 0 and info["no_bake"] == 1
    assert "repack" in info["bake_errors"][0]
    # marshal-free selections still bake under the same policy
    acc2 = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                         policy="jnp.segment", marshal_policy="off")
    acc2(*a)
    assert acc2.plan_info()["baked"] == 1
    np.testing.assert_allclose(
        np.asarray(out1),
        np.asarray(_naive_fn(csr.rows, csr.nnz)(*a)), atol=2e-3, rtol=1e-3)


def test_harness_override_invalidates_baked_plan():
    """Replacing a harness in place (register override=True) moves the
    registry epoch: already-baked plans must re-bake with the new body —
    the fingerprint can't see a same-name body swap, the epoch can."""
    import dataclasses

    from repro.core.harness import REGISTRY

    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        policy="jnp.segment")
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    out1 = np.asarray(acc(*a))
    acc(*a)
    assert acc.plan_info()["plan_hits"] == 1
    orig = REGISTRY.get("spmv_csr", "jnp.segment")
    doubled = dataclasses.replace(
        orig, fn=lambda b, ctx: orig.fn(b, ctx) * 2.0)
    REGISTRY.register(doubled, override=True)
    try:
        out2 = np.asarray(acc(*a))                    # epoch moved: re-bakes
        np.testing.assert_allclose(out2, out1 * 2.0, rtol=1e-5, atol=1e-5)
        out3 = np.asarray(acc(*a))                    # new plan serves
        np.testing.assert_allclose(out3, out2, rtol=0, atol=0)
    finally:
        REGISTRY.register(orig, override=True)


def test_stateful_or_opted_out_harness_never_bakes():
    """Backends with lifecycle hooks / persistent state / bakeable=False
    keep their per-call host-side behavior: the plan would freeze it at
    trace time, so they stay on the interpreter."""
    from repro.core.harness import REGISTRY

    h = REGISTRY.get("spmv_csr", "jnp.segment")
    orig = h.bakeable
    h.bakeable = False
    try:
        csr, vec = _problem()
        acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                            policy="jnp.segment")
        a = (csr.val, csr.col_ind, csr.row_ptr, vec)
        acc(*a)
        acc(*a)
        info = acc.plan_info()
        assert info["baked"] == 0 and info["no_bake"] == 1
        assert "opted out" in info["bake_errors"][0]
    finally:
        h.bakeable = orig


def test_donate_args_rejects_marshal_sources():
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        policy="jnp.ell", donate_args=(0,))  # 0 = csr.val
    with pytest.raises(P.PlanDonationError):
        acc(csr.val, csr.col_ind, csr.row_ptr, vec)


# ---------------------------------------------------------------------------
# serialization + persistent plan cache
# ---------------------------------------------------------------------------

def test_match_serialization_round_trip():
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    acc = lilac.compile(naive, mode="host")
    report = acc.report_for(csr.val, csr.col_ind, csr.row_ptr, vec)
    entry = next(iter(acc._compiled.values()))
    ser = P.serialize_matches(entry.closed_jaxpr, report.matches)
    assert json.loads(json.dumps(ser)) == ser         # JSON-able
    got = P.rehydrate_matches(entry.closed_jaxpr, ser)
    assert got is not None and len(got) == len(report.matches)
    for a, b in zip(report.matches, got):
        assert (a.computation, a.variant, a.format, a.epilogue) == \
               (b.computation, b.variant, b.format, b.epilogue)
        assert a.anchor_eqn is b.anchor_eqn
        assert set(a.binding) == set(b.binding)
        for k in a.binding:
            va, vb = a.binding[k], b.binding[k]
            if isinstance(va, (int, float, bool)):
                assert va == vb
            else:
                assert va is vb or np.all(
                    np.asarray(getattr(va, "val", va))
                    == np.asarray(getattr(vb, "val", vb)))


def test_plan_cache_round_trip_and_detection_skip(tmp_path):
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    path = tmp_path / "plans.json"
    acc = lilac.compile(naive, mode="host", policy="autotune",
                        plan_cache=str(path))
    acc(*a)
    assert path.exists()
    doc = json.loads(path.read_text())
    assert doc["schema"] == P.SCHEMA_VERSION
    (key, rec), = doc["entries"].items()
    assert rec["pins"] and rec["matches"] and rec["detect_digest"]

    # a fresh LilacFunction over the same program: detection is skipped
    calls, restore = _spy_detect()
    try:
        acc2 = lilac.compile(naive, mode="host", policy="autotune",
                             plan_cache=str(path))
        out = acc2(*a)
    finally:
        restore()
    assert calls["n"] == 0
    assert acc2.plan_info()["baked"] == 1
    assert acc2.last_selections[0][1] == acc.last_selections[0][1]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(naive(*a)), atol=2e-3, rtol=1e-3)


def test_plan_cache_registry_fingerprint_invalidation(tmp_path):
    path = tmp_path / "plans.json"
    c1 = P.PlanCache(path, registry_fingerprint="fp-A")
    c1.put("some|key", {"matches": [], "pins": {}})
    assert path.exists()
    c2 = P.PlanCache(path, registry_fingerprint="fp-B")
    assert c2.get("some|key") is None
    assert c2.stats.invalidations == 1
    c3 = P.PlanCache(path, registry_fingerprint="fp-A")
    assert c3.get("some|key") is not None


def test_plan_cache_schema_drift_invalidates(tmp_path):
    path = tmp_path / "plans.json"
    path.write_text(json.dumps({"schema": 99, "registry": "fp",
                                "entries": {"k": {}}}))
    c = P.PlanCache(path, registry_fingerprint="fp")
    assert c.get("k") is None
    assert c.stats.invalidations == 1


def test_corrupt_plan_record_degrades_to_detection(tmp_path):
    """A record whose anchors no longer line up must fall back to a full
    detect, not produce a wrong rewrite."""
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    path = tmp_path / "plans.json"
    acc = lilac.compile(naive, mode="host", plan_cache=str(path))
    acc(*a)
    doc = json.loads(path.read_text())
    for rec in doc["entries"].values():
        for m in rec["matches"]:
            m["anchor_eqn"] = 99999
        # keep the digest consistent with the edit so the corruption is
        # caught by positional validation, not the integrity pre-check
        rec["detect_digest"] = P.detect_digest(rec["matches"])
    path.write_text(json.dumps(doc))
    # fresh (injected) cache instance: the shared per-path view would
    # still hold the pre-edit record in memory
    fresh = P.PlanCache(path,
                        registry_fingerprint=lilac.REGISTRY.fingerprint())
    acc2 = lilac.compile(naive, mode="host", plan_cache=fresh)
    out = acc2(*a)
    assert fresh.stats.rejected == 1
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(naive(*a)), atol=2e-3, rtol=1e-3)


def test_record_with_stale_digest_or_missing_fields_rejected(tmp_path):
    """The integrity pre-check: schema-1 records always carry
    n_eqns/detect_digest, so a record missing them (truncated/foreign) or
    whose digest disagrees with its own matches is rejected before any
    positional reference is resolved."""
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    path = tmp_path / "plans.json"
    acc = lilac.compile(naive, mode="host", plan_cache=str(path))
    acc(*a)
    doc = json.loads(path.read_text())
    for rec in doc["entries"].values():
        del rec["detect_digest"]                      # truncated record
    path.write_text(json.dumps(doc))
    fresh = P.PlanCache(path,
                        registry_fingerprint=lilac.REGISTRY.fingerprint())
    acc2 = lilac.compile(naive, mode="host", plan_cache=fresh)
    out = acc2(*a)
    assert fresh.stats.rejected == 1                  # fell back to detect
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(naive(*a)), atol=2e-3, rtol=1e-3)


_SUBPROC = textwrap.dedent("""
    import json, sys
    import numpy as np, jax, jax.numpy as jnp
    from repro import lilac
    from repro.core import REGISTRY
    from repro.sparse import csr_from_dense
    from repro.sparse.random import random_dense_sparse

    csr = csr_from_dense(random_dense_sparse(96, 96, 0.1, 0))
    rows, nnz = csr.rows, csr.nnz
    vec = jnp.asarray(np.random.default_rng(1)
                      .standard_normal(96).astype(np.float32))

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)

    acc = lilac.compile(naive, mode="host", policy="autotune")
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    print(json.dumps({
        "selected": acc.last_selections[0][1],
        "plan": acc.plan_info(),
        "tuner": REGISTRY.autotuner.stats.as_dict(),
    }))
""")


def test_cross_process_warm_start_zero_detect(tmp_path):
    """The acceptance criterion: a warm second process rehydrates the
    detection report + pins from the plan cache, performs ZERO
    Detector.detect calls and zero candidate timing, and reaches a baked
    plan."""
    env = dict(os.environ,
               LILAC_AUTOTUNE_CACHE=str(tmp_path / "autotune.json"),
               LILAC_PLAN_CACHE=str(tmp_path / "plans.json"),
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(ROOT, "src"),
                    os.environ.get("PYTHONPATH", "")]).rstrip(os.pathsep))
    p = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr
    first = json.loads(p.stdout.strip().splitlines()[-1])
    assert first["plan"]["baked"] == 1

    # warm start in THIS process with a spy on detection (the conftest
    # fixture already pointed both cache env vars at this tmp_path)
    csr, vec = _problem()
    naive = _naive_fn(csr.rows, csr.nnz)
    calls, restore = _spy_detect()
    try:
        from repro.core import REGISTRY
        REGISTRY.reset_autotuner()
        timing_before = REGISTRY.autotuner.stats.timing_calls
        acc = lilac.compile(naive, mode="host", policy="autotune")
        out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    finally:
        restore()
    assert calls["n"] == 0                            # zero detection
    assert REGISTRY.autotuner.stats.timing_calls == timing_before
    assert acc.plan_info()["baked"] == 1              # straight to baking
    assert acc.last_selections[0][1] == first["selected"]
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(naive(csr.val, csr.col_ind, csr.row_ptr, vec)),
        atol=2e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# satellites: memoized liveness + compile fast path
# ---------------------------------------------------------------------------

def test_idx_of_and_needed_built_once_per_entry():
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        bake=False)
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    acc(*a)
    entry = next(iter(acc._compiled.values()))
    idx_of = entry.idx_of
    needed = entry.needed_for(entry.report.matches)
    assert idx_of and isinstance(needed, frozenset)
    acc(*a)
    assert entry.idx_of is idx_of                     # not rebuilt
    assert entry.needed_for(entry.report.matches) is needed
    assert needed == needed_eqn_ids(entry.closed_jaxpr,
                                    entry.report.matches)


def test_compile_last_entry_fast_path():
    csr, vec = _problem()
    acc = lilac.compile(_naive_fn(csr.rows, csr.nnz), mode="host",
                        bake=False)
    a = (csr.val, csr.col_ind, csr.row_ptr, vec)
    acc(*a)
    entry, _ = acc._compile(a, {})
    assert acc._last_compiled[0] is entry
    # same signature, different arrays: the last-entry template matches
    vec2 = jnp.asarray(np.random.default_rng(9)
                       .standard_normal(csr.shape[1]).astype(np.float32))
    entry2, _ = acc._compile((csr.val, csr.col_ind, csr.row_ptr, vec2), {})
    assert entry2 is entry
    assert len(acc._compiled) == 1
