"""Tunable kernel schedules (spec-declared parameter spaces + variant
sweeps):

  * ``tune`` / ``constraint`` / ``fuse epilogue`` grammar round-trips, and
    malformed clauses fail with line/col positions
  * constraint expressions prune the schedule cross-product (and an
    over-tight constraint set fails at registration, not mid-sweep)
  * schedule params reach kernel bodies as keyword arguments; unknown
    schedule keys raise
  * every schedule variant of the ELL slab kernel is bit-identical to the
    default (fixed-seed always; property-tested under hypothesis)
  * the successive-halving sweep picks the known-best (harness, schedule)
    pair on a rigged timer, spending full measurements only on survivors
  * v2 -> v3 cache migration keeps kernel-level winners as priors and
    never serves them stale when schedule variants exist
  * fused-epilogue detection widens spmv matches and the fused kernels
    reproduce the unfused semantics
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import lilac
from repro.core import what_lang as W
from repro.core.autotune import (Autotuner, AutotuneCache, schedule_key,
                                 signature_of)
from repro.core.harness import CallCtx, HarnessRegistry
from repro.core.marshal import MarshalingCache
from repro.core.spec import SpecError, register_spec
from repro.sparse import csr_from_dense, ell_from_csr
from repro.sparse.random import random_dense_sparse


# ---------------------------------------------------------------------------
# grammar
# ---------------------------------------------------------------------------

TUNED_TEXT = """
HARNESS toy.tuned implements spmv_csr
  formats CSR;
  tune block in {256, 64, 128, 512};
  tune dimsem in {arbitrary, parallel};
  constraint (block * 128) < 65536;
  fuse epilogue;
"""


def test_tune_clause_round_trip():
    d = W.parse_harness(TUNED_TEXT)
    assert [t.name for t in d.tune] == ["block", "dimsem"]
    assert d.tune[0].values == (256, 64, 128, 512)
    assert d.tune[1].values == ("arbitrary", "parallel")
    assert d.fuse_epilogue
    assert len(d.constraints) == 1
    # printed form re-parses to an equal AST (the spec-surface invariant
    # CI checks for every builtin)
    assert W.parse_harness(str(d)) == d
    # default schedule = first declared values (the old hard constants)
    assert d.default_schedule() == {"block": 256, "dimsem": "arbitrary"}


def test_builtin_kernel_specs_round_trip():
    """The shipped Pallas HARNESS blocks (which now carry tune clauses)
    must round-trip through the printer like every other builtin."""
    for comp in ("spmv_ell", "spmv_csr", "spmm_csr", "moe_ffn"):
        for h in lilac.REGISTRY.harnesses_for(comp):
            if not h.tune:
                continue
            assert h.schedules[0] == h.default_schedule
            assert all(set(s) == set(h.default_schedule)
                       for s in h.schedules)


@pytest.mark.parametrize("bad,fragment", [
    ("HARNESS h implements x\n  tune p in {};", "tune value"),
    ("HARNESS h implements x\n  tune p in {1, 1};", "duplicate values"),
    ("HARNESS h implements x\n  tune p in {1};\n  tune p in {2};",
     "duplicate tune parameter"),
    ("HARNESS h implements x\n  constraint a <= 4;", "unknown tune"),
    ("HARNESS h implements x\n  tune p in {1};\n  constraint p = 4;",
     "expected <= or <"),
    ("HARNESS h implements x\n  fuse something;", "epilogue"),
])
def test_tune_parse_errors_have_positions(bad, fragment):
    with pytest.raises(W.ParseError) as ei:
        W.parse_harness(bad)
    assert fragment in str(ei.value)
    # 1-based source position attached (all fixtures err past line 1)
    assert ei.value.line is not None and ei.value.line >= 2
    assert ei.value.col is not None and ei.value.col >= 1


def test_constraint_filters_cross_product():
    d = W.parse_harness(TUNED_TEXT)
    scheds = d.schedules()
    # block * 128 <= 65536 prunes block=512 in every dimsem combination
    assert len(scheds) == 3 * 2
    assert all(s["block"] != 512 for s in scheds)
    assert scheds[0] == d.default_schedule()


def test_overtight_constraints_fail_at_registration():
    reg = HarnessRegistry()
    with pytest.raises(SpecError, match="prune every schedule"):
        register_spec("""
HARNESS toy.bad implements spmv_csr
  tune block in {64, 128};
  constraint block < 64;
""", {"toy.bad": lambda b, ctx, **kw: None}, registry=reg)


def test_default_schedule_violating_constraint_rejected():
    reg = HarnessRegistry()
    with pytest.raises(SpecError, match="default schedule"):
        register_spec("""
HARNESS toy.bad implements spmv_csr
  tune block in {512, 64};
  constraint block <= 128;
""", {"toy.bad": lambda b, ctx, **kw: None}, registry=reg)


# ---------------------------------------------------------------------------
# schedule params -> kernel body
# ---------------------------------------------------------------------------

def _record_registry():
    reg = HarnessRegistry()
    seen = []

    def body(b, ctx, *, block=None, dimsem=None):
        seen.append({"block": block, "dimsem": dimsem})
        return np.zeros(b["rows"], np.float32)

    register_spec(TUNED_TEXT, {"toy.tuned": body}, registry=reg)
    return reg, seen


def _toy_binding(rows=64, nnz=512, cols=64):
    return {"a": np.ones(nnz, np.float32),
            "colidx": np.zeros(nnz, np.int32),
            "rowstr": np.linspace(0, nnz, rows + 1).astype(np.int32),
            "iv": np.ones(cols, np.float32),
            "rows": rows, "nnz": nnz}


def test_schedule_params_reach_body_as_kwargs():
    reg, seen = _record_registry()
    h = reg.get("spmv_csr", "toy.tuned")
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    h(_toy_binding(), ctx)
    assert seen[-1] == {"block": 256, "dimsem": "arbitrary"}   # defaults
    ctx.schedule = {"block": 64, "dimsem": "parallel"}
    h(_toy_binding(), ctx)
    assert seen[-1] == {"block": 64, "dimsem": "parallel"}
    ctx.schedule = {"block": 64}                               # partial
    h(_toy_binding(), ctx)
    assert seen[-1] == {"block": 64, "dimsem": "arbitrary"}
    ctx.schedule = {"nope": 1}
    with pytest.raises(SpecError, match="unknown"):
        h(_toy_binding(), ctx)


# ---------------------------------------------------------------------------
# variant-vs-default bit-identical outputs
# ---------------------------------------------------------------------------

def _ell_problem(rows, cols, density, seed):
    csr = csr_from_dense(random_dense_sparse(rows, cols, density, seed))
    ell = ell_from_csr(csr)
    vec = jnp.asarray(np.random.default_rng(seed + 1)
                      .standard_normal(cols).astype(np.float32))
    return ell, vec


def _assert_variants_bit_identical(rows, cols, density, seed):
    from repro.kernels.spmv_ell import ops as ell_ops
    ell, vec = _ell_problem(rows, cols, density, seed)
    base = np.asarray(ell_ops.spmv_ell(ell.val, ell.col, vec,
                                       interpret=True))
    h = lilac.REGISTRY.get("spmv_ell", "pallas.ell")
    for sched in h.schedules:
        out = np.asarray(ell_ops.spmv_ell(
            ell.val, ell.col, vec,
            rows_per_slab=sched["rows_per_slab"],
            dimension_semantics=sched["dimsem"], interpret=True))
        # bit-identical, not allclose: schedule variants only re-tile the
        # grid, never the within-row accumulation order
        assert (out == base).all(), sched


def test_variants_bit_identical_fixed_seeds():
    _assert_variants_bit_identical(96, 80, 0.15, 3)


def test_variants_bit_identical_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(rows=st.integers(8, 96), cols=st.integers(8, 96),
               density=st.floats(0.05, 0.5), seed=st.integers(0, 5))
    @hyp.settings(max_examples=8, deadline=None)
    def prop(rows, cols, density, seed):
        _assert_variants_bit_identical(rows, cols, density, seed)

    prop()


# ---------------------------------------------------------------------------
# successive halving on a rigged timer
# ---------------------------------------------------------------------------

def _rigged_tuner(monkeypatch, costs, budget=2, fingerprint="fp"):
    """An Autotuner whose variant timer reads from a cost table keyed on
    (harness name, schedule_key) — deterministic sweeps, zero sleeping."""
    calls = []

    def fake_time_variant(self, h, binding, ctx, mode, operands, schedule,
                          reps):
        calls.append((h.name, schedule_key(schedule), reps))
        return costs[(h.name, schedule_key(schedule))]

    monkeypatch.setattr(Autotuner, "_time_variant", fake_time_variant)
    return Autotuner(registry_fingerprint=fingerprint, budget=budget), calls


def test_successive_halving_picks_known_best(monkeypatch):
    reg, _ = _record_registry()
    register_spec("""
HARNESS toy.plain implements spmv_csr
  formats CSR;
""", {"toy.plain": lambda b, ctx: np.zeros(b["rows"], np.float32)},
        registry=reg)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    h = reg.get("spmv_csr", "toy.tuned")
    assert len(h.schedules) == 6          # constraint-filtered space
    best = {"block": 128, "dimsem": "parallel"}
    costs = {("toy.plain", "default"): 5e-3}
    for s in h.schedules:
        costs[("toy.tuned", schedule_key(s))] = \
            1e-4 if s == best else 3e-3
    tuner, calls = _rigged_tuner(monkeypatch, costs, budget=2)
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    w = tuner.select("spmv_csr", "CSR", "cpu", "host", cands,
                     _toy_binding(), ctx, default_name="toy.plain")
    assert w.name == "toy.tuned"
    assert tuner.last_decision.schedule == best
    assert ctx.schedule == best           # pinned for the actual call
    # halving economics: the 7-variant pool was thinned by cheap
    # single-rep rounds; full-rep measurements only for <= budget
    # survivors
    elim = [c for c in calls if c[2] == 1]
    full = [c for c in calls if c[2] != 1]
    assert tuner.stats.elimination_calls == len(elim) > 0
    assert len(full) <= 2
    assert tuner.stats.timing_calls == len(full)
    # the winner's record persists the schedule
    rec = tuner.cache.get(tuner.last_decision.sig, "host")
    assert rec["schedule"] == best and rec["schedule_swept"] is True


def test_variant_pool_cap_keeps_defaults(monkeypatch):
    reg, _ = _record_registry()
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    h = cands[0]
    tuner = Autotuner(registry_fingerprint="fp", budget=8, max_variants=3)
    pool = tuner._variant_pool(cands)
    assert len(pool) == 3
    assert pool[0] == (h, h.schedules[0], None)   # default survives the cap


# ---------------------------------------------------------------------------
# v2 -> v3 migration
# ---------------------------------------------------------------------------

def _v2_record(winner, timings):
    return {"harness": winner, "best_s": timings[winner],
            "timings": timings, "marshal_s": {n: 0.0 for n in timings},
            "reuse": 100.0, "amortized_s": dict(timings),
            "cost_model": "amortized"}


def test_v2_migration_serves_when_no_variants(tmp_path, monkeypatch):
    """Against a variant-free candidate set, a migrated v2 record is still
    authoritative: served with zero re-timing."""
    reg = HarnessRegistry()
    for name in ("toy.a", "toy.b"):
        register_spec(f"""
HARNESS {name} implements spmv_csr
  formats CSR;
""", {name: lambda b, ctx: np.zeros(b["rows"], np.float32)}, registry=reg)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    binding = _toy_binding()
    sig = signature_of("spmv_csr", "CSR", "cpu", binding)
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({
        "schema": 2, "registry": "fp", "entries": {
            sig: {"host": _v2_record("toy.b",
                                     {"toy.a": 2e-3, "toy.b": 1e-3})}}}))
    cache = AutotuneCache(path, registry_fingerprint="fp")
    tuner = Autotuner(registry_fingerprint="fp", cache=cache, budget=4)
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    w = tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx,
                     default_name="toy.a")
    assert w.name == "toy.b"
    assert tuner.stats.timing_calls == 0
    assert tuner.stats.remeasures == 0
    assert cache.stats.migrations == 1


def test_v2_migration_never_serves_stale_winner_with_variants(
        tmp_path, monkeypatch):
    """When any live candidate declares schedule variants, a migrated
    (unswept) v2 winner is a *prior*, not an answer: the tuner re-sweeps
    and can dethrone it with a swept schedule."""
    reg, _ = _record_registry()                     # toy.tuned (6 variants)
    register_spec("""
HARNESS toy.legacy implements spmv_csr
  formats CSR;
""", {"toy.legacy": lambda b, ctx: np.zeros(b["rows"], np.float32)},
        registry=reg)
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    binding = _toy_binding()
    sig = signature_of("spmv_csr", "CSR", "cpu", binding)
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps({
        "schema": 2, "registry": "fp", "entries": {
            sig: {"host": _v2_record(
                "toy.legacy",
                {"toy.legacy": 1e-3, "toy.tuned": 2e-3})}}}))
    best = {"block": 64, "dimsem": "parallel"}
    costs = {("toy.legacy", "default"): 1e-3}
    h = reg.get("spmv_csr", "toy.tuned")
    for s in h.schedules:
        costs[("toy.tuned", schedule_key(s))] = \
            1e-5 if s == best else 5e-3
    cache = AutotuneCache(path, registry_fingerprint="fp")
    tuner, calls = _rigged_tuner(monkeypatch, costs, budget=2)
    tuner._cache = cache
    tuner._cache_injected = True
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    w = tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx,
                     default_name="toy.legacy")
    # the stale kernel-level winner was NOT served: a sweep ran and found
    # the faster swept schedule
    assert tuner.stats.remeasures == 1
    assert w.name == "toy.tuned"
    assert tuner.last_decision.schedule == best
    # the prior winner was ranked into the sweep (survived budget
    # truncation) rather than discarded
    assert any(name == "toy.legacy" for name, _, _ in calls)
    # and the re-written record is schedule-swept: a second select serves
    # from cache with no further timing
    n = len(calls)
    w2 = tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding,
                      ctx, default_name="toy.legacy")
    assert w2.name == "toy.tuned" and len(calls) == n


def test_stale_pinned_schedule_retunes(monkeypatch):
    """A v3 record whose pinned schedule vanished from the declared family
    (the tune space changed) re-measures instead of running a dead pin."""
    reg, _ = _record_registry()
    cands = reg.candidates("spmv_csr", "CSR", "cpu", "host")
    binding = _toy_binding()
    sig = signature_of("spmv_csr", "CSR", "cpu", binding)
    h = cands[0]
    costs = {("toy.tuned", schedule_key(s)): 1e-3 for s in h.schedules}
    tuner, calls = _rigged_tuner(monkeypatch, costs, budget=8)
    tuner.cache.put(sig, "host", {
        "harness": "toy.tuned", "best_s": 1e-4,
        "timings": {"toy.tuned": 1e-4}, "marshal_s": {}, "reuse": 100.0,
        "amortized_s": {"toy.tuned": 1e-4}, "cost_model": "amortized",
        "schedule": {"block": 1024, "dimsem": "arbitrary"},   # no longer valid
        "schedules": {}, "variant_s": {}, "schedule_swept": True},
        persist=False)
    ctx = CallCtx(mode="host", cache=MarshalingCache(), format="CSR")
    w = tuner.select("spmv_csr", "CSR", "cpu", "host", cands, binding, ctx,
                     default_name="toy.tuned")
    assert tuner.stats.remeasures == 1
    assert w.name == "toy.tuned"
    assert tuner.last_decision.schedule in h.schedules


# ---------------------------------------------------------------------------
# end-to-end: autotuned schedule pinned into the rewrite
# ---------------------------------------------------------------------------

def test_autotune_pins_harness_and_schedule_into_rewrite(monkeypatch):
    reg = HarnessRegistry()
    fast = {"block": 128, "dimsem": "arbitrary"}

    # rig the timer (wall-clock sleeps flake on loaded machines): the
    # harness still executes for real through the rigged measurement and
    # the pinned call, so numerics are exercised end to end
    real = Autotuner._time_variant

    def rigged(self, h, binding, ctx, mode, operands, schedule, reps):
        t = real(self, h, binding, ctx, mode, operands, schedule, reps)
        if t is None:
            return None
        return 1e-5 if schedule == fast else 1e-2

    monkeypatch.setattr(Autotuner, "_time_variant", rigged)

    def tuned_body(b, ctx, *, block=256, dimsem="arbitrary"):
        row = jnp.repeat(jnp.arange(b["rows"], dtype=jnp.int32),
                         jnp.diff(b["rowstr"]),
                         total_repeat_length=b["nnz"])
        return jax.ops.segment_sum(b["a"] * b["iv"][b["colidx"]], row,
                                   num_segments=b["rows"])

    register_spec("""
HARNESS toy.tuned implements spmv_csr
  formats CSR;
  tune block in {256, 128};
  tune dimsem in {arbitrary};
""", {"toy.tuned": tuned_body}, registry=reg)
    reg._defaults[("spmv_csr", jax.default_backend())] = "toy.tuned"

    csr = csr_from_dense(random_dense_sparse(64, 64, 0.2, 0))
    vec = jnp.asarray(np.random.default_rng(1)
                      .standard_normal(64).astype(np.float32))

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(64, dtype=jnp.int32), jnp.diff(row_ptr),
                         total_repeat_length=csr.nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=64)

    acc = lilac.compile(naive, mode="host", policy="autotune", registry=reg)
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    ref = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=1e-3)
    assert acc.last_selections[0][1] == "toy.tuned"
    assert acc.last_schedules[0] == fast
    entry = next(iter(acc._compiled.values()))
    assert entry.pins == {0: ("toy.tuned", fast, None)}
    # repeat call rides the pin: same schedule, zero re-timing
    timed = reg.autotuner.stats.timing_calls
    acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert acc.last_schedules[0] == fast
    assert reg.autotuner.stats.timing_calls == timed


# ---------------------------------------------------------------------------
# fused epilogues
# ---------------------------------------------------------------------------

def _spmv_fn(rows, nnz):
    def fn(val, col, row_ptr, v, b):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        y = jax.ops.segment_sum(val * v[col], row, num_segments=rows)
        return jax.nn.relu(y + b)
    return fn


def test_epilogue_detection_and_rewrite_equivalence():
    csr = csr_from_dense(random_dense_sparse(96, 96, 0.1, 0))
    rng = np.random.default_rng(1)
    vec = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    bias = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    fn = _spmv_fn(csr.rows, csr.nnz)
    acc = lilac.compile(fn, mode="host")
    rep = acc.report_for(csr.val, csr.col_ind, csr.row_ptr, vec, bias)
    assert len(rep.matches) == 1
    m = rep.matches[0]
    assert m.epilogue == "relu" and "bias" in m.binding
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec, bias)
    ref = fn(csr.val, csr.col_ind, csr.row_ptr, vec, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)


def test_epilogue_not_fused_when_intermediate_escapes():
    """If the pre-activation value is also a function output, fusing it
    away would change observable results — the match must stay unfused."""
    csr = csr_from_dense(random_dense_sparse(64, 64, 0.1, 0))
    rng = np.random.default_rng(1)
    vec = jnp.asarray(rng.standard_normal(64).astype(np.float32))

    def fn(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(64, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=csr.nnz)
        y = jax.ops.segment_sum(val * v[col], row, num_segments=64)
        return y, jax.nn.relu(y)

    acc = lilac.compile(fn, mode="host")
    rep = acc.report_for(csr.val, csr.col_ind, csr.row_ptr, vec)
    assert len(rep.matches) == 1
    assert rep.matches[0].epilogue is None
    outs = acc(csr.val, csr.col_ind, csr.row_ptr, vec)
    refs = fn(csr.val, csr.col_ind, csr.row_ptr, vec)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   atol=2e-3, rtol=1e-3)


def test_fused_ell_kernel_matches_unfused_semantics():
    from repro.core.rewrite import apply_epilogue
    from repro.kernels.spmv_ell import ops as ell_ops
    ell, vec = _ell_problem(96, 80, 0.15, 7)
    rows = ell.val.shape[0]
    bias = jnp.asarray(np.random.default_rng(8)
                       .standard_normal(rows).astype(np.float32))
    base = ell_ops.spmv_ell(ell.val, ell.col, vec, interpret=True)
    for ep in ("relu", "silu", "none"):
        fused = np.asarray(ell_ops.spmv_ell(ell.val, ell.col, vec,
                                            epilogue=ep, bias=bias,
                                            interpret=True))
        ref = np.asarray(apply_epilogue(base, bias, ep))
        np.testing.assert_allclose(fused, ref, atol=1e-6, rtol=1e-6)


def test_fused_kernels_fall_back_on_scalar_bias():
    """relu(spmv + 0.5) binds a scalar bias; the fused kernels tile a
    (rows,) bias, so mis-shaped biases must take the post-kernel path
    (correct, just unfused) instead of crashing the Pallas harness."""
    from repro.core.rewrite import apply_epilogue
    from repro.kernels.spmv_ell import ops as ell_ops
    ell, vec = _ell_problem(64, 64, 0.2, 11)
    base = ell_ops.spmv_ell(ell.val, ell.col, vec, interpret=True)
    out = np.asarray(ell_ops.spmv_ell(ell.val, ell.col, vec,
                                      epilogue="relu",
                                      bias=jnp.float32(0.5),
                                      interpret=True))
    ref = np.asarray(apply_epilogue(base, jnp.float32(0.5), "relu"))
    np.testing.assert_allclose(out, ref, atol=1e-6)

    # end-to-end: detection binds the scalar literal, pallas.ell by
    # explicit policy must still produce the right values
    csr = csr_from_dense(random_dense_sparse(64, 64, 0.2, 0))
    vec2 = jnp.asarray(np.random.default_rng(1)
                       .standard_normal(64).astype(np.float32))

    def fn(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(64, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=csr.nnz)
        y = jax.ops.segment_sum(val * v[col], row, num_segments=64)
        return jax.nn.relu(y + 0.5)

    acc = lilac.compile(fn, mode="host", policy="pallas.ell")
    rep = acc.report_for(csr.val, csr.col_ind, csr.row_ptr, vec2)
    assert rep.matches and rep.matches[0].epilogue == "relu"
    out = acc(csr.val, csr.col_ind, csr.row_ptr, vec2)
    ref = fn(csr.val, csr.col_ind, csr.row_ptr, vec2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)


def test_fused_bsr_kernel_matches_unfused_semantics():
    from repro.core.rewrite import apply_epilogue
    from repro.kernels.bsr_spmm import ops as bsr_ops
    from repro.sparse.convert import csr_to_bcsr
    d = random_dense_sparse(256, 128, 0.2, seed=0)
    bcsr = csr_to_bcsr(csr_from_dense(d), block_shape=(128, 128))
    rng = np.random.default_rng(1)
    dense = jnp.asarray(rng.standard_normal((128, 256)).astype(np.float32))
    base = bsr_ops.bsr_spmm(bcsr, dense, interpret=True)
    bias_r = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    bias_c = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    for bias, kind in ((bias_r, "row"), (bias_c, "col"), (None, None)):
        fused = np.asarray(bsr_ops.bsr_spmm(
            bcsr, dense, epilogue="silu", bias=bias, bias_kind=kind,
            interpret=True))
        b = None if bias is None else (
            np.asarray(bias)[:, None] if kind == "row"
            else np.asarray(bias)[None, :])
        ref = np.asarray(apply_epilogue(np.asarray(base), b, "silu"))
        np.testing.assert_allclose(fused, ref, atol=1e-5, rtol=1e-5)
