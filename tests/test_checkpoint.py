"""Checkpointing + fault tolerance: atomic commit, restart, elastic
reshard across different mesh shapes, resumable data, stragglers."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer
from repro.train.data import SyntheticLM
from repro.train.elastic import StragglerMonitor, plan_remesh


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32)),
        "b": {"scale": jnp.asarray(rng.standard_normal(16).astype(np.float32)),
              "step": jnp.asarray(3, jnp.int32)},
        "h": jnp.asarray(rng.standard_normal(4).astype(np.float32)
                         ).astype(jnp.bfloat16),
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(10, t, blocking=True)
    assert ck.latest_step() == 10
    out = ck.restore(10, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).astype(np.float64) if a.dtype == jnp.bfloat16
            else np.asarray(a),
            np.asarray(b).astype(np.float64) if b.dtype == jnp.bfloat16
            else np.asarray(b))


def test_async_save_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    ck.wait()
    assert ck.available_steps() == [3, 4]


def test_atomic_no_partial_checkpoint(tmp_path):
    """A .tmp dir (crashed writer) is never listed as restorable."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=True)
    os.makedirs(tmp_path / "step_6.tmp")
    (tmp_path / "step_6.tmp" / "junk.npy").write_bytes(b"xx")
    assert ck.available_steps() == [5]


def test_elastic_reshard_across_mesh_shapes(tmp_path):
    """Save on a (4,2) mesh, restore onto (2,4) — the restart-after-resize
    path. Runs in a subprocess with 8 host devices."""
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import Checkpointer

t = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
s1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
placed = jax.tree.map(jax.device_put, t, s1)
ck = Checkpointer({str(tmp_path)!r})
ck.save(1, placed, blocking=True)

mesh2 = jax.make_mesh((2, 4), ("data", "model"))
s2 = {{"w": NamedSharding(mesh2, P("data", "model"))}}
out = ck.restore(1, t, shardings=s2)
assert out["w"].sharding.is_equivalent_to(s2["w"], 2)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
print("ELASTIC_OK")
"""
    env = {**os.environ, "PYTHONPATH": os.path.join(
        os.path.dirname(__file__), "..", "src")}
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in proc.stdout, proc.stderr[-1500:]


def test_plan_remesh():
    assert plan_remesh(512, 16) == (32, 16)
    assert plan_remesh(496, 16) == (31, 16)   # one node lost
    with pytest.raises(AssertionError):
        plan_remesh(8, 16)


def test_data_pipeline_pure_function_of_step():
    d1 = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=7)
    d2 = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=7)
    b1 = d1.batch_at(123)
    b2 = d2.batch_at(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(124)["tokens"], b1["tokens"])


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0)
    events = []
    mon.on_straggler = lambda s, t, e: events.append(s)
    for s in range(10):
        mon.observe(s, 0.1)
    assert mon.observe(10, 1.0)          # 10x the EWMA -> straggler
    assert events == [10]
    assert not mon.observe(11, 0.1)      # EWMA not poisoned by the outlier


def test_train_restart_resumes(tmp_path):
    from repro.configs import get_arch, smoke_config
    from repro.models import build_model
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.optim import AdamWConfig

    cfg = smoke_config(get_arch("olmo-1b"))
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=2)
    lc = LoopConfig(steps=6, ckpt_every=3, log_every=100,
                    ckpt_dir=str(tmp_path))
    r1 = train_loop(model, AdamWConfig(total_steps=10), lc, data.batch_at,
                    emit=lambda s: None)
    lc2 = LoopConfig(steps=8, ckpt_every=100, log_every=100,
                     ckpt_dir=str(tmp_path))
    r2 = train_loop(model, AdamWConfig(total_steps=10), lc2, data.batch_at,
                    emit=lambda s: None)
    assert len(r2["history"]) == 2      # resumed at 6, ran 6..8
