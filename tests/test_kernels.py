"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret=True).

Shapes and dtypes are swept per kernel; tolerance accounts for f32
accumulation differences only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import ell_from_csr, random_bcsr, random_csr
from repro.kernels.bsr_spmm import ops as bsr_ops
from repro.kernels.spmv_ell import ops as ell_ops
from repro.kernels.moe_gmm import ops as gmm_ops
from repro.kernels.moe_gmm.kernel import gmm_pallas
from repro.kernels.moe_gmm.ref import gmm_ref


@pytest.mark.parametrize("rows,cols,n,bm,density", [
    (256, 384, 256, 128, 0.3),
    (128, 128, 128, 64, 0.5),
    (384, 256, 128, 128, 0.1),
])
def test_bsr_spmm_shapes(rows, cols, n, bm, density):
    bcsr = random_bcsr(rows, cols, block_shape=(bm, 128),
                       block_density=density, seed=rows + n)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (cols, n)).astype(np.float32))
    ref = bsr_ops.bsr_spmm_oracle(bcsr, x)
    out = bsr_ops.bsr_spmm(bcsr, x, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_spmm_dtypes(dtype):
    bcsr = random_bcsr(256, 256, block_shape=(128, 128), block_density=0.4,
                       seed=7)
    bcsr = jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, bcsr)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (256, 128)).astype(np.float32)).astype(dtype)
    ref = bsr_ops.bsr_spmm_oracle(bcsr, x)
    out = bsr_ops.bsr_spmm(bcsr, x, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=tol, rtol=tol)


def test_bsr_spmm_empty_block_rows():
    """Rows with no stored blocks must produce zeros (explicit zero block)."""
    d = np.zeros((256, 256), np.float32)
    d[:128] = np.random.default_rng(0).standard_normal((128, 256))
    from repro.sparse import bcsr_from_dense
    bcsr = bcsr_from_dense(d, (128, 128))
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (256, 128)).astype(np.float32))
    out = bsr_ops.bsr_spmm(bcsr, x, interpret=True)
    np.testing.assert_allclose(np.asarray(out)[128:], 0.0)
    np.testing.assert_allclose(out, d @ np.asarray(x), atol=1e-3)


@pytest.mark.parametrize("rows,cols,density,skew", [
    (200, 300, 0.05, 0.0),
    (64, 64, 0.2, 0.0),
    (512, 128, 0.02, 1.0),      # power-law rows (graph-like)
])
def test_spmv_ell_shapes(rows, cols, density, skew):
    csr = random_csr(rows, cols, density=density, seed=rows, skew=skew)
    ell = ell_from_csr(csr)
    vec = jnp.asarray(np.random.default_rng(3).standard_normal(
        cols).astype(np.float32))
    ref = ell_ops.spmv_ell_oracle(ell.val, ell.col, vec)
    out = ell_ops.spmv_ell(ell.val, ell.col, vec, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


def test_spmv_ell_windowed():
    csr = random_csr(128, 512, density=0.05, seed=11)
    ell = ell_from_csr(csr)
    vec = jnp.asarray(np.random.default_rng(4).standard_normal(
        512).astype(np.float32))
    ref = ell_ops.spmv_ell_oracle(ell.val, ell.col, vec)
    from repro.kernels.spmv_ell.ops import _windowed
    out = _windowed(ell.val, ell.col, vec, 8, True, window=128)
    np.testing.assert_allclose(out[:128], ref, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,D,F,E,K,tm", [
    (64, 128, 256, 8, 2, 16),
    (32, 96, 192, 4, 4, 8),
    (128, 64, 128, 16, 2, 32),
])
def test_moe_gmm_shapes(T, D, F, E, K, tm):
    rng = np.random.default_rng(T + E)
    x = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    gate = jnp.asarray(rng.random((T, K)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, E, (T, K)).astype(np.int32))
    wg = jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .05)
    wu = jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .05)
    wd = jnp.asarray(rng.standard_normal((E, F, D)).astype(np.float32) * .05)
    ref = gmm_ops.moe_ffn_oracle(x, gate, idx, wg, wu, wd)
    out = gmm_ops.moe_ffn(x, gate, idx, wg, wu, wd, tm=tm, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)


def test_gmm_kernel_direct():
    """The raw group-aligned gmm vs its oracle."""
    rng = np.random.default_rng(0)
    Tp, D, F, E, tm = 64, 32, 64, 4, 16
    xs = jnp.asarray(rng.standard_normal((Tp, D)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32))
    te = jnp.asarray(rng.integers(0, E, Tp // tm).astype(np.int32))
    ref = gmm_ref(xs, w, te, tm)
    out = gmm_pallas(xs, w, te, tm=tm, fn=32, dk=16, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
