"""Detection (paper §4.1, Table 3): the backtracking matcher must find
sparse linear algebra across syntactic variants, and must NOT fire on
superficially similar dense code (negative controls)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.detect import Detector


@pytest.fixture(scope="module")
def det():
    return Detector()


ROWS, COLS, NNZ = 16, 8, 40


def _args():
    rng = np.random.default_rng(0)
    val = jnp.asarray(rng.standard_normal(NNZ).astype(np.float32))
    col = jnp.asarray(rng.integers(0, COLS, NNZ).astype(np.int32))
    row = jnp.asarray(np.sort(rng.integers(0, ROWS, NNZ)).astype(np.int32))
    cuts = np.sort(rng.integers(0, NNZ + 1, ROWS - 1))
    row_ptr = jnp.asarray(np.concatenate([[0], cuts, [NNZ]]).astype(np.int32))
    vec = jnp.asarray(rng.standard_normal(COLS).astype(np.float32))
    return val, col, row, row_ptr, vec


# -- positive variants (Table 3 rows) ----------------------------------------

def test_coo_segment_sum(det):
    val, col, row, _, vec = _args()

    def f(val, row, col, vec):
        return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)

    r = det.detect_fn(f, val, row, col, vec)
    assert [m.format for m in r.matches] == ["COO"]


def test_csr_repeat_diff(det):
    val, col, _, row_ptr, vec = _args()

    def f(val, col, row_ptr, vec):
        row = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=NNZ)
        return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)

    r = det.detect_fn(f, val, col, row_ptr, vec)
    assert [m.format for m in r.matches] == ["CSR"]
    assert "rowstr" in r.matches[0].binding


def test_csr_searchsorted_variant(det):
    """A different row-expansion idiom — provenance + semantic validation
    accepts any subgraph equivalent to repeat(arange, diff(row_ptr))."""
    val, col, _, row_ptr, vec = _args()

    def f(val, col, row_ptr, vec):
        row = jnp.searchsorted(row_ptr, jnp.arange(NNZ, dtype=jnp.int32),
                               side="right").astype(jnp.int32) - 1
        return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)

    r = det.detect_fn(f, val, col, row_ptr, vec)
    assert [m.format for m in r.matches] == ["CSR"]


def test_commuted_multiply(det):
    """Fig. 13 backtracking: operand order must not matter."""
    val, col, row, _, vec = _args()

    def f(val, row, col, vec):
        return jax.ops.segment_sum(vec[col] * val, row, num_segments=ROWS)

    r = det.detect_fn(f, val, row, col, vec)
    assert len(r.matches) == 1


def test_ell_padded(det):
    def f(val, col, vec):
        return jnp.sum(val * vec[col], axis=1)

    r = det.detect_fn(f, jnp.ones((ROWS, 8)), jnp.zeros((ROWS, 8), jnp.int32),
                      jnp.ones(COLS))
    assert [m.format for m in r.matches] == ["ELL"]


def test_jds_with_perm(det):
    def f(val, col, perm, vec):
        acc = jnp.sum(val * vec[col], axis=1)
        return jnp.zeros(ROWS, acc.dtype).at[perm].set(acc)

    r = det.detect_fn(f, jnp.ones((ROWS, 8)), jnp.zeros((ROWS, 8), jnp.int32),
                      jnp.arange(ROWS, dtype=jnp.int32), jnp.ones(COLS))
    assert [m.format for m in r.matches] == ["JDS"]


def test_loop_skeleton_coo(det):
    """Control-flow skeleton matching (paper's primary case)."""
    val, col, row, _, vec = _args()

    def f(val, row, col, vec):
        def body(j, out):
            return out.at[row[j]].add(val[j] * vec[col[j]])
        return jax.lax.fori_loop(0, NNZ, body, jnp.zeros(ROWS))

    r = det.detect_fn(f, val, row, col, vec)
    assert [m.variant for m in r.matches] == ["loop"]


def test_loop_skeleton_dot(det):
    def f(a, b):
        return jax.lax.fori_loop(
            0, 8, lambda i, acc: acc + a[i] * b[i], jnp.float32(0))

    r = det.detect_fn(f, jnp.ones(8), jnp.ones(8))
    assert [m.computation for m in r.matches] == ["dotproduct"]


def test_dot_vectorized_and_language_invariance(det):
    """Fig. 11: different surface syntax, same jaxpr, same detection."""
    a, b = jnp.ones(8), jnp.ones(8)

    def f1(a, b):
        return jnp.sum(a * b)

    def f2(a, b):
        return jnp.dot(a, b)

    def f3(a, b):
        total = a * b
        return total.sum()

    for f in (f1, f2, f3):
        r = det.detect_fn(f, a, b)
        assert len(r.matches) == 1, f
        assert r.matches[0].computation == "dotproduct"


def test_gemv(det):
    r = det.detect_fn(lambda m, v: m @ v, jnp.ones((16, 8)), jnp.ones(8))
    assert [m.computation for m in r.matches] == ["gemv"]


def test_moe_dispatch(det):
    from repro.models.layers import _moe_naive_2d
    T, D, F, E, K = 8, 16, 32, 4, 2
    r = det.detect_fn(
        _moe_naive_2d, jnp.ones((T, D)), jnp.ones((T, K)),
        jnp.zeros((T, K), jnp.int32), jnp.ones((E, D, F)),
        jnp.ones((E, D, F)), jnp.ones((E, F, D)))
    assert [m.computation for m in r.matches] == ["moe_ffn"]
    assert r.matches[0].binding["experts"] == E


def test_multiple_matches_in_one_program(det):
    """CG-like step: two dots + one SpMV, all detected."""
    val, col, row, _, vec = _args()

    def f(val, row, col, p, r_):
        q = jax.ops.segment_sum(val * p[col], row, num_segments=ROWS)
        alpha = jnp.sum(r_ * r_) / jnp.sum(jnp.pad(p, (0, ROWS - COLS)) * q)
        return alpha

    r = det.detect_fn(f, val, row, col, vec, jnp.ones(ROWS))
    comps = sorted(m.computation for m in r.matches)
    assert comps.count("dotproduct") == 2
    assert "spmv_csr" in comps or "spmv_coo" in comps


# -- negative controls (no false positives on dense/attention code) ----------

def test_negative_softmax_attention(det):
    def f(q, k, v):
        return jax.nn.softmax(q @ k.T) @ v

    r = det.detect_fn(f, jnp.ones((8, 4)), jnp.ones((8, 4)), jnp.ones((8, 4)))
    assert all(m.computation in ("gemv",) for m in r.matches)  # no sparse
    assert not any("spmv" in m.computation for m in r.matches)


def test_negative_scatter_mean_not_spmv(det):
    """segment MEAN has a divide — must not match the SpMV sum pattern."""
    val, col, row, _, vec = _args()

    def f(val, row, col, vec):
        s = jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)
        n = jax.ops.segment_sum(jnp.ones_like(val), row, num_segments=ROWS)
        return s / jnp.maximum(n, 1)

    r = det.detect_fn(f, val, row, col, vec)
    # the sum core may legitimately match; the mean itself must not create
    # a second spurious spmv of the ones-vector with a gather
    assert sum(1 for m in r.matches if "spmv" in m.computation) <= 1


def test_negative_wrong_rowptr_semantics(det):
    """A row vector NOT derived from a valid row_ptr expansion must not
    bind as CSR (semantic validation, beyond the paper)."""
    val, col, _, row_ptr, vec = _args()

    def f(val, col, row_ptr, vec):
        # bogus: uses row_ptr but NOT as a CSR expansion
        row = (jnp.cumsum(jnp.ones(NNZ, jnp.int32))
               + row_ptr[:1].astype(jnp.int32)) % ROWS
        return jax.ops.segment_sum(val * vec[col], row, num_segments=ROWS)

    r = det.detect_fn(f, val, col, row_ptr, vec)
    for m in r.matches:
        assert m.format != "CSR"   # may match as derived-COO, never CSR


def test_spmm_csr_detection_and_rewrite(det):
    """SpMM (CSR x dense matrix) — the doubly-forall What-program."""
    from repro import lilac
    rng = np.random.default_rng(0)
    val = jnp.asarray(rng.standard_normal(NNZ).astype(np.float32))
    col = jnp.asarray(rng.integers(0, COLS, NNZ).astype(np.int32))
    cuts = np.sort(rng.integers(0, NNZ + 1, ROWS - 1))
    row_ptr = jnp.asarray(np.concatenate([[0], cuts, [NNZ]]).astype(np.int32))
    dense = jnp.asarray(rng.standard_normal((COLS, 6)).astype(np.float32))

    def f(val, col, row_ptr, dense):
        row = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=NNZ)
        return jax.ops.segment_sum(val[:, None] * dense[col], row,
                                   num_segments=ROWS)

    r = det.detect_fn(f, val, col, row_ptr, dense)
    assert [(m.computation, m.format) for m in r.matches] \
        == [("spmm_csr", "CSR")]
    ref = f(val, col, row_ptr, dense)
    opt = lilac.compile(f)
    np.testing.assert_allclose(np.asarray(opt(val, col, row_ptr, dense)),
                               np.asarray(ref), atol=1e-4)
    acc = lilac.compile(f, mode="host", policy="jnp.bcsr")
    np.testing.assert_allclose(np.asarray(acc(val, col, row_ptr, dense)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)
    acc2 = lilac.compile(f, mode="host", policy="pallas.bcsr")
    np.testing.assert_allclose(np.asarray(acc2(val, col, row_ptr, dense)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)
