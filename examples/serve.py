"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively — the full serving flow (prefill cache -> decode cache
handoff) on a reduced config.

Run:  PYTHONPATH=src python examples/serve.py [--arch olmo-1b] [--tokens 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch))
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    max_seq = args.prompt_len + args.tokens

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab, (args.batch, args.prompt_len))
        .astype(np.int32))

    t0 = time.perf_counter()
    logits, caches = model.prefill(params, {"tokens": prompts})
    cache = model.cache_from_prefill(caches, args.prompt_len, max_seq)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode, donate_argnums=(1,))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.tokens - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill({args.prompt_len} toks): {t_prefill*1e3:.1f} ms, "
          f"decode: {t_decode/max(args.tokens-1,1)*1e3:.2f} ms/token")
    print("generated token ids (first row):", np.asarray(gen[0])[:12])


if __name__ == "__main__":
    main()
