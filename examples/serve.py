"""Serving-tier client: drive the continuous-batching engine
(``repro.serve``) over a synthetic workload on baked plans.

The engine owns the whole flow this script used to hand-roll — bucketed
plan prewarming, per-request prefill/cache install, per-step admit/evict,
batched decode with per-slot positions — so the client is: build engine,
submit workload, read metrics.

Run:  PYTHONPATH=src python examples/serve.py [--arch olmoe-1b-7b]
          [--requests 8] [--mode continuous|static]
"""
import argparse
import json

from repro.serve import (BucketPolicy, ServeConfig, SyntheticWorkload,
                         build_engine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--tokens", type=int, default=12,
                    help="max new tokens per request")
    args = ap.parse_args()

    grid = (4, 8, 12)            # prompt lengths -> prewarmed prefills
    # admit_deadline_s: a full queue is retried with bounded backoff
    # (Scheduler.try_admit) before rejecting; deadline_s evicts requests
    # that overstay their latency budget instead of pinning a slot
    cfg = ServeConfig(buckets=BucketPolicy(batch=(1, 2, 4), seq=(32, 64)),
                      mode=args.mode, prefill_lengths=grid,
                      admit_deadline_s=0.05, deadline_s=120.0)
    eng = build_engine(args.arch, smoke=True, config=cfg)
    pw = eng.metrics.prewarm
    print(f"prewarm: {pw['baked']}/{pw['n_signatures']} bucket plans baked "
          f"({pw['plan_cache_hits']} rehydrated from the plan cache)")

    wl = SyntheticWorkload(n_requests=args.requests,
                           vocab=eng.model.cfg.vocab, prompt_grid=grid,
                           new_tokens=(2, args.tokens), rate_rps=0.0, seed=0)
    pairs = wl.requests()
    snap = eng.run(pairs)

    print(f"mode={args.mode} finished={snap['requests']['finished']} "
          f"steps={snap['steps']} occupancy={snap['batch_occupancy']:.2f}")
    print(f"ttft p50={snap['ttft_s']['p50'] * 1e3:.1f} ms  "
          f"decode-step p50={snap['decode_step_s']['p50'] * 1e3:.2f} ms  "
          f"bucket hits/misses={snap['buckets']['hits']}"
          f"/{snap['buckets']['misses']}")
    res = snap["resilience"]
    print(f"resilience: decode_faults={res['decode_faults']} "
          f"fault_evictions={res['fault_evictions']} "
          f"admission_retries={res['admission_retries']}")
    first = pairs[0][1]
    print("first request tokens:", json.dumps(first.tokens[:10]))


if __name__ == "__main__":
    main()
