"""BFS graph kernel (paper §5) as boolean-semiring SpMV; the frontier
changes every step but the matrix doesn't — marshaling still amortizes.

Run:  PYTHONPATH=src python examples/bfs.py [--nodes 8192]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.sparse.random import random_graph_csr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8192)
    ap.add_argument("--policy", default="autotune")
    args = ap.parse_args()

    g = random_graph_csr(args.nodes, avg_degree=8, seed=0)
    n = g.rows
    val01 = jnp.asarray((np.asarray(g.val) > 0).astype(np.float32))

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(n, dtype=jnp.int32), jnp.diff(row_ptr),
                         total_repeat_length=val.shape[0])
        return jax.ops.segment_sum(val * v[col], row, num_segments=n)

    def bfs(spmv, steps=12):
        frontier = jnp.zeros(n).at[0].set(1.0)
        visited = frontier
        for _ in range(steps):
            nxt = spmv(val01, g.col_ind, g.row_ptr, frontier)
            frontier = jnp.where((nxt > 0) & (visited == 0), 1.0, 0.0)
            visited = jnp.maximum(visited, frontier)
        return visited

    naive_jit = jax.jit(naive)
    jax.block_until_ready(bfs(naive_jit))
    t0 = time.perf_counter()
    v0 = bfs(naive_jit)
    jax.block_until_ready(v0)
    t_naive = time.perf_counter() - t0

    spmv = lilac.compile(naive, mode="host", policy=args.policy)
    jax.block_until_ready(bfs(spmv))
    t0 = time.perf_counter()
    v1 = bfs(spmv)
    jax.block_until_ready(v1)
    t_lilac = time.perf_counter() - t0

    print(f"nodes={n} nnz={g.nnz}")
    print(f"reached {int(v0.sum())} nodes (naive) / {int(v1.sum())} (lilac)")
    print(f"naive : {t_naive:.3f}s   lilac : {t_lilac:.3f}s   "
          f"speedup {t_naive / t_lilac:.2f}x")


if __name__ == "__main__":
    main()
