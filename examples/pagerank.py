"""PageRank graph kernel (paper §5): many SpMV iterations over ONE matrix —
the marshaling cache amortizes the format repack (paper Fig. 18).

Run:  PYTHONPATH=src python examples/pagerank.py [--nodes 8192]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.sparse.random import random_graph_csr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8192)
    ap.add_argument("--iters", type=int, default=40)
    ap.add_argument("--policy", default="jnp.ell")
    args = ap.parse_args()

    g = random_graph_csr(args.nodes, avg_degree=16, seed=0)
    n = g.rows

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(n, dtype=jnp.int32), jnp.diff(row_ptr),
                         total_repeat_length=val.shape[0])
        return jax.ops.segment_sum(val * v[col], row, num_segments=n)

    def pagerank(spmv):
        x = jnp.ones(n) / n
        for _ in range(args.iters):
            x = 0.85 * spmv(g.val, g.col_ind, g.row_ptr, x) + 0.15 / n
        return x

    naive_jit = jax.jit(naive)
    jax.block_until_ready(pagerank(naive_jit))
    t0 = time.perf_counter()
    x0 = pagerank(naive_jit)
    jax.block_until_ready(x0)
    t_naive = time.perf_counter() - t0

    # bake=False keeps the per-call marshaling cache live so the
    # cache.clear() ablation below really re-packs every iteration (a
    # baked plan hoists the repack and would ignore the clear); see
    # docs/dispatch.md for the baked steady-state path.
    spmv = lilac.compile(naive, mode="host", policy=args.policy,
                         bake=False)
    jax.block_until_ready(pagerank(spmv))   # warm (includes the one repack)
    t0 = time.perf_counter()
    x1 = pagerank(spmv)
    jax.block_until_ready(x1)
    t_lilac = time.perf_counter() - t0

    # ablation: clear the cache every iteration = the naive-marshaling
    # variant of Fig. 18
    def pagerank_no_marshal():
        x = jnp.ones(n) / n
        for _ in range(args.iters):
            spmv.cache.clear()
            x = 0.85 * spmv(g.val, g.col_ind, g.row_ptr, x) + 0.15 / n
        return x

    t0 = time.perf_counter()
    x2 = pagerank_no_marshal()
    jax.block_until_ready(x2)
    t_nomarshal = time.perf_counter() - t0

    print(f"nodes={n} nnz={g.nnz} iters={args.iters}")
    print(f"naive jit        : {t_naive:7.3f}s")
    print(f"lilac (marshal)  : {t_lilac:7.3f}s  speedup {t_naive/t_lilac:.2f}x")
    print(f"lilac (no cache) : {t_nomarshal:7.3f}s  "
          f"marshaling win {t_nomarshal/t_lilac:.2f}x")
    print(f"|x_lilac - x_naive|_inf = "
          f"{float(jnp.max(jnp.abs(x1 - x0))):.2e}")


if __name__ == "__main__":
    main()
