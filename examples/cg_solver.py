"""NPB-CG analogue: conjugate gradient with a naively-written SpMV,
accelerated by the LiLAC pass without touching the solver.

Run:  PYTHONPATH=src python examples/cg_solver.py [--n 2048] [--iters 100]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.sparse import csr_from_dense
from repro.sparse.random import random_dense_sparse


def build_spd(n, density=0.002, seed=0):
    a = random_dense_sparse(n, n, density, seed)
    a = (a + a.T) / 2 + np.eye(n, dtype=np.float32) * (density * n + 1)
    return csr_from_dense(a), a


def cg(spmv, csr, b, iters=100, tol=1e-8):
    n = b.shape[0]
    x = jnp.zeros(n)
    r = b - spmv(csr.val, csr.col_ind, csr.row_ptr, x)
    p = r
    rs = jnp.dot(r, r)
    for i in range(iters):
        ap = spmv(csr.val, csr.col_ind, csr.row_ptr, p)
        alpha = rs / jnp.dot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.dot(r, r)
        if float(rs_new) < tol:
            return x, i + 1
        p = r + (rs_new / rs) * p
        rs = rs_new
    return x, iters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--policy", default="autotune")
    args = ap.parse_args()

    csr, dense = build_spd(args.n)
    b = jnp.asarray(np.random.default_rng(1).standard_normal(args.n)
                    .astype(np.float32))

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(args.n, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=val.shape[0])
        return jax.ops.segment_sum(val * v[col], row, num_segments=args.n)

    for name, fn in [("naive (-O2 baseline)", jax.jit(naive)),
                     ("lilac", lilac.compile(naive, mode="host", policy=args.policy))]:
        t0 = time.perf_counter()
        x, k = cg(fn, csr, b, iters=args.iters)
        jax.block_until_ready(x)
        dt = time.perf_counter() - t0
        resid = float(np.linalg.norm(dense @ np.asarray(x) - np.asarray(b)))
        print(f"{name:22s}: {dt:7.3f}s  {k} iters  residual {resid:.2e}")
        if hasattr(fn, "cache"):
            info = fn.plan_info()
            print(f"{'':22s}  marshaled once "
                  f"({fn.cache.stats.misses} repack misses); baked plan "
                  f"served {info['plan_hits']} of the solver's calls")


if __name__ == "__main__":
    main()
