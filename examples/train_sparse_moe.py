"""Train a sparse-MoE block through ``lilac.compile(jax.value_and_grad(...))``.

The transform-composition story (docs/transforms.md) end to end: a naive
one-hot MoE written in plain JAX, a loss, and a plain SGD train step.  The
whole ``value_and_grad`` goes through one ``lilac.compile`` call:

* the MoE forward in the loss is detected and replaced by the
  capacity-bucket harness (E·C work instead of E·T);
* the *gradient jaxpr* flows through the same pass, so the backward is
  sparse too — the rewrite composes with ``jax.grad`` instead of being
  silently dropped by it;
* once selections resolve, the entire train step bakes into one jitted
  executable plan — steady-state dispatch is a guard check + one call.

Run:  PYTHONPATH=src python examples/train_sparse_moe.py [--steps 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.models.layers import _moe_naive_2d

T, D, F, E, K = 512, 32, 64, 8, 1
LR = 1e-2


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    params = {
        "wg": jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1),
        "wu": jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32) * .1),
        "wd": jnp.asarray(rng.standard_normal((E, F, D)).astype(np.float32) * .1),
    }
    x = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    gate = jnp.asarray(rng.random((T, K)).astype(np.float32))
    # balanced routing: every expert sees T*K/E tokens, so capacity
    # buckets never drop and gradients equal the dense oracle's
    idx = jnp.asarray((np.arange(T * K).reshape(T, K) % E).astype(np.int32))
    target = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))

    def loss_fn(params, x, gate):
        out = _moe_naive_2d(x, gate, idx,
                            params["wg"], params["wu"], params["wd"])
        return jnp.mean((out - target) ** 2)

    def train_step(params, x, gate):
        loss, g = jax.value_and_grad(loss_fn)(params, x, gate)
        return loss, jax.tree.map(lambda p, gi: p - LR * gi, params, g)

    fast = lilac.compile(train_step)

    # gradient oracle: the rewritten step's grads vs plain jax.grad
    _, p_fast = fast(params, x, gate)
    _, p_ref = jax.jit(train_step)(params, x, gate)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p_fast), jax.tree.leaves(p_ref)))
    print("detection:", fast.last_report.summary())
    print(f"max |params_lilac - params_dense| after one step: {err:.2e}")

    # train: loss must go down; steady state serves the baked plan
    p = params
    t0 = time.perf_counter()
    for step in range(args.steps):
        loss, p = fast(p, x, gate)
        if step % max(1, args.steps // 5) == 0 or step == args.steps - 1:
            print(f"step {step:3d}  loss {float(loss):.5f}")
    dt = (time.perf_counter() - t0) / args.steps
    info = fast.plan_info()
    print(f"{args.steps} steps at {dt * 1e3:.2f} ms/step; "
          f"baked={info['baked']} plan_hits={info['plan_hits']} "
          f"bake_errors={info['bake_errors']}")


if __name__ == "__main__":
    main()
