"""End-to-end training driver: train a reduced OLMoE-family MoE LM with the
LiLAC pass live inside the layer (moe_impl='lilac': the naive one-hot MoE is
detected in the jaxpr and rewritten to the grouped harness at trace time).

Default is laptop-scale; --full trains a ~100M-param config for a few
hundred steps (hours on CPU, minutes on a real accelerator).

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 100] [--full]
"""
import argparse

import jax

from repro.configs import get_arch
from repro.models import build_model
from repro.train.data import SyntheticLM
from repro.train.loop import LoopConfig, train_loop
from repro.train.optim import AdamWConfig


def make_config(full: bool, moe_impl: str):
    base = get_arch("olmoe-1b-7b")
    if full:
        # ~100M active params
        return base.replace(n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                            d_ff=512, vocab=16384, moe_experts=16, moe_topk=4,
                            moe_impl=moe_impl, kv_chunk=256, remat=False,
                            param_dtype=jax.numpy.float32,
                            cache_dtype=jax.numpy.float32)
    return base.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=1024, moe_experts=8, moe_topk=2,
                        moe_impl=moe_impl, kv_chunk=64, remat=False,
                        param_dtype=jax.numpy.float32,
                        cache_dtype=jax.numpy.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--moe-impl", default="lilac",
                    choices=["naive", "lilac", "grouped"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = make_config(args.full, args.moe_impl)
    model = build_model(cfg)
    print(f"arch family={cfg.family} layers={cfg.n_layers} "
          f"d_model={cfg.d_model} experts={cfg.moe_experts} "
          f"params={model.param_count()/1e6:.1f}M "
          f"(active {model.active_param_count()/1e6:.1f}M) "
          f"moe_impl={cfg.moe_impl}")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch)
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    loop = LoopConfig(steps=args.steps, ckpt_every=max(args.steps // 3, 1),
                      log_every=10, ckpt_dir=args.ckpt_dir)
    res = train_loop(model, opt, loop, data.batch_at)
    h = res["history"]
    print(f"loss: {h[0]:.4f} -> {h[-1]:.4f} over {len(h)} steps "
          f"({'DECREASED' if h[-1] < h[0] else 'no improvement'})")
    print(f"stragglers observed: {res['straggler'].slow_steps}")


if __name__ == "__main__":
    main()
