"""Quickstart: the paper's Fig. 1 experience in 40 lines.

An application author writes a naive CSR SpMV in plain JAX.  The
LiLAC-enabled "compiler" (the lilac pass) detects it in the jaxpr via
backtracking search, replaces it with a tuned harness, and the program gets
faster — zero changes to the application code.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.core import what_lang
from repro.sparse import random_csr

ROWS, COLS = 4096, 4096


# --- the application author's code (never modified) -------------------------

def application_spmv(val, col, row_ptr, v):
    """Textbook CSR SpMV, written naively."""
    row = jnp.repeat(jnp.arange(ROWS, dtype=jnp.int32), jnp.diff(row_ptr),
                     total_repeat_length=val.shape[0])
    return jax.ops.segment_sum(val * v[col], row, num_segments=ROWS)


def main():
    print("LiLAC-What specification (paper Fig. 2):")
    print(what_lang.BUILTINS["spmv_csr"])
    print()

    csr = random_csr(ROWS, COLS, density=0.002, seed=0)
    vec = jnp.asarray(np.random.default_rng(1).standard_normal(COLS)
                      .astype(np.float32))

    # detection + rewrite (host mode with marshaling cache)
    spmv = lilac.compile(application_spmv, mode="host", policy="jnp.bcsr")
    out = spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    print("detection:", spmv.last_report.summary())
    ref = application_spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    print("max |lilac - naive| =", float(jnp.max(jnp.abs(out - ref))))

    # measure: naive (jit'd, steady state) vs lilac-rewritten
    naive = jax.jit(application_spmv)
    jax.block_until_ready(naive(csr.val, csr.col_ind, csr.row_ptr, vec))
    reps = 50
    t0 = time.perf_counter()
    for _ in range(reps):
        r = naive(csr.val, csr.col_ind, csr.row_ptr, vec)
    jax.block_until_ready(r)
    t_naive = (time.perf_counter() - t0) / reps

    t0 = time.perf_counter()
    for _ in range(reps):
        r = spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    jax.block_until_ready(r)
    t_lilac = (time.perf_counter() - t0) / reps

    info = spmv.plan_info()
    print(f"naive   : {t_naive * 1e6:9.1f} us/call")
    print(f"lilac   : {t_lilac * 1e6:9.1f} us/call")
    print(f"speedup : {t_naive / t_lilac:.2f}x "
          f"(marshaled once: {spmv.cache.stats.misses} repack; "
          f"baked plan served {info['plan_hits']} calls)")


if __name__ == "__main__":
    main()
