"""Persistent autotuning walkthrough (paper Table 2 / SparseX analogue).

Run this twice:

    PYTHONPATH=src python examples/autotune_demo.py
    PYTHONPATH=src python examples/autotune_demo.py

The first run measures every viable backend on the problem's signature and
persists the winner to the autotune cache (~/.cache/lilac/autotune.json, or
$LILAC_AUTOTUNE_CACHE).  The second run — a fresh process — selects the
same winner straight from disk: zero candidates re-timed.  ``--fresh``
deletes the cache first; ``--trace`` shows the jit-compatible path where
the winner is pinned into the rewrite at first lowering.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import lilac
from repro.core import REGISTRY
from repro.core.autotune import default_cache_path
from repro.sparse.random import random_graph_csr


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--degree", type=int, default=12)
    ap.add_argument("--calls", type=int, default=20)
    ap.add_argument("--fresh", action="store_true",
                    help="delete the autotune cache before running")
    ap.add_argument("--trace", action="store_true",
                    help="also tune the jit-compatible (trace-mode) path")
    args = ap.parse_args()

    path = default_cache_path()
    if args.fresh and path.exists():
        os.unlink(path)
        print(f"removed {path}")

    csr = random_graph_csr(args.n, avg_degree=args.degree, seed=0)
    rows, nnz = csr.rows, csr.nnz
    vec = jnp.asarray(np.random.default_rng(1).standard_normal(
        csr.shape[1]).astype(np.float32))

    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)

    tuner = REGISTRY.autotuner
    print(f"autotune cache: {tuner.cache.path} "
          f"({'exists' if tuner.cache.path.exists() else 'cold'})")

    spmv = lilac.compile(naive, mode="host", policy="autotune")
    t0 = time.perf_counter()
    out = spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    jax.block_until_ready(out)
    first = time.perf_counter() - t0
    sel = spmv.last_selections[0][1] if spmv.last_selections else "<none>"
    s = tuner.stats
    pstats = spmv.plan_info()["plan_cache_stats"] or {}
    if s.timing_calls:
        how = f"measured {s.timing_calls} candidate(s)"
    elif s.fallbacks:
        how = "platform default (tuning disabled or budget exhausted)"
    elif ((pstats.get("memory_hits", 0) or pstats.get("disk_hits", 0))
          and not pstats.get("rejected", 0)):
        # the executable-plan cache outranks even the tuner's disk warm
        # start: detection AND tuning were skipped, the persisted pins
        # went straight into plan baking (docs/dispatch.md)
        how = ("plan-cache warm start — detection and tuning both "
               "skipped, pins rehydrated")
    else:
        how = "warm start — zero candidates re-timed"
    print(f"first call: {first * 1e3:.1f} ms, selected {sel} ({how})")

    t0 = time.perf_counter()
    for _ in range(args.calls):
        out = spmv(csr.val, csr.col_ind, csr.row_ptr, vec)
    jax.block_until_ready(out)
    steady = (time.perf_counter() - t0) / args.calls
    print(f"steady state: {steady * 1e6:.0f} us/call over {args.calls} calls")

    if args.trace:
        opt = lilac.compile(naive, policy="autotune")
        jopt = jax.jit(lambda *a: opt(*a))
        out = jopt(csr.val, csr.col_ind, csr.row_ptr, vec)
        jax.block_until_ready(out)
        sel = opt.last_selections[0][1] if opt.last_selections else "<none>"
        print(f"trace mode under jax.jit: winner {sel} pinned at lowering")

    print(f"tuner stats: {s.as_dict()}")
    print(f"autotune cache holds {len(tuner.cache.entries)} in-memory "
          f"signature(s); baked plans: {spmv.plan_info()['baked']} "
          f"(persisted to {spmv.plan_info()['plan_cache']})")


if __name__ == "__main__":
    main()
