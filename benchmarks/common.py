"""Shared benchmark utilities: timing, problem zoo, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse import csr_from_dense
from repro.sparse.random import random_dense_sparse, random_graph_csr


def timeit(fn: Callable, *args, reps: int = 20, warmup: int = 3) -> float:
    """Median seconds per call (steady state; ``warmup=0`` times cold)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")


# canonical implementations live in repro.serve.metrics (src/ cannot import
# benchmarks/; benchmarks already import repro) — re-exported here so every
# benchmark script shares one percentile/histogram definition
from repro.serve.metrics import latency_histogram, percentiles  # noqa: E402,F401


def sweep(variants: Dict[str, Callable], *args, reps: int = 20,
          warmup: int = 3) -> Dict[str, float]:
    """Median steady-state seconds per named variant — the timing loop
    previously copy-pasted across tab2/fig18, shared by the backend sweeps
    and the per-schedule kernel sweeps.  A variant that raises records NaN
    instead of killing the sweep (mirrors the autotuner's variant
    elimination)."""
    out: Dict[str, float] = {}
    for name, fn in variants.items():
        try:
            out[name] = timeit(fn, *args, reps=reps, warmup=warmup)
        except Exception:
            out[name] = float("nan")
    return out


def naive_spmv_fn(rows: int, nnz: int):
    def naive(val, col, row_ptr, v):
        row = jnp.repeat(jnp.arange(rows, dtype=jnp.int32),
                         jnp.diff(row_ptr), total_repeat_length=nnz)
        return jax.ops.segment_sum(val * v[col], row, num_segments=rows)
    return naive


# problem zoo: stands in for the paper's UFlorida matrices + app inputs
def problem_suite(quick: bool = False) -> Dict[str, object]:
    """``quick=True`` is the CI smoke grid: small instances of two
    structurally different problems, enough to exercise every backend and
    seed the autotune cache in seconds."""
    out = {}
    if quick:
        out["erdos_1k"] = random_graph_csr(1024, avg_degree=12, seed=0)
        out["banded_1k"] = _banded(1024, 9)
        out["dense_block_512"] = csr_from_dense(
            random_dense_sparse(512, 512, 0.05, seed=3))
        return out
    out["erdos_8k"] = random_graph_csr(8192, avg_degree=12, seed=0)
    out["erdos_4k"] = random_graph_csr(4096, avg_degree=16, seed=1)
    out["powerlaw_4k"] = csr_from_dense(
        random_dense_sparse(4096, 4096, 0.002, seed=2, skew=1.0))
    out["banded_8k"] = _banded(8192, 9)
    out["dense_block_2k"] = csr_from_dense(
        random_dense_sparse(2048, 2048, 0.05, seed=3))
    return out


def write_json_report(path: str, report: dict):
    """Write a BENCH_*.json artifact (the perf-trajectory format: one JSON
    object per benchmark run, uploaded by the CI bench-smoke job).  The
    parent directory is created, so `--out /tmp/x/BENCH.json` works
    without losing the run to a FileNotFoundError at the very end."""
    import json
    import os

    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {path}")


def _banded(n: int, band: int):
    d = np.zeros((n, n), np.float32)
    rng = np.random.default_rng(4)
    for off in range(-(band // 2), band // 2 + 1):
        idx = np.arange(max(0, -off), min(n, n - off))
        d[idx, idx + off] = rng.standard_normal(idx.shape[0])
    return csr_from_dense(d)


def vec_for(csr) -> jax.Array:
    return jnp.asarray(np.random.default_rng(9).standard_normal(
        csr.shape[1]).astype(np.float32))
