"""Paper Fig. 16: LiLAC performance as a fraction of a hand-written expert
implementation, plus the lines-of-code-changed productivity comparison.

Expert versions here are hand-optimized JAX: pre-packed formats chosen per
problem, jit'd end-to-end with the packing hoisted out — what an engineer
who rewrote the app would ship.  LiLAC gets its speedup with 0 application
LoC changed (the paper reports 44 one-off LiLAC lines; our builtin What+How
specs total the equivalent — counted below)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, naive_spmv_fn, problem_suite, timeit, vec_for
from repro import lilac
from repro.core import what_lang
from repro.sparse import ell_from_csr


def lilac_loc() -> int:
    """One-off specification lines (the paper's '44 lines' analogue):
    the builtin What-programs, counted as source lines."""
    total = 0
    for comp in what_lang.BUILTINS.values():
        total += str(comp).count("\n") + 1
    return total


def run(reps: int = 10) -> dict:
    suite = problem_suite()
    out = {}
    for prob_name in ("erdos_4k", "banded_8k", "dense_block_2k"):
        csr = suite[prob_name]
        naive = naive_spmv_fn(csr.rows, csr.nnz)
        vec = vec_for(csr)

        # expert version: offline-packed ELL, jit'd, hand-chosen format
        ell = ell_from_csr(csr)

        @jax.jit
        def expert_ell(val, col, perm, v):
            acc = jnp.sum(val * v[col], axis=1)
            return jnp.zeros((val.shape[0],), acc.dtype).at[perm].set(acc)

        t_expert = timeit(expert_ell, ell.val, ell.col, ell.perm, vec,
                          reps=reps)

        # LiLAC compiled path — the paper's model: insertion happens at
        # compile time, zero per-call overhead
        opt = lilac.compile(naive)
        opt_jit = jax.jit(lambda *a: opt(*a))
        t_jit = timeit(opt_jit, csr.val, csr.col_ind, csr.row_ptr, vec,
                       reps=reps)
        # LiLAC runtime-harness path (host mode + marshaling cache):
        # per-call Python overhead, amortizes on large problems
        acc_fn = lilac.compile(naive, mode="host", policy="jnp.ell")
        t_host = timeit(acc_fn, csr.val, csr.col_ind, csr.row_ptr, vec,
                        reps=reps)
        frac_jit = t_expert / t_jit
        out[prob_name] = frac_jit
        emit(f"fig16.{prob_name}", t_jit * 1e6,
             f"fraction_of_expert={frac_jit:.2f} "
             f"(expert {t_expert*1e6:.0f}us, lilac-compiled {t_jit*1e6:.0f}us, "
             f"lilac-runtime {t_host*1e6:.0f}us)")
    emit("fig16.loc", 0.0,
         f"app_loc_changed=0 lilac_spec_loc={lilac_loc()} "
         f"(one-off, application-independent)")
    return out


if __name__ == "__main__":
    run()
