"""Paper Fig. 15: geomean speedup of LiLAC-accelerated applications over
the '-O2' baseline, per application.

Baseline fidelity: the paper's baseline is *sequential compiler-generated
code* — clang/icc cannot vectorize or parallelize sparse loops (their
Table 3). The JAX analogue is the element-wise fori_loop SpMV (what a
C loop becomes), which XLA:CPU likewise executes sequentially. LiLAC
detects the loop skeleton (control-flow matching, §4.1) and replaces it
with a vectorized harness — the same transformation the paper performs.

Applications: CG (NPB), SpMV (Parboil), PageRank, BFS, PFold-like
committor solve (PATHSAMPLE analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, problem_suite, timeit, vec_for
from repro.sparse.ops import row_ids_from_row_ptr


def loop_spmv_fn(rows: int, nnz: int):
    """The sequential element loop — the '-O2 baseline' formulation."""
    def naive(val, row, col, v):
        def body(j, out):
            return out.at[row[j]].add(val[j] * v[col[j]])
        return jax.lax.fori_loop(0, nnz, body, jnp.zeros(rows))
    return naive


def _fit(x, cols):
    if x.shape[0] == cols:
        return x
    if x.shape[0] > cols:
        return x[:cols]
    return jnp.pad(x, (0, cols - x.shape[0]))


def _apps(csr, vec):
    rows = csr.rows
    cols = csr.shape[1]

    def cg_app(spmv, args, iters=5):
        x = jnp.zeros(rows)
        r = _fit(vec, rows)
        p = r
        rs = jnp.dot(r, r)
        for _ in range(iters):
            ap = spmv(*args, _fit(p, cols))
            alpha = rs / (jnp.dot(p, ap) + 1e-9)
            x = x + alpha * p
            r = r - alpha * ap
            rs2 = jnp.dot(r, r)
            p = r + (rs2 / (rs + 1e-9)) * p
            rs = rs2
        return x

    def spmv_app(spmv, args):
        return spmv(*args, vec)

    def pagerank_app(spmv, args, iters=5):
        x = jnp.ones(rows) / rows
        for _ in range(iters):
            x = 0.85 * spmv(*args, _fit(x, cols)) + 0.15 / rows
        return x

    def bfs_app(spmv, args, steps=4):
        frontier = jnp.zeros(rows).at[0].set(1.0)
        visited = frontier
        for _ in range(steps):
            nxt = spmv(*args, _fit(frontier, cols))
            frontier = jnp.where((nxt > 0) & (visited == 0), 1.0, 0.0)
            visited = jnp.maximum(visited, frontier)
        return visited

    def pfold_app(spmv, args, iters=5):
        x = jnp.linspace(0, 1, rows)
        for _ in range(iters):
            x = spmv(*args, _fit(x, cols))
            x = x.at[0].set(0.0).at[-1].set(1.0)
        return x

    return {"NPB-CG": cg_app, "Parboil-SPMV": spmv_app,
            "PageRank": pagerank_app, "BFS": bfs_app, "PFold": pfold_app}


def run(reps: int = 3) -> dict:
    suite = problem_suite()
    # cap problem sizes: the sequential baseline is O(nnz) per call
    probs = {k: v for k, v in suite.items()
             if k in ("erdos_4k", "powerlaw_4k", "dense_block_2k")}
    results = {}
    for app_name in ("NPB-CG", "Parboil-SPMV", "PageRank", "BFS", "PFold"):
        speedups = []
        for prob_name, csr in probs.items():
            vec = vec_for(csr)
            row = row_ids_from_row_ptr(csr.row_ptr, csr.nnz)
            args = (csr.val, row, csr.col_ind)
            naive = loop_spmv_fn(csr.rows, csr.nnz)
            apps = _apps(csr, vec)
            app = apps[app_name]
            base = jax.jit(naive)
            t_naive = timeit(lambda: app(base, args), reps=reps, warmup=1)
            # the paper's model: insertion at compile time (jit'd rewrite)
            from repro import lilac
            opt = lilac.compile(naive)
            acc = jax.jit(lambda *a: opt(*a))
            t_lilac = timeit(lambda: app(acc, args), reps=reps, warmup=1)
            speedups.append(t_naive / t_lilac)
        geo = float(np.exp(np.mean(np.log(speedups))))
        results[app_name] = geo
        emit(f"fig15.{app_name}", 0.0,
             f"geomean_speedup={geo:.2f}x over sequential-loop baseline "
             f"(per-problem: "
             + " ".join(f"{s:.2f}x" for s in speedups) + ")")
    emit("fig15.note", 0.0,
         "XLA:CPU compiles the scalar loop baseline ~100x better than the "
         "paper's clang -O2 (it IS an optimizing tensor compiler), so "
         "speedups here are compressed vs the paper's 1.1-12x; the "
         "TPU-target headroom is quantified in kernels/roofline instead")
    return results


if __name__ == "__main__":
    run()
